"""Personalized-PageRank batch serving: B-user sweeps vs one user at a time.

Personalization is the serving workload the batched [N, B] runtime was
built for: every user carries their own restart vector, so B concurrent
users are B independent PPR solves — but the pull step for all of them is
one SpMM over the shared graph. This benchmark measures exactly that
amortization:

* **batched** — one `rt.ppr_multi(g, sources[:B])` sweep ranks B users in
  a single while_loop (lanes freeze independently as they converge);
* **per_user** — the same B users ranked one sweep each through the
  identical single-lane kernel (what serving looks like without lane
  packing).

Reported per batch width B: wall-clock per sweep, users/sec both ways,
and the amortization ratio. Every batched rank row is asserted against
the NumPy oracle (`ppr_matrix_ref`) before any number is reported — a
fast wrong kernel would be worthless. The full run emits BENCH_ppr.json
with a headline batched/per-user throughput ratio at the widest B.

    PYTHONPATH=src python benchmarks/bench_ppr.py [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_context, runtime as rt
from repro.graph import preferential_attachment
from repro.graph.algorithms_ref import ppr_matrix_ref

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_ppr.json")
DELTA, BETA, MAX_ITER = 0.85, 1e-4, 100


def _time(fn, reps: int) -> float:
    """Best-of-reps wall clock for an already-warm jitted callable."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def bench_width(g, ppr_jit, sources: np.ndarray, b: int, reps: int) -> dict:
    """One row of the sweep: B users batched vs the same B one at a time."""
    srcs = jnp.asarray(sources[:b])
    batched = lambda: ppr_jit(g, srcs)
    jax.block_until_ready(batched())                       # pay the trace
    t_batch = _time(batched, reps)

    # per-user: identical kernel, one lane — the shape is traced once and
    # every user reuses it, so the gap measured is lane packing, not jit
    lone = lambda s: ppr_jit(g, jnp.asarray([s]))
    jax.block_until_ready(lone(int(sources[0])))
    t_seq = 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        for s in sources[:b]:
            jax.block_until_ready(lone(int(s)))
        t_seq = min(t_seq or float("inf"), time.perf_counter() - t0)

    return {
        "batch_users": b,
        "batched_ms": round(t_batch * 1e3, 3),
        "per_user_ms": round(t_seq * 1e3, 3),
        "batched_qps": round(b / t_batch, 1),
        "per_user_qps": round(b / t_seq, 1),
        "speedup": round(t_seq / t_batch, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized graph + sweep (no JSON emitted)")
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args()

    if args.tiny:
        g = preferential_attachment(800, m=6, seed=1)
        widths, reps = [1, 4, 8], args.reps or 2
    else:
        g = preferential_attachment(12000, m=8, seed=1)
        widths, reps = [1, 4, 8, 16, 32], args.reps or 3

    rng = np.random.default_rng(7)
    sources = rng.choice(g.num_nodes, size=max(widths),
                         replace=False).astype(np.int32)
    ppr_jit = jax.jit(lambda gg, ss: rt.ppr_multi(
        gg, ss, delta=DELTA, beta=BETA, max_iter=MAX_ITER))

    # oracle first: the widest batch covers every narrower one's lanes
    got = np.asarray(jax.block_until_ready(
        ppr_jit(g, jnp.asarray(sources))))
    ref = ppr_matrix_ref(g, sources, delta=DELTA, beta=BETA,
                         max_iter=MAX_ITER)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
    print(f"oracle: all {len(sources)} user rank rows match ppr_matrix_ref")

    stats = get_context(g).stats()
    print(f"graph: N={g.num_nodes} E={g.num_edges} "
          f"skew={stats['skew']} | widths={widths} reps={reps}")
    results = {
        "backend": jax.default_backend(),
        "config": {"tiny": args.tiny, "widths": widths, "reps": reps,
                   "delta": DELTA, "beta": BETA, "max_iter": MAX_ITER},
        "graph": stats,
        "oracle": {"users_verified": int(len(sources))},
        "runs": [],
    }
    for b in widths:
        run = bench_width(g, ppr_jit, sources, b, reps)
        results["runs"].append(run)
        print(f"[B={b:3d}] batched {run['batched_ms']:9.2f} ms "
              f"({run['batched_qps']:8.1f} users/s)  per-user "
              f"{run['per_user_ms']:9.2f} ms ({run['per_user_qps']:8.1f} "
              f"users/s)  -> {run['speedup']:5.2f}x")

    top = results["runs"][-1]
    results["headline"] = {
        "batch_users": top["batch_users"],
        "batched_qps": top["batched_qps"],
        "per_user_qps": top["per_user_qps"],
        "qps_ratio": top["speedup"],
        "oracle_verified": True,
    }
    print(f"headline @ B={top['batch_users']}: {top['batched_qps']} users/s "
          f"batched vs {top['per_user_qps']} users/s one-at-a-time "
          f"-> {top['speedup']}x, oracle-verified")

    if not args.tiny:
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {os.path.normpath(OUT_PATH)}")


if __name__ == "__main__":
    main()
