"""Hand-crafted JAX baselines — the 'library code' the paper compares its
generated code against (Galois/Ligra/Gunrock role). Written directly against
jax.numpy with no DSL involvement; the benchmark tables report
generated-vs-handwritten ratios exactly like the paper's Tables 3/5/6."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph.csr import CSRGraph, INF_I32


@jax.jit
def sssp_handwritten(g: CSRGraph, src) -> jax.Array:
    n = g.num_nodes
    dist0 = jnp.full((n,), INF_I32, jnp.int32).at[src].set(0)

    def cond(state):
        return state[1]

    def body(state):
        dist, _ = state
        cand = dist[g.edge_src] + g.weights
        new = dist.at[g.indices].min(cand)
        return new, jnp.any(new < dist)

    dist, _ = jax.lax.while_loop(cond, body, (dist0, jnp.bool_(True)))
    return dist


@jax.jit
def pagerank_handwritten(g: CSRGraph, delta=0.85, beta=1e-4, max_iter=100):
    n = g.num_nodes
    deg = jnp.maximum(g.out_degree, 1)

    def cond(state):
        pr, diff, it, first = state
        return first | ((diff > beta) & (it < max_iter))

    def body(state):
        pr, _, it, _ = state
        contrib = pr / deg
        s = jax.ops.segment_sum(contrib[g.rev_indices], g.rev_edge_dst,
                                num_segments=n, indices_are_sorted=True)
        val = (1 - delta) / n + delta * s
        return val, jnp.sum(jnp.abs(val - pr)), it + 1, jnp.bool_(False)

    pr, _, _, _ = jax.lax.while_loop(
        cond, body, (jnp.full((n,), 1.0 / n), jnp.float32(0), jnp.int32(0),
                     jnp.bool_(True)))
    return pr


@jax.jit
def tc_handwritten(g: CSRGraph) -> jax.Array:
    from repro.core.runtime import wedge_count
    return wedge_count(g)           # same wedge semantics as Fig. 20


def bc_handwritten(g: CSRGraph, sources) -> jax.Array:
    from repro.core.runtime import bfs_levels, segment_sum
    n = g.num_nodes

    @jax.jit
    def one_source(src):
        level, depth = bfs_levels(g, src)
        sigma0 = jnp.zeros((n,), jnp.float32).at[src].set(1.0)

        def fwd(l, sigma):
            em = (level[g.edge_src] == l) & (level[g.indices] == l + 1)
            return sigma + segment_sum(jnp.where(em, sigma[g.edge_src], 0.0),
                                       g.indices, n, sorted_ids=False)
        sigma = jax.lax.fori_loop(0, depth - 1, fwd, sigma0)

        def bwd(k, delta):
            l = depth - 2 - k
            em = (level[g.edge_src] == l) & (level[g.indices] == l + 1)
            contrib = jnp.where(
                em, sigma[g.edge_src] / jnp.maximum(sigma[g.indices], 1e-9)
                * (1.0 + delta[g.indices]), 0.0)
            return delta + segment_sum(contrib, g.edge_src, n)
        delta = jax.lax.fori_loop(0, depth - 1, bwd, jnp.zeros((n,), jnp.float32))
        return jnp.where((level >= 0) & (jnp.arange(n) != src), delta, 0.0)

    bc = jnp.zeros((n,), jnp.float32)
    for s in sources:
        bc = bc + one_source(jnp.int32(s))
    return bc
