"""Benchmark utilities: timing, CSV rows, scaled-down Table-2 suite."""
from __future__ import annotations

import time

import jax

from repro.graph import load_suite

ROWS = []


def timeit(fn, *args, reps=3, warmup=1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6, out      # µs


def row(name, us, derived=""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def header():
    print("name,us_per_call,derived")


_SUITE = None


def suite():
    global _SUITE
    if _SUITE is None:
        _SUITE = load_suite()
    return _SUITE
