"""Benchmark utilities: timing, CSV rows, scaled-down Table-2 suite."""
from __future__ import annotations

import time

import jax

from repro.graph import load_suite

ROWS = []


def timeit(fn, *args, reps=3, warmup=1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6, out      # µs


def row(name, us, derived=""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def header():
    print("name,us_per_call,derived")


_SUITE = None


def suite():
    global _SUITE
    if _SUITE is None:
        _SUITE = load_suite()
    return _SUITE


def weighted_grid(side, seed=0, weight_scale=1):
    """side x side road grid with edge weights multiplied by `weight_scale`.

    The delta-stepping benchmark family: high diameter plus a wide weight
    range means many distinct tentative distances per hop, which is where
    bucketing the frontier by distance pays off. `weight_scale=1` is the
    suite's `road` graph unchanged."""
    import numpy as np

    from repro.graph.csr import from_edges
    from repro.graph.generators import road

    g = road(side, seed=seed)
    if weight_scale == 1:
        return g
    # road() already symmetrized the edge list, so rebuild directed as-is
    return from_edges(g.num_nodes, np.asarray(g.edge_src),
                      np.asarray(g.indices),
                      np.asarray(g.weights) * int(weight_scale))
