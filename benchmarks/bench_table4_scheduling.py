"""Paper Table 4 analogue. The paper studies OpenMP static vs dynamic
scheduling for SSSP; TPU has no thread scheduler, so the analogous
load-balance lever is push (scatter-min) vs pull (gather/segment-min)
operator choice — pronounced on road (large-diameter) vs social graphs,
exactly like the paper's US/GR observation."""
from __future__ import annotations

from repro.core import compile_bundled

from .common import row, suite, timeit


def run(graphs=None):
    graphs = graphs or suite()
    push = compile_bundled("sssp")
    pull = compile_bundled("sssp_pull")
    for gname, g in graphs.items():
        us_push, _ = timeit(lambda: push(g, src=0))
        us_pull, _ = timeit(lambda: pull(g, src=0))
        row(f"table4/sssp_push/{gname}", us_push,
            f"pull_ratio={us_pull/us_push:.2f}")
        row(f"table4/sssp_pull/{gname}", us_pull)
