"""Paper Table 3 analogue: single-device ('OpenMP') backend — DSL-generated
code vs hand-written JAX library code, 4 algorithms × the (scaled) ten-graph
suite. `derived` = generated/handwritten runtime ratio (paper's claim:
competitive ⇒ ratio ≈ 1)."""
from __future__ import annotations

import numpy as np

from repro.core import compile_bundled

from . import handwritten as hw
from .common import row, suite, timeit

BC_SOURCES = np.array([0, 3, 11, 17], np.int32)   # paper uses fixed source lists


def run(graphs=None):
    graphs = graphs or suite()
    progs = {n: compile_bundled(n) for n in ["sssp", "pr", "tc", "bc"]}
    for gname, g in graphs.items():
        us_g, out_g = timeit(lambda: progs["sssp"](g, src=0))
        us_h, out_h = timeit(lambda: hw.sssp_handwritten(g, 0))
        assert np.array_equal(np.asarray(out_g["dist"]), np.asarray(out_h))
        row(f"table3/sssp/{gname}/generated", us_g, f"ratio={us_g/us_h:.2f}")
        row(f"table3/sssp/{gname}/handwritten", us_h)

        us_g, out_g = timeit(lambda: progs["pr"](g, beta=1e-4, delta=0.85, maxIter=100))
        us_h, out_h = timeit(lambda: hw.pagerank_handwritten(g))
        row(f"table3/pr/{gname}/generated", us_g, f"ratio={us_g/us_h:.2f}")
        row(f"table3/pr/{gname}/handwritten", us_h)

        us_g, out_g = timeit(lambda: progs["tc"](g), reps=2)
        us_h, out_h = timeit(lambda: hw.tc_handwritten(g), reps=2)
        assert int(out_g["triangle_count"]) == int(out_h)
        row(f"table3/tc/{gname}/generated", us_g, f"ratio={us_g/us_h:.2f}")
        row(f"table3/tc/{gname}/handwritten", us_h)

        us_g, out_g = timeit(lambda: progs["bc"](g, sourceSet=BC_SOURCES), reps=2)
        us_h, out_h = timeit(lambda: hw.bc_handwritten(g, BC_SOURCES.tolist()), reps=2)
        np.testing.assert_allclose(np.asarray(out_g["BC"]), np.asarray(out_h),
                                   rtol=1e-2, atol=1e-2)
        row(f"table3/bc/{gname}/generated", us_g, f"ratio={us_g/us_h:.2f}")
        row(f"table3/bc/{gname}/handwritten", us_h)
