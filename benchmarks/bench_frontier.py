"""Frontier-aware engine benchmark: dense full-graph sweeps vs the
degree-bucketed sliced-ELL + direction-optimized engine, on a road-like
graph (large diameter, uniform degree) and a power-law graph (hub-skewed —
the case the old `[N, max_deg]` ELL view pads catastrophically).

    PYTHONPATH=src python benchmarks/bench_frontier.py [--smoke]

Emits BENCH_frontier.json next to the repo root so the perf trajectory
accumulates across PRs. Measured quantities per (graph, algo):
  * dense_ms     — fixed point of full dense sweeps (old engine)
  * frontier_ms  — fixed point of frontier-masked hybrid steps (new engine)
  * plus the padded-cells memory footprint of both layouts.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import runtime as rt
from repro.graph import preferential_attachment, road
from repro.graph.csr import INF_I32
from repro.kernels.ell_spmv import ops as kops

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_frontier.json")


def timeit(fn, reps=3):
    out = jax.block_until_ready(fn())       # warmup + compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e3, out               # ms


# --- SSSP ------------------------------------------------------------------

def sssp_dense(g, cols, wts, src):
    """Old engine: full-graph pull sweeps over the single-width ELL view."""
    dist0 = jnp.full((g.num_nodes,), INF_I32, jnp.int32).at[src].set(0)

    def cond(s):
        return s[1]

    def body(s):
        d, _ = s
        d2 = kops._relax_dense(cols, wts, d)
        return d2, jnp.any(d2 < d)

    dist, _ = jax.lax.while_loop(cond, body, (dist0, jnp.bool_(True)))
    return dist


def sssp_frontier(g, ell, src):
    """New engine: frontier-masked sliced-ELL pull / scatter push hybrid."""
    n = g.num_nodes
    dist0 = jnp.full((n,), INF_I32, jnp.int32).at[src].set(0)
    fr0 = jnp.zeros((n,), jnp.bool_).at[src].set(True)

    def cond(s):
        return jnp.any(s[1])

    def body(s):
        d, fr = s
        d2 = kops.relax_minplus(ell, d, frontier=fr, csr=g)
        return d2, d2 < d

    dist, _ = jax.lax.while_loop(cond, body, (dist0, fr0))
    return dist


# --- BFS -------------------------------------------------------------------

def bfs_dense(g, root):
    """Old bfs_levels: one segment-max over ALL edges per level."""
    n = g.num_nodes
    level0 = jnp.full((n,), -1, jnp.int32).at[root].set(0)

    def cond(s):
        return s[2]

    def body(s):
        level, cur, _ = s
        src_on = level[g.edge_src] == cur
        unseen = level[g.indices] < 0
        reach = rt.segment_max((src_on & unseen).astype(jnp.int32), g.indices, n) > 0
        newly = reach & (level < 0)
        return jnp.where(newly, cur + 1, level), cur + 1, jnp.any(newly)

    level, depth, _ = jax.lax.while_loop(cond, body, (level0, jnp.int32(0), jnp.bool_(True)))
    return level, depth


# --- PR gather -------------------------------------------------------------

def pr_dense(g, cols, iters):
    n = g.num_nodes
    x0 = jnp.full((n,), 1.0 / n, jnp.float32)
    inv_deg = 1.0 / jnp.maximum(g.out_degree, 1).astype(jnp.float32)

    def body(_, x):
        y = kops._gather_dense(cols, x * inv_deg)[:n]
        return 0.15 / n + 0.85 * y

    return jax.lax.fori_loop(0, iters, body, x0)


def pr_sliced(g, ell, iters):
    n = g.num_nodes
    x0 = jnp.full((n,), 1.0 / n, jnp.float32)
    inv_deg = 1.0 / jnp.maximum(g.out_degree, 1).astype(jnp.float32)

    def body(_, x):
        y = kops.gather_plustimes(ell, x * inv_deg)
        return 0.15 / n + 0.85 * y

    return jax.lax.fori_loop(0, iters, body, x0)


# --- driver ----------------------------------------------------------------

def bench_graph(gname, g, results):
    n = g.num_nodes
    cols, wts, _ = kops.prepare_ell(g, reverse=True)
    ell = kops.prepare_sliced_ell(g, reverse=True)

    dense_cells = int(cols.shape[0]) * int(cols.shape[1])
    sliced_cells = ell.padded_cells()
    mem = dict(dense_padded_cells=dense_cells, sliced_padded_cells=sliced_cells,
               sliced_over_dense=round(sliced_cells / dense_cells, 4),
               max_in_degree=int(g.max_in_degree), num_edges=g.num_edges,
               bucket_widths=list(ell.widths))
    results[gname] = {"num_nodes": n, "memory": mem}
    print(f"[{gname}] n={n} E={g.num_edges} max_in_deg={g.max_in_degree} "
          f"padded cells dense={dense_cells} sliced={sliced_cells} "
          f"({100 * sliced_cells / dense_cells:.1f}%)")

    d_ms, d_out = timeit(lambda: sssp_dense(g, cols, wts, 0))
    f_ms, f_out = timeit(lambda: sssp_frontier(g, ell, 0))
    assert np.array_equal(np.asarray(d_out), np.asarray(f_out)), "SSSP mismatch"
    results[gname]["sssp"] = dict(dense_ms=round(d_ms, 3), frontier_ms=round(f_ms, 3),
                                  speedup=round(d_ms / f_ms, 2))
    print(f"[{gname}] sssp  dense={d_ms:9.2f}ms  frontier={f_ms:9.2f}ms  "
          f"speedup={d_ms / f_ms:5.2f}x")

    d_ms, (dl, dd) = timeit(lambda: bfs_dense(g, 0))
    f_ms, (fl, fd) = timeit(lambda: rt.bfs_levels(g, 0))
    assert np.array_equal(np.asarray(dl), np.asarray(fl)), "BFS mismatch"
    results[gname]["bfs"] = dict(dense_ms=round(d_ms, 3), frontier_ms=round(f_ms, 3),
                                 speedup=round(d_ms / f_ms, 2))
    print(f"[{gname}] bfs   dense={d_ms:9.2f}ms  frontier={f_ms:9.2f}ms  "
          f"speedup={d_ms / f_ms:5.2f}x")

    iters = 30
    d_ms, d_pr = timeit(lambda: pr_dense(g, cols, iters))
    f_ms, f_pr = timeit(lambda: pr_sliced(g, ell, iters))
    assert np.allclose(np.asarray(d_pr), np.asarray(f_pr), atol=1e-6), "PR mismatch"
    results[gname]["pr"] = dict(dense_ms=round(d_ms, 3), frontier_ms=round(f_ms, 3),
                                speedup=round(d_ms / f_ms, 2))
    print(f"[{gname}] pr    dense={d_ms:9.2f}ms  frontier={f_ms:9.2f}ms  "
          f"speedup={d_ms / f_ms:5.2f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (no JSON emitted)")
    args = ap.parse_args()

    if args.smoke:
        graphs = {"powerlaw": preferential_attachment(800, m=6, seed=1),
                  "road": road(24, seed=2)}
    else:
        graphs = {"powerlaw": preferential_attachment(12000, m=8, seed=1),
                  "road": road(110, seed=2)}

    results = {"backend": jax.default_backend(),
               "config": {"smoke": args.smoke}}
    for gname, g in graphs.items():
        bench_graph(gname, g, results)

    if not args.smoke:
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {os.path.normpath(OUT_PATH)}")
    sp = results["powerlaw"]["sssp"]["speedup"]
    mem = results["powerlaw"]["memory"]["sliced_over_dense"]
    print(f"powerlaw SSSP speedup: {sp}x, sliced/dense padded memory: {mem:.2%}")


if __name__ == "__main__":
    main()
