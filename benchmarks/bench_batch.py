"""Batched multi-source traversal benchmark: the sequential per-source
fori_loop (one full BFS + reverse pass per source) vs the batched engine
(`Schedule.batch_sources`: per-source [N] properties become [B, N]
matrices, every per-bucket SpMV an SpMM with B lanes). The two variants
are two explicit `Schedule`s compiled side by side — the API the schedule
separation exists for.

    PYTHONPATH=src python benchmarks/bench_batch.py [--smoke]

Emits BENCH_batch.json next to the repo root. Measured quantities:
  * BC over S ∈ {32, 64} sources: sequential_ms vs batched_ms (+ speedup),
    outputs asserted to agree within float tolerance;
  * multi-query SSSP: S=64 queries answered by a per-source loop of the
    single-source frontier engine vs one batched `rt.sssp_multi` sweep,
    reported as queries/second.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import timeit as _timeit_us  # noqa: E402  (shared methodology)

from repro.core import Schedule, compile_bundled, runtime as rt
from repro.graph import preferential_attachment

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_batch.json")


def timeit(fn, reps=3):
    """ms wrapper over benchmarks/common.py's timeit (min-of-reps, µs)."""
    us, out = _timeit_us(fn, reps=reps)
    return us / 1e3, out


def bench_bc(g, num_sources, batch, results, backend="local", reps=3):
    srcs = np.linspace(0, g.num_nodes - 1, num_sources).astype(np.int32)
    seq = compile_bundled("bc", backend=backend,
                          schedule=Schedule(batch_sources=1))
    bat = compile_bundled("bc", backend=backend,
                          schedule=Schedule(batch_sources=batch))
    assert "bfs_levels_batch" in bat.source and "bfs_levels_batch" not in seq.source

    s_ms, s_out = timeit(lambda: seq(g, sourceSet=srcs)["BC"], reps)
    b_ms, b_out = timeit(lambda: bat(g, sourceSet=srcs)["BC"], reps)
    np.testing.assert_allclose(np.asarray(b_out), np.asarray(s_out),
                               rtol=1e-3, atol=1e-3)
    key = f"bc_S{num_sources}"
    results[key] = dict(num_sources=num_sources, batch=batch, backend=backend,
                        sequential_ms=round(s_ms, 3), batched_ms=round(b_ms, 3),
                        speedup=round(s_ms / b_ms, 2))
    print(f"[{key}] seq={s_ms:9.1f}ms  batched(B={batch})={b_ms:9.1f}ms  "
          f"speedup={s_ms / b_ms:5.2f}x")


def bench_sssp_multi(g, num_queries, results, reps=3):
    srcs = np.linspace(0, g.num_nodes - 1, num_queries).astype(np.int32)
    single = compile_bundled("sssp", backend="local")

    def seq():
        return [single(g, src=int(s))["dist"] for s in srcs]

    batched = jax.jit(rt.sssp_multi)

    s_ms, s_out = timeit(seq, reps)
    b_ms, b_out = timeit(lambda: batched(g, jnp.asarray(srcs)), reps)
    for i in range(num_queries):
        assert np.array_equal(np.asarray(b_out)[i], np.asarray(s_out[i])), i
    key = f"sssp_multi_S{num_queries}"
    results[key] = dict(
        num_queries=num_queries,
        sequential_ms=round(s_ms, 3), batched_ms=round(b_ms, 3),
        sequential_qps=round(num_queries / (s_ms / 1e3), 1),
        batched_qps=round(num_queries / (b_ms / 1e3), 1),
        speedup=round(s_ms / b_ms, 2))
    print(f"[{key}] seq={s_ms:9.1f}ms ({results[key]['sequential_qps']} q/s)  "
          f"batched={b_ms:9.1f}ms ({results[key]['batched_qps']} q/s)  "
          f"speedup={s_ms / b_ms:5.2f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (no JSON emitted)")
    args = ap.parse_args()

    if args.smoke:
        g = preferential_attachment(800, m=6, seed=1)
        bc_sizes, batch, nq, reps = [8], 4, 8, 1
    else:
        g = preferential_attachment(12000, m=8, seed=1)
        bc_sizes, batch, nq, reps = [32, 64], 32, 64, 3

    sched = Schedule(batch_sources=batch)
    results = {"backend": jax.default_backend(),
               "config": {"smoke": args.smoke, "num_nodes": g.num_nodes,
                          "num_edges": g.num_edges, "batch_sources": batch,
                          "engine": {"num_buckets": sched.num_buckets,
                                     "push_threshold_frac": sched.push_threshold_frac}}}
    for s in bc_sizes:
        bench_bc(g, s, batch, results, reps=reps)
    bench_sssp_multi(g, nq, results, reps=reps)

    if not args.smoke:
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {os.path.normpath(OUT_PATH)}")
    sp = results[f"bc_S{bc_sizes[0]}"]["speedup"]
    print(f"BC S={bc_sizes[0]} batched speedup: {sp}x")


if __name__ == "__main__":
    main()
