"""Dynamic-graph benchmark: incremental `refresh` vs from-scratch recompute.

A 12k-vertex power-law graph absorbs a stream of write batches of
increasing size. After each `g.update(adds, dels)` the SSSP program is
re-run two ways on the new version:

  * **full** — `bound(src=0)` from scratch, and
  * **refresh** — `bound.refresh(prev, delta, src=0)` warm-started from
    the previous version's distances, with the deletion cone reset and
    the sweep seeded only at update-incident vertices
    (`Schedule(refresh_threshold_frac=1.0)` forces the incremental path
    so every batch size is measured through it; `affected_frac` in the
    output shows where the default 0.25 threshold would have fallen back
    to the dense recompute instead).

Two comparisons per batch, the refreshed answer asserted identical to
the from-scratch answer every time:

  * ``wall_ms`` — measured wall-clock of both paths (both warmed on the
    same graph version first, so retracing is excluded).
  * ``edges_relaxed`` — a host-side numpy replay of the monotone relax
    sweep counting frontier out-edges: cold starts from {src}, warm
    starts from the refresh plan's seed with its reset applied. This is
    the actual relaxation work each path performs; for insert-only
    batches the warm count must be strictly lower (asserted).

Deletions reset the conservative forward closure of the deleted edges'
heads, and on a low-diameter power-law graph that cone is most of the
vertex set — so delete-heavy batches land near ``affected_frac == 1``
and approach full-recompute work. That regime is included deliberately:
it is exactly what `refresh_threshold_frac` exists to gate (the default
0.25 sends such batches down the dense path), while insert-heavy
batches seed only the new edges' sources and relax a small fraction of
the cold run's edges.

    PYTHONPATH=src python benchmarks/bench_dynamic.py [--tiny]

Emits BENCH_dynamic.json at the repo root (full run only).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import timeit as _timeit_us  # noqa: E402

from repro.core import Schedule, compile_bundled  # noqa: E402
from repro.graph import powerlaw_social  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_dynamic.json")
INF = np.int64(2**30)


def random_batch(rng, g, k_add, k_del):
    """k_add genuinely-new edges + k_del existing edges. New pairs are
    rejection-sampled: re-adding an existing pair is a weight
    *replacement* (removal + addition), which would reset a deletion
    cone and turn an "insert-only" batch into a delete."""
    n = g.num_nodes
    existing = set(zip(np.asarray(g.edge_src).tolist(),
                       np.asarray(g.indices).tolist()))
    adds = []
    while len(adds) < k_add:
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u != v and (u, v) not in existing:
            existing.add((u, v))
            adds.append((u, v))
    adds = np.array(adds, np.int64)
    weights = rng.integers(1, 10, k_add)
    idx = rng.choice(g.num_edges, min(k_del, g.num_edges), replace=False)
    dels = np.stack([np.asarray(g.edge_src)[idx],
                     np.asarray(g.indices)[idx]], 1)
    return adds, dels, weights


def replay_edges(g, dist0, frontier0):
    """Monotone relax sweep on the host, counting frontier out-edges —
    the same rule the lowered fixedPoint runs, so the edge count is the
    work either path performs."""
    out_deg = np.diff(np.asarray(g.indptr))
    indices, edge_src = np.asarray(g.indices), np.asarray(g.edge_src)
    wts = np.asarray(g.weights, np.int64)
    dist = np.asarray(dist0, np.int64).copy()
    front = frontier0.copy()
    edges = 0
    while front.any():
        edges += int(out_deg[front].sum())
        fe = front[edge_src]
        cand = np.full(len(dist), INF, np.int64)
        np.minimum.at(cand, indices[fe], dist[edge_src[fe]] + wts[fe])
        improved = cand < dist
        dist = np.minimum(dist, cand)
        front = improved
    return edges, dist


def work_metric(delta, prev_dist, src):
    """edges_relaxed for cold-from-src vs warm-from-seed on delta.graph."""
    g2 = delta.graph
    n = g2.num_nodes
    plan = delta.plan()

    cold_front = np.zeros(n, bool)
    cold_front[src] = True
    cold_dist = np.full(n, INF, np.int64)
    cold_dist[src] = 0
    cold_edges, cold = replay_edges(g2, cold_dist, cold_front)

    warm_dist = np.asarray(prev_dist, np.int64).copy()
    warm_dist[plan.reset] = INF
    warm_dist[src] = 0
    warm_edges, warm = replay_edges(g2, warm_dist, plan.seed.copy())
    assert np.array_equal(cold, warm), "warm replay reached a different fixpoint"
    return cold_edges, warm_edges, cold


def bench_backend(backend, g0, batch_sizes, reps, seed, measure_work):
    prog = compile_bundled("sssp", backend=backend,
                           schedule=Schedule(refresh_threshold_frac=1.0))
    rng = np.random.default_rng(seed)
    g = g0
    prev = prog.bind(g)(src=0)
    rows = []
    for label, k_add, k_del in batch_sizes:
        adds, dels, w = random_batch(rng, g, k_add, k_del)
        delta = g.update(adds, dels, weights=w)
        plan = delta.plan()
        bound = prog.bind(delta.graph)

        # warm both paths on this version, then measure
        bound(src=0)
        bound.refresh(prev, delta, src=0)
        full_us, scratch = _timeit_us(lambda: bound(src=0), reps=reps)
        refresh_us, refreshed = _timeit_us(
            lambda: bound.refresh(prev, delta, src=0), reps=reps)
        sd = np.asarray(scratch["dist"])
        rd = np.asarray(refreshed["dist"])
        assert np.array_equal(sd, rd), \
            f"{backend}/{label}: refresh disagrees with from-scratch"

        row = {
            "batch": label, "k_add": k_add, "k_del": k_del,
            "effective_added": delta.num_added,
            "effective_removed": delta.num_removed,
            "affected_frac": round(plan.affected_frac, 4),
            "cone_size": plan.cone_size,
            "full_ms": round(full_us / 1e3, 3),
            "refresh_ms": round(refresh_us / 1e3, 3),
            "wall_speedup": round(full_us / max(refresh_us, 1e-9), 3),
        }
        if measure_work:
            cold_e, warm_e, replay = work_metric(delta, prev["dist"], src=0)
            assert np.array_equal(
                np.where(sd.astype(np.int64) >= INF, INF,
                         sd.astype(np.int64)), replay), \
                f"{backend}/{label}: replay disagrees with compiled output"
            row.update({
                "cold_edges_relaxed": cold_e,
                "warm_edges_relaxed": warm_e,
                "work_ratio": round(cold_e / max(warm_e, 1), 2),
            })
        rows.append(row)
        print(f"[{backend}] {label:7s} adds={k_add:4d} dels={k_del:4d} "
              f"affected={plan.affected_frac:6.3f}  "
              f"full={row['full_ms']:8.2f}ms refresh={row['refresh_ms']:8.2f}ms"
              + (f"  edges {cold_e}->{warm_e}" if measure_work else ""))
        g, prev = delta.graph, refreshed
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized graph + reps (no JSON emitted)")
    args = ap.parse_args()

    if args.tiny:
        n, avg_degree, reps = 400, 8, 1
        batch_sizes = [("small-ins", 4, 0), ("mixed", 16, 12)]
    else:
        n, avg_degree, reps = 12000, 8, 3
        batch_sizes = [("small-ins", 8, 0), ("small-ins", 8, 0),
                       ("medium-ins", 64, 0),
                       ("mixed", 64, 48), ("large", 512, 384)]

    g0 = powerlaw_social(n, avg_degree=avg_degree, seed=7)
    print(f"graph: powerlaw n={g0.num_nodes} m={g0.num_edges}")

    results = {
        "config": {"tiny": args.tiny, "reps": reps, "num_nodes": g0.num_nodes,
                   "num_edges": g0.num_edges},
        "note": ("Each batch: g.update -> full recompute vs "
                 "bound.refresh(prev, delta) on the new version, answers "
                 "asserted identical. edges_relaxed comes from a host "
                 "replay of the monotone relax sweep (cold from {src} vs "
                 "warm from the refresh plan's seed); affected_frac is "
                 "the seed fraction the 0.25 default threshold gates on. "
                 "Delete-heavy batches reset a conservative forward cone "
                 "that covers most of a low-diameter graph (high "
                 "affected_frac) — the regime the threshold routes to "
                 "the dense path; insert-only batches show the "
                 "incremental win."),
        "backends": {}}
    for backend in ("local", "pallas"):
        results["backends"][backend] = bench_backend(
            backend, g0, batch_sizes, reps,
            seed=11, measure_work=(backend == "local"))

    # acceptance: insert-only small batches must beat full recompute on
    # the work axis (structurally true: the seed is a handful of sources)
    small = [r for r in results["backends"]["local"]
             if r["batch"].endswith("-ins")]
    for r in small:
        assert r["warm_edges_relaxed"] < r["cold_edges_relaxed"], r
    best = max(small, key=lambda r: r["work_ratio"])
    print(f"insert-batch work ratio up to x{best['work_ratio']} "
          f"(edges relaxed {best['cold_edges_relaxed']} -> "
          f"{best['warm_edges_relaxed']}), "
          f"wall x{best['wall_speedup']}")

    if not args.tiny:
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {os.path.normpath(OUT_PATH)}")


if __name__ == "__main__":
    main()
