"""Distributed frontier-exchange benchmark: dense vs compressed supersteps.

Runs the distributed backend on 8 virtual host devices and compares the
BSP property-exchange policies (`Schedule.dist_frontier`) on the BFS and
SSSP workloads:

  * per-superstep gathered-element counts — reconstructed host-side by
    replaying the exchange decision rule over the same frontier sizes, and
    cross-checked against the `_gather_elems` counter the generated
    program itself accumulates on device (the two must agree exactly);
  * wall-clock per query, measured identically for every policy.

The dense policy is the paper's scheme (full all-gather every superstep)
and the baseline; "compact" exchanges only changed entries through fixed
per-shard buffers; "auto" additionally skips empty supersteps. On CPU
host devices the collectives are memcpys, so the volume reduction is the
headline number here and the wall-clock is reported honestly either way —
the volume is what an ICI-attached mesh would save.

    PYTHONPATH=src python benchmarks/bench_dist.py [--tiny]

Emits BENCH_dist.json next to the repo root (full run only).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# 8 virtual devices — must precede the first jax import
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402
import numpy as np  # noqa: E402

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import timeit as _timeit_us  # noqa: E402

from repro.core import Schedule, compile_bundled, dist  # noqa: E402
from repro.core.runtime_dist import compact_cap  # noqa: E402
from repro.graph import preferential_attachment  # noqa: E402
from repro.graph.algorithms_ref import bfs_levels_ref  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_dist.json")
P = 8
POLICIES = ("dense", "compact", "auto")


# --------------------------------------------------------------------------
# host-side replay of the exchange decision rule (per-superstep volumes)
# --------------------------------------------------------------------------

def _exchange_vol(chg_counts, n_pad, block, frac, policy):
    """Elements one exchange moves, given per-shard change counts — the
    exact rule `rtd.exchange` applies on device."""
    if policy == "dense":
        return n_pad
    cap = compact_cap(block, frac)
    skip_empty = policy == "auto"
    if 2 * cap * P >= n_pad:                      # compact can't win: dense
        return 0 if (skip_empty and sum(chg_counts) == 0) else n_pad
    if skip_empty and sum(chg_counts) == 0:
        return 0
    return 2 * cap * P if max(chg_counts) <= cap else n_pad


def _shard_counts(changed_mask, block):
    n_pad = len(changed_mask)
    return [int(changed_mask[s * block:(s + 1) * block].sum())
            for s in range(n_pad // block)]


def _pad(arr, n_pad, fill):
    out = np.full(n_pad, fill, arr.dtype)
    out[: len(arr)] = arr
    return out


def replay_sssp_supersteps(g, src, frac, policy):
    """Per-superstep exchange volumes of the generated distributed SSSP:
    each superstep exchanges `dist` then `modified` (sorted read order),
    plus the two initial gathers when the policy carries full views."""
    n = g.num_nodes
    block = -(-n // P)
    n_pad = block * P
    INF = np.int32(2**30)
    esrc = np.asarray(g.edge_src)
    edst = np.asarray(g.indices)
    w = np.asarray(g.weights)
    dist_b = np.full(n_pad, INF, np.int64)
    dist_b[src] = 0
    mod_b = np.zeros(n_pad, bool)
    mod_b[src] = True
    dist_f, mod_f = dist_b.copy(), mod_b.copy()
    steps = []
    initial = 2 * n_pad if policy != "dense" else 0   # pre-loop full gathers
    while True:
        vol = _exchange_vol(_shard_counts(dist_b != dist_f, block),
                            n_pad, block, frac, policy)
        dist_f = dist_b.copy()
        vol += _exchange_vol(_shard_counts(mod_b != mod_f, block),
                             n_pad, block, frac, policy)
        mod_f = mod_b.copy()
        steps.append(vol)
        nd = dist_b.copy()
        on = mod_f[esrc]
        np.minimum.at(nd, edst[on], dist_f[esrc[on]] + w[on])
        mod_b = nd < dist_b
        dist_b = nd
        if not mod_b.any():
            break
    return steps, initial + sum(steps)


def replay_bfs_supersteps(g, src, frac, policy):
    """Per-superstep exchange volumes of `rtd.bfs_levels_1d` (the
    iterateInBFS expansion): per level, the changed entries are exactly
    the newly visited vertices."""
    n = g.num_nodes
    block = -(-n // P)
    n_pad = block * P
    level = _pad(bfs_levels_ref(g, src).astype(np.int64), n_pad, -1)
    depth = int(level.max())
    steps = []
    for lvl in range(1, depth + 2):   # loop runs until no new vertices
        newly = level == lvl
        steps.append(_exchange_vol(_shard_counts(newly, block),
                                   n_pad, block, frac, policy))
    return steps, n_pad + sum(steps)   # + the initial full gather


# --------------------------------------------------------------------------
# the measured side
# --------------------------------------------------------------------------

def _bfs_runner(g, mesh, policy, frac):
    """Drive `rtd.bfs_levels_1d` (the kernel the iterateInBFS construct
    calls) directly under shard_map — the pure BFS workload, with the
    returned gathered-element counter."""
    from jax.sharding import PartitionSpec as PS

    from repro.core import runtime_dist as rtd
    gd = rtd.prepare_graph_1d(g, P)
    n_pad = int(gd["own_ids"].size)
    specs = rtd.partition_specs(gd, mesh)

    def body(gd_, root_):
        return rtd.bfs_levels_1d(
            gd_["esrc"][0], gd_["edst"][0], gd_["evalid"][0],
            gd_["isrc"][0], gd_["idst_local"][0], gd_["ivalid"][0],
            gd_["own_ids"][0], root_, n_pad,
            frontier=policy, gather_frac=frac,
            direction="auto", threshold_frac=1.0 / 16.0)

    fn = jax.jit(rtd.shard_map(body, mesh=mesh,
                               in_specs=(specs, PS()),
                               out_specs=(PS("data"), PS(), PS())))
    return lambda root: fn(gd, root)


def bench_family(name, g, mesh, src, reps, results):
    fam = {"num_nodes": g.num_nodes, "num_edges": g.num_edges,
           "num_shards": P, "workloads": {"sssp": {}, "bfs": {}}}
    for policy in POLICIES:
        sched = Schedule(dist_frontier=policy)

        # --- SSSP: the whole generated distributed program ---------------
        prog = compile_bundled("sssp", backend="distributed", schedule=sched)
        bound = prog.bind(g, mesh=mesh)
        us, out = _timeit_us(lambda: bound(src=src), reps=reps)
        measured = int(out["_gather_elems"])
        per_step, replayed = replay_sssp_supersteps(
            g, src, sched.dist_gather_frac, policy)
        fam["workloads"]["sssp"][policy] = {
            "wall_ms": round(us / 1e3, 3),
            "gather_elems_device": measured,
            "gather_elems_replayed": replayed,
            "counter_matches_replay": measured == replayed,
            "per_superstep": per_step,
            "supersteps": len(per_step),
        }
        print(f"[{name}/sssp] {policy:8s} wall={us / 1e3:9.2f}ms"
              f"  elems={measured} (replay {replayed})  steps={len(per_step)}")

        # --- BFS: the runtime kernel iterateInBFS lowers to ---------------
        run = _bfs_runner(g, mesh, policy, sched.dist_gather_frac)
        us, (_, _, elems) = _timeit_us(run, np.int32(src), reps=reps)
        measured = int(elems)
        per_step, replayed = replay_bfs_supersteps(
            g, src, sched.dist_gather_frac, policy)
        fam["workloads"]["bfs"][policy] = {
            "wall_ms": round(us / 1e3, 3),
            "gather_elems_device": measured,
            "gather_elems_replayed": replayed,
            "counter_matches_replay": measured == replayed,
            "per_superstep": per_step,
            "supersteps": len(per_step),
        }
        print(f"[{name}/bfs ] {policy:8s} wall={us / 1e3:9.2f}ms"
              f"  elems={measured} (replay {replayed})  steps={len(per_step)}")

    for work in ("sssp", "bfs"):
        w = fam["workloads"][work]
        w["volume_ratio_auto_vs_dense"] = round(
            w["auto"]["gather_elems_device"]
            / max(w["dense"]["gather_elems_device"], 1), 4)
    results["families"][name] = fam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized graph + reps (no JSON emitted)")
    args = ap.parse_args()
    assert len(jax.devices()) >= P, "expected 8 forced host devices"
    mesh = dist.make_mesh_1d(P)

    if args.tiny:
        fams = {"powerlaw": preferential_attachment(800, m=6, seed=1)}
        reps = 1
    else:
        fams = {"powerlaw": preferential_attachment(12000, m=8, seed=1)}
        reps = 3

    results = {"backend": jax.default_backend(), "num_shards": P,
               "config": {"tiny": args.tiny, "reps": reps},
               "note": ("gathered elements = property-exchange volume per "
                        "device; the push-combine volume is policy-"
                        "invariant and excluded. On CPU host devices the "
                        "collectives are memcpys, so wall-clock tracks "
                        "compute more than volume."),
               "families": {}}
    for name, g in fams.items():
        bench_family(name, g, mesh, src=0, reps=reps, results=results)

    for work in ("sssp", "bfs"):
        w = results["families"]["powerlaw"]["workloads"][work]
        assert all(w[p]["counter_matches_replay"] for p in POLICIES), (
            f"{work}: device counter disagrees with the host replay")
        print(f"{work}: volume auto/dense = {w['volume_ratio_auto_vs_dense']}"
              f"  (device counter == host replay for all policies)")
    if not args.tiny:
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {os.path.normpath(OUT_PATH)}")


if __name__ == "__main__":
    main()
