"""Paper Fig. 17 analogue: scaling with parallelism (threads → devices).
Runs the distributed SSSP/PR on 1/2/4/8 host devices in subprocesses and
reports the scaling curve."""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import row

_SCRIPT = r"""
import json, time, sys
import numpy as np, jax
from repro.core import compile_bundled, dist
from repro.graph import load_suite

nd = int(sys.argv[1])
mesh = dist.make_mesh_1d(nd)
g = load_suite(["LJ"])["LJ"]

def timeit(fn, reps=3):
    fn(); ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); jax.block_until_ready(fn()); ts.append(time.perf_counter()-t0)
    return min(ts)*1e6

out = {}
p = compile_bundled("sssp", backend="distributed")
out["sssp"] = timeit(lambda: dist.run(p, g, mesh, src=0)["dist"])
p = compile_bundled("pr", backend="distributed")
out["pr"] = timeit(lambda: dist.run(p, g, mesh, beta=1e-4, delta=0.85, maxIter=50)["pageRank"])
print("RESULTS:" + json.dumps(out))
"""


def run(graphs=None):
    base = {}
    for nd in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={nd}"
        env.setdefault("PYTHONPATH", "src")
        proc = subprocess.run([sys.executable, "-c", _SCRIPT, str(nd)], env=env,
                              capture_output=True, text=True, timeout=900)
        if proc.returncode != 0:
            print(f"fig17/ERROR_{nd},,{proc.stderr[-300:]}")
            continue
        res = json.loads([l for l in proc.stdout.splitlines()
                          if l.startswith("RESULTS:")][0][len("RESULTS:"):])
        for alg, us in res.items():
            if nd == 1:
                base[alg] = us
            row(f"fig17/{alg}/devices={nd}", us,
                f"speedup={base.get(alg, us)/us:.2f}")
