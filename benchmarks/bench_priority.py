"""Delta-stepping SSSP benchmark: `Schedule.priority` on weighted grids.

Compares the monotonic Min-relax lowering (`priority="none"`) against the
delta-stepping lowering (`priority="delta"`, several bucket widths) on the
suite's road-grid family — high diameter, uniform weights in [1, 100] —
where bucketing the frontier by tentative distance pays.

Three work metrics come from a host-side numpy replay of the exact
lowered iteration rules, plus measured wall-clock:

  * ``relax_sweeps`` — fixedPoint loop trips (one frontier relaxation
    each). The monotonic loop runs exactly hop-diameter + 1 trips; the
    delta loop re-sweeps inside a bucket until it settles, so it can trip
    MORE while touching far fewer edges per trip.
  * ``bucket_phases`` — distinct priority buckets processed (delta only;
    reported as == sweeps for the monotonic baseline). This is the
    superstep count a distributed run pays collectives for per bucket.
  * ``edges_relaxed`` — total frontier out-edges relaxed across the run:
    the actual work. Monotonic relaxation re-relaxes every vertex whose
    tentative distance later improves; delta-stepping settles a bucket
    before expanding past it, so far fewer corrections happen.

The replay's final distances are asserted identical to the compiled
program's output for every (priority, delta_bucket) point, and the
autotuner is run on each graph to confirm it selects (or measures
no-worse-than) a delta schedule on this family.

    PYTHONPATH=src python benchmarks/bench_priority.py [--tiny]

Emits BENCH_priority.json at the repo root (full run only).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import timeit as _timeit_us  # noqa: E402
from common import weighted_grid  # noqa: E402

from repro.autotune import autotune  # noqa: E402
from repro.core import Schedule, compile_bundled  # noqa: E402
from repro.core.context import get_context  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_priority.json")
INF = np.int64(2**30)


# --------------------------------------------------------------------------
# host-side replay of the two lowered fixedPoint iteration rules
# --------------------------------------------------------------------------

def _edge_arrays(g):
    return (np.asarray(g.edge_src), np.asarray(g.indices),
            np.asarray(g.weights).astype(np.int64))


def replay_monotonic(g, src):
    """The priority="none" lowering: frontier = every vertex modified last
    sweep; relax all its out-edges; repeat until no distance improves."""
    esrc, edst, w = _edge_arrays(g)
    dist = np.full(g.num_nodes, INF)
    dist[src] = 0
    mod = np.zeros(g.num_nodes, bool)
    mod[src] = True
    sweeps = edges = 0
    while mod.any():
        on = mod[esrc]
        nd = dist.copy()
        np.minimum.at(nd, edst[on], dist[esrc[on]] + w[on])
        edges += int(on.sum())
        mod = nd < dist
        dist = nd
        sweeps += 1
    return dist, {"relax_sweeps": sweeps, "bucket_phases": sweeps,
                  "edges_relaxed": edges}


def replay_delta(g, src, delta):
    """The priority="delta" lowering: per trip, advance the bucket if no
    pending vertex falls under its upper bound, take the in-window slice
    as the frontier, relax it, and carry the out-of-window rest."""
    esrc, edst, w = _edge_arrays(g)
    dist = np.full(g.num_nodes, INF)
    dist[src] = 0
    mod = np.zeros(g.num_nodes, bool)
    mod[src] = True
    bk = 0
    sweeps = phases = edges = 0
    last_bk = -1
    while mod.any():
        if not (mod & (dist < (bk + 1) * delta)).any():
            bk = int(dist[mod].min()) // delta
        if bk != last_bk:
            phases += 1
            last_bk = bk
        fr = mod & (dist < (bk + 1) * delta)
        keep = mod & ~fr
        on = fr[esrc]
        nd = dist.copy()
        np.minimum.at(nd, edst[on], dist[esrc[on]] + w[on])
        edges += int(on.sum())
        mod = (nd < dist) | keep
        dist = nd
        sweeps += 1
    return dist, {"relax_sweeps": sweeps, "bucket_phases": phases,
                  "edges_relaxed": edges}


# --------------------------------------------------------------------------
# the measured side
# --------------------------------------------------------------------------

def bench_family(name, g, src, reps, results):
    stats = get_context(g).stats()
    avg_w = max(stats["avg_weight"], 1.0)
    deltas = [max(int(avg_w * m), 1) for m in (4, 16, 64)]
    fam = {"num_nodes": g.num_nodes, "num_edges": g.num_edges,
           "avg_weight": stats["avg_weight"], "variants": {}}

    ref = None
    for label, sched in [("none", Schedule())] + [
            (f"delta/{d}", Schedule(priority="delta", delta_bucket=d))
            for d in deltas]:
        prog = compile_bundled("sssp", backend="local", schedule=sched)
        bound = prog.bind(g)
        us, out = _timeit_us(lambda: bound(src=src), reps=reps)
        dist = np.asarray(out["dist"])
        if ref is None:
            ref = dist
        assert np.array_equal(dist, ref), f"{name}/{label}: wrong distances"

        if sched.priority == "delta":
            rdist, work = replay_delta(g, src, sched.delta_bucket)
        else:
            rdist, work = replay_monotonic(g, src)
        assert np.array_equal(
            np.where(dist >= INF, INF, dist.astype(np.int64)), rdist), \
            f"{name}/{label}: replay disagrees with the compiled program"

        fam["variants"][label] = {"wall_ms": round(us / 1e3, 3), **work}
        print(f"[{name}] {label:10s} wall={us / 1e3:8.2f}ms"
              f"  sweeps={work['relax_sweeps']:4d}"
              f"  phases={work['bucket_phases']:4d}"
              f"  edges_relaxed={work['edges_relaxed']}")

    base = fam["variants"]["none"]
    best_label = min(
        (k for k in fam["variants"] if k != "none"),
        key=lambda k: fam["variants"][k]["wall_ms"])
    best = fam["variants"][best_label]
    fam["best_delta"] = best_label
    fam["speedup_wall"] = round(base["wall_ms"] / best["wall_ms"], 3)
    fam["phase_ratio"] = round(
        base["bucket_phases"] / best["bucket_phases"], 2)
    fam["edges_ratio"] = round(
        base["edges_relaxed"] / best["edges_relaxed"], 2)

    # --- does the autotuner find this on its own? ------------------------
    prog = compile_bundled("sssp", backend="local")
    res = autotune(prog, g, budget=12, params={"src": src}, reps=reps)
    tuned_delta = res.schedule.priority == "delta"
    fam["autotune"] = {
        "selected_priority": res.schedule.priority,
        "selected_delta_bucket": res.schedule.delta_bucket,
        "speedup_vs_default": round(res.speedup, 3),
    }
    print(f"[{name}] autotune -> priority={res.schedule.priority!r} "
          f"delta_bucket={res.schedule.delta_bucket} "
          f"speedup={res.speedup:.2f}x")
    # acceptance: the tuner either picks delta or measured it no faster
    assert tuned_delta or res.speedup >= 1.0
    results["families"][name] = fam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized graph + reps (no JSON emitted)")
    args = ap.parse_args()

    if args.tiny:
        fams = {"grid24": weighted_grid(24, seed=7)}
        reps = 1
    else:
        fams = {"grid96": weighted_grid(96, seed=7),
                "grid64": weighted_grid(64, seed=8)}
        reps = 3

    results = {
        "config": {"tiny": args.tiny, "reps": reps},
        "note": ("relax_sweeps/bucket_phases/edges_relaxed come from a "
                 "host-side replay of the lowered iteration rules, "
                 "asserted bit-identical to the compiled program's "
                 "distances. The monotonic baseline needs hop-diameter+1 "
                 "sweeps; delta-stepping trades a few extra in-bucket "
                 "sweeps for far fewer corrected (re-relaxed) edges."),
        "families": {}}
    for name, g in fams.items():
        bench_family(name, g, src=0, reps=reps, results=results)

    for name, fam in results["families"].items():
        print(f"{name}: delta best={fam['best_delta']} "
              f"wall x{fam['speedup_wall']}  "
              f"phases x{fam['phase_ratio']}  "
              f"edges x{fam['edges_ratio']} vs monotonic")
    if not args.tiny:
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {os.path.normpath(OUT_PATH)}")


if __name__ == "__main__":
    main()
