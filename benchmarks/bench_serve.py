"""Serving-layer benchmark: coalesced vs one-query-per-sweep SSSP serving.

Drives `repro.serve.GraphService` with an open-loop Poisson arrival
process (requests arrive on their own clock, whether or not the server
has kept up — the honest way to measure a service, since a closed loop
self-throttles and hides queueing collapse). At each arrival rate the
same query stream is served twice:

* **coalesced** — the dispatcher packs up to `Schedule.batch_sources`
  concurrent queries into one batched [N, B] SpMM sweep (waiting at most
  `max_wait_ms` for lane-mates);
* **per_query** — coalescing disabled: every query runs as its own sweep
  through the bound compiled program (what serving looked like before
  this layer).

Reported per (mode, rate): achieved queries/sec, p50/p99 latency from the
*scheduled* arrival time (so backlog shows up as latency), mean lane
occupancy, sweeps, and admission/timeout counts. Every served answer is
asserted equal to the numpy reference oracle (`sssp_ref`, memoized per
unique source). The full run emits BENCH_serve.json with a headline
coalesced/per-query throughput ratio at the saturating (top) rate.

    PYTHONPATH=src python benchmarks/bench_serve.py [--tiny]
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os

import jax
import numpy as np

from repro.core import get_context
from repro.graph import preferential_attachment
from repro.graph.algorithms_ref import sssp_ref
from repro.schedule import Schedule
from repro.serve import (GraphService, ServiceConfig, ServiceOverloaded,
                         ServiceTimeout)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
TIMEOUT_S = 60.0          # per-request deadline the p99 must stay under


def make_service(g, *, coalesce: bool, width: int, max_wait_ms: float):
    svc = GraphService(ServiceConfig(
        backend="local", schedule=Schedule(batch_sources=width),
        coalesce=coalesce, max_wait_ms=max_wait_ms, max_pending=1 << 16,
        default_timeout_s=TIMEOUT_S))
    svc.register_graph("g", g, kinds=["sssp"])
    return svc


async def warmup(svc, width: int):
    """Pay every jit trace before timing: bursts of exactly k concurrent
    queries for each power-of-two lane occupancy the load can produce."""
    k = 1
    while k <= width:
        await asyncio.gather(*(svc.query("g", "sssp", src=s % 7)
                               for s in range(k)))
        k *= 2


async def run_load(svc, srcs: np.ndarray, rate: float, seed: int) -> dict:
    """Open-loop Poisson load: query i arrives at t_i (exponential gaps at
    `rate`/s) regardless of server progress; latency is measured from the
    scheduled arrival, so a backlog is charged to the server."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=len(srcs))
    arrivals = np.cumsum(gaps)
    loop = asyncio.get_running_loop()
    t0 = loop.time() + 0.05          # small lead so task 0 isn't already late

    async def one(i):
        at = t0 + arrivals[i]
        delay = at - loop.time()
        if delay > 0:
            await asyncio.sleep(delay)
        try:
            res = await svc.query("g", "sssp", src=int(srcs[i]))
        except ServiceOverloaded:
            return ("rejected", i, None, loop.time() - at)
        except ServiceTimeout:
            return ("timeout", i, None, loop.time() - at)
        return ("ok", i, res, loop.time() - at)

    st0 = svc.stats()       # counters are service-cumulative: diff per run
    outcomes = await asyncio.gather(*(one(i) for i in range(len(srcs))))
    end = loop.time()
    st1 = svc.stats()
    lat = np.array([o[3] for o in outcomes if o[0] == "ok"])
    served = [(o[1], o[2]) for o in outcomes if o[0] == "ok"]
    sweeps = st1["sweeps"] - st0["sweeps"]
    return {
        "offered_rate_qps": rate,
        "queries": len(srcs),
        "served": len(served),
        "rejected": sum(o[0] == "rejected" for o in outcomes),
        "timeouts": sum(o[0] == "timeout" for o in outcomes),
        "qps": round(len(served) / (end - t0), 2),
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 2),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 2),
        "max_ms": round(float(lat.max()) * 1e3, 2),
        "sweeps": sweeps,
        "mean_batch": round(len(served) / sweeps, 2) if sweeps else 0.0,
        "_served": served,    # stripped before JSON; oracle-checked by caller
    }


def verify(g, srcs, served, oracle_cache) -> int:
    """Assert every served distance row equals the reference oracle."""
    for i, res in served:
        s = int(srcs[i])
        if s not in oracle_cache:
            oracle_cache[s] = sssp_ref(g, s).astype(np.int32)
        assert np.array_equal(np.asarray(res), oracle_cache[s]), \
            f"served SSSP from {s} != oracle"
    return len(served)


async def bench(args, g, rates, n_queries, width, results):
    rng = np.random.default_rng(0)
    pool = rng.integers(0, g.num_nodes,
                        size=args.unique_sources).astype(np.int32)
    srcs = pool[rng.integers(0, len(pool), size=n_queries)]
    oracle_cache: dict = {}
    checked = 0

    for mode, coalesce in (("coalesced", True), ("per_query", False)):
        svc = make_service(g, coalesce=coalesce, width=width,
                           max_wait_ms=args.max_wait_ms)
        async with svc:
            await warmup(svc, width if coalesce else 1)
            for rate in rates:
                run = await run_load(svc, srcs, rate, seed=42)
                checked += verify(g, srcs, run.pop("_served"), oracle_cache)
                results["runs"][f"{mode}@{rate}"] = run
                print(f"[{mode:>9} @ {rate:5g} q/s] served {run['served']:4d}"
                      f"  qps={run['qps']:8.1f}  p50={run['p50_ms']:8.1f}ms"
                      f"  p99={run['p99_ms']:8.1f}ms"
                      f"  sweeps={run['sweeps']:4d}"
                      f"  lane occupancy={run['mean_batch']:5.2f}")
    results["oracle"] = {"unique_sources": len(oracle_cache),
                        "results_verified": checked}
    print(f"oracle: all {checked} served results verified against sssp_ref "
          f"({len(oracle_cache)} unique sources)")

    top = rates[-1]
    co, pq = (results["runs"][f"{m}@{top}"] for m in ("coalesced",
                                                      "per_query"))
    results["headline"] = {
        "saturating_rate_qps": top,
        "coalesced_qps": co["qps"],
        "per_query_qps": pq["qps"],
        "qps_ratio": round(co["qps"] / pq["qps"], 2),
        "coalesced_p99_ms": co["p99_ms"],
        "deadline_ms": TIMEOUT_S * 1e3,
        "p99_under_deadline": co["p99_ms"] < TIMEOUT_S * 1e3
        and co["timeouts"] == 0,
    }
    h = results["headline"]
    print(f"headline @ {top} q/s: coalesced {h['coalesced_qps']} q/s vs "
          f"per-query {h['per_query_qps']} q/s -> {h['qps_ratio']}x; "
          f"coalesced p99 {h['coalesced_p99_ms']} ms < deadline "
          f"{h['deadline_ms']:.0f} ms: {h['p99_under_deadline']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized graph + load (no JSON emitted)")
    ap.add_argument("--max-wait-ms", type=float, default=10.0)
    ap.add_argument("--unique-sources", type=int, default=None,
                    help="distinct query sources (each oracle-checked once)")
    args = ap.parse_args()

    if args.tiny:
        g = preferential_attachment(800, m=6, seed=1)
        rates, n_queries, width = [50.0, 400.0], 48, 8
        args.unique_sources = args.unique_sources or 12
    else:
        g = preferential_attachment(12000, m=8, seed=1)
        rates, n_queries, width = [50.0, 200.0, 800.0], 320, 32
        args.unique_sources = args.unique_sources or 32

    stats = get_context(g).stats()
    print(f"graph: N={g.num_nodes} E={g.num_edges} deg_cv={stats['deg_cv']} "
          f"skew={stats['skew']} | width={width} "
          f"max_wait={args.max_wait_ms}ms queries={n_queries}")
    results = {
        "backend": jax.default_backend(),
        "config": {"tiny": args.tiny, "width": width,
                   "max_wait_ms": args.max_wait_ms, "rates": rates,
                   "queries": n_queries, "timeout_s": TIMEOUT_S,
                   "unique_sources": args.unique_sources},
        "graph": stats,
        "runs": {},
    }
    asyncio.run(bench(args, g, rates, n_queries, width, results))

    if not args.tiny:
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {os.path.normpath(OUT_PATH)}")


if __name__ == "__main__":
    main()
