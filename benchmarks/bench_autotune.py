"""Schedule autotuner benchmark: tuned vs default per graph family.

For each (graph family × program) pair, `repro.autotune.autotune` sweeps
candidate schedules derived from the graph's statistics (degree skew /
frontier probe — so the power-law and grid graphs explore *different*
candidate sets), then the winning schedule is re-measured head-to-head
against the default `Schedule()` with identical methodology. This is the
GraphIt claim reproduced end-to-end: the algorithm text never changes,
only the schedule, and the right schedule is graph-dependent.

    PYTHONPATH=src python benchmarks/bench_autotune.py [--tiny]

Emits BENCH_autotune.json next to the repo root (full run only).
Reported per pair: default_ms, tuned_ms, speedup, the chosen schedule,
and the tuner's own trial log; plus each family's GraphContext stats.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import timeit as _timeit_us  # noqa: E402  (shared methodology)

from repro.autotune import autotune, default_params, schedule_to_dict
from repro.core import Schedule, compile_bundled, get_context
from repro.graph import preferential_attachment
from repro.graph.generators import road

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_autotune.json")


def measure_ms(bound, params, reps):
    us, _ = _timeit_us(lambda: bound(**params), reps=reps)
    return us / 1e3


def bench_pair(fam_name, g, prog_name, results, *, backend="local",
               budget=12, reps=3):
    default = compile_bundled(prog_name, backend=backend,
                              schedule=Schedule())
    res = autotune(default, g, budget=budget, seed=0, reps=reps)
    params = default_params(default, g, seed=0)

    # head-to-head re-measure (identical methodology for both sides, after
    # the sweep, so trial ordering can't bias the headline numbers)
    d_ms = measure_ms(default.bind(g), params, reps)
    t_ms = measure_ms(res.program.bind(g), params, reps)

    key = f"{fam_name}_{prog_name}"
    results[key] = dict(
        family=fam_name, program=prog_name, backend=backend,
        default_ms=round(d_ms, 3), tuned_ms=round(t_ms, 3),
        speedup=round(d_ms / t_ms, 3),
        tuned_schedule=schedule_to_dict(res.schedule),
        sweep=dict(budget=budget, num_trials=len(res.record.trials),
                   best_ms=res.record.best_ms,
                   default_ms=res.record.default_ms,
                   trials=res.record.trials),
    )
    print(f"[{key}] default={d_ms:9.1f}ms  tuned={t_ms:9.1f}ms  "
          f"speedup={d_ms / t_ms:5.2f}x  ({res.schedule})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized graphs + budget (no JSON emitted)")
    ap.add_argument("--backend", default="local",
                    choices=["local", "pallas"])
    args = ap.parse_args()

    if args.tiny:
        fams = {"powerlaw": preferential_attachment(800, m=6, seed=1),
                "grid": road(28, seed=7)}
        budget, reps, progs = 4, 1, ["sssp"]
    else:
        fams = {"powerlaw": preferential_attachment(12000, m=8, seed=1),
                "grid": road(110, seed=7)}
        budget, reps, progs = 12, 3, ["sssp", "bc"]

    results = {"backend": jax.default_backend(),
               "config": {"tiny": args.tiny, "budget": budget, "reps": reps,
                          "codegen_backend": args.backend},
               "families": {}}
    for name, g in fams.items():
        stats = get_context(g).stats()
        results["families"][name] = stats
        print(f"{name}: N={g.num_nodes} E={g.num_edges} "
              f"deg_cv={stats['deg_cv']} skew={stats['skew']} "
              f"probe_depth={stats['probe_depth']}")
    for name, g in fams.items():
        for prog in progs:
            bench_pair(name, g, prog, results, backend=args.backend,
                       budget=budget, reps=reps)

    wins = [k for k, v in results.items()
            if isinstance(v, dict) and v.get("speedup", 0) > 1.05]
    print(f"tuned wins (>1.05x): {wins or 'none'}")
    if not args.tiny:
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {os.path.normpath(OUT_PATH)}")


if __name__ == "__main__":
    main()
