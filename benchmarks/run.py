"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (paper Tables 3/4/5/6 + Fig. 17)."""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="table3|table4|table5|table6|fig17")
    ap.add_argument("--quick", action="store_true",
                    help="small graph subset (CI-speed)")
    args = ap.parse_args()

    from .common import header
    from . import (bench_fig17_scaling, bench_table3_openmp,
                   bench_table4_scheduling, bench_table5_mpi,
                   bench_table6_cuda)

    graphs = None
    if args.quick:
        from repro.graph import load_suite
        graphs = load_suite(["PK", "US", "UR"])

    header()
    tables = {
        "table3": lambda: bench_table3_openmp.run(graphs),
        "table4": lambda: bench_table4_scheduling.run(graphs),
        "table5": lambda: bench_table5_mpi.run(graphs),
        "table6": lambda: bench_table6_cuda.run(graphs),
        "fig17": lambda: bench_fig17_scaling.run(graphs),
    }
    for name, fn in tables.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        try:
            fn()
        except Exception as e:  # keep the harness going; report the failure
            print(f"{name}/HARNESS_ERROR,,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
