"""Effect-analysis-driven exchange planning: measured volume win.

The distributed codegen consumes the analyzer's effect sets to classify
each BSP loop's read properties: read-AND-written properties are the real
per-superstep exchange set, while read-but-never-written properties are
loop-invariant and are gathered exactly once before the loop. This
benchmark measures what that hoist is worth on the 8-shard distributed
backend by running the SAME workloads twice — once with the hoist
(current codegen) and once with `codegen.distributed.HOIST_INVARIANT`
flipped off, which reproduces the previous exchange plan exactly — and
comparing the `_gather_elems` counters the generated programs accumulate
on device.

Workloads (12k-node power-law graph, 8 virtual host devices):

  * **bc** — the headline win. The reverse (dependency-accumulation) pass
    reads `sigma` but only writes `delta`/`BC`, so `sigma`'s full view is
    invariant across the reverse supersteps: per source, one gather
    replaces depth-many. The forward pass writes `sigma` and keeps its
    per-superstep exchange — the win is surgical, not a blanket skip.
  * **cc** — the honest control. Its fixedPoint reads exactly the
    properties it writes (`comp`, `modified`), the invariant set is empty,
    and the volumes must come out IDENTICAL. A nonzero delta here would
    mean the hoist misclassified something.

Outputs are also cross-checked for equality between the two plans (the
hoist is a pure communication-plan change).

    PYTHONPATH=src python benchmarks/bench_analysis.py [--tiny]

Emits BENCH_analysis.json next to the repo root (full run only).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# 8 virtual devices — must precede the first jax import
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402
import numpy as np  # noqa: E402

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import timeit as _timeit_us  # noqa: E402

from repro.core import Schedule, compile_bundled, dist  # noqa: E402
from repro.core.api import bind_cache_clear, compile_cache_clear  # noqa: E402
from repro.core.codegen import distributed as distmod  # noqa: E402
from repro.graph import preferential_attachment  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_analysis.json")
P = 8
POLICIES = ("dense", "auto")


def _run(name, g, mesh, sched, params, hoist, reps):
    """Compile+run one workload under one exchange plan; returns the
    device gather counter, wall time, and the comparable outputs."""
    distmod.HOIST_INVARIANT = hoist
    # the plan is not part of the compile-cache key (it is an ablation
    # flag, not a Schedule knob) — clear so both plans really codegen
    compile_cache_clear()
    bind_cache_clear()
    try:
        bound = compile_bundled(name, backend="distributed",
                                schedule=sched).bind(g, mesh=mesh)
        us, out = _timeit_us(lambda: bound(**params), reps=reps)
    finally:
        distmod.HOIST_INVARIANT = True
        compile_cache_clear()
        bind_cache_clear()
    return {"wall_ms": round(us / 1e3, 3),
            "gather_elems": int(out["_gather_elems"]),
            "out": {k: np.asarray(v) for k, v in out.items()
                    if k != "_gather_elems"}}


def bench_workload(name, g, mesh, params, reps, results):
    entry = {}
    for policy in POLICIES:
        sched = Schedule(dist_frontier=policy)
        hoisted = _run(name, g, mesh, sched, params, True, reps)
        baseline = _run(name, g, mesh, sched, params, False, reps)
        for k, v in hoisted["out"].items():
            assert np.allclose(v, baseline["out"][k], atol=1e-3), (
                f"{name}/{policy}: outputs diverge on {k!r} — the hoist "
                "must be a pure communication-plan change")
        he, be = hoisted["gather_elems"], baseline["gather_elems"]
        entry[policy] = {
            "gather_elems_hoisted": he,
            "gather_elems_baseline": be,
            "volume_ratio": round(he / max(be, 1), 4),
            "wall_ms_hoisted": hoisted["wall_ms"],
            "wall_ms_baseline": baseline["wall_ms"],
        }
        print(f"[{name}] {policy:6s} elems {be} -> {he}"
              f"  (x{he / max(be, 1):.3f})"
              f"  wall {baseline['wall_ms']:.1f} -> "
              f"{hoisted['wall_ms']:.1f} ms")
    results["workloads"][name] = entry
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized graph + reps (no JSON emitted)")
    args = ap.parse_args()
    assert len(jax.devices()) >= P, "expected 8 forced host devices"
    mesh = dist.make_mesh_1d(P)

    n = 800 if args.tiny else 12000
    g = preferential_attachment(n, m=8, seed=1)
    reps = 1 if args.tiny else 3
    srcs = np.arange(4, dtype=np.int32)

    results = {"backend": jax.default_backend(), "num_shards": P,
               "graph": {"num_nodes": g.num_nodes, "num_edges": g.num_edges},
               "config": {"tiny": args.tiny, "reps": reps,
                          "bc_sources": int(srcs.size)},
               "note": ("gather_elems = property-exchange elements the "
                        "generated program's collectives moved, from the "
                        "on-device counter. baseline = invariant-gather "
                        "hoist disabled (the pre-analysis exchange plan); "
                        "outputs are asserted equal between plans."),
               "workloads": {}}

    bc = bench_workload("bc", g, mesh, {"sourceSet": srcs}, reps, results)
    cc = bench_workload("cc", g, mesh, {}, reps, results)

    # bc's reverse pass must show a real reduction; cc's invariant set is
    # empty so its plan — and volume — must be bit-identical
    for policy in POLICIES:
        assert bc[policy]["volume_ratio"] < 1.0, (
            f"bc/{policy}: expected an exchange-volume win from hoisting "
            f"sigma out of the reverse pass, got {bc[policy]}")
        assert cc[policy]["gather_elems_hoisted"] \
            == cc[policy]["gather_elems_baseline"], (
            f"cc/{policy}: volumes must be identical (empty invariant "
            f"set), got {cc[policy]}")
    print(f"bc volume ratio (hoisted/baseline): "
          f"dense {bc['dense']['volume_ratio']}, "
          f"auto {bc['auto']['volume_ratio']}; cc unchanged (control)")

    if not args.tiny:
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {os.path.normpath(OUT_PATH)}")


if __name__ == "__main__":
    main()
