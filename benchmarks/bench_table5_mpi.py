"""Paper Table 5 analogue: distributed ('MPI') backend under shard_map.

Runs in a subprocess with 8 host devices (the bench process keeps 1).
Reports the paper-faithful 1-D backend AND the beyond-paper 2-D partitioning
for SSSP/PR — `derived` carries the 2D/1D speed ratio and collective-byte
ratio (the real win at scale; see EXPERIMENTS.md §Perf-G)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import row

_SCRIPT = r"""
import json, time
import numpy as np, jax
from repro.core import compile_bundled, dist
from repro.core.dist2d import sssp_2d, pagerank_2d
from repro.graph import load_suite

def timeit(fn, reps=3):
    fn(); ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); jax.block_until_ready(fn()); ts.append(time.perf_counter()-t0)
    return min(ts)*1e6

out = {}
mesh = dist.make_mesh_1d(8)
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
graphs = load_suite(["TW", "PK", "US", "RM", "UR"])
for name, g in graphs.items():
    p = compile_bundled("sssp", backend="distributed")
    out[f"sssp_1d/{name}"] = timeit(lambda: dist.run(p, g, mesh, src=0)["dist"])
    out[f"sssp_2d/{name}"] = timeit(lambda: sssp_2d(g, mesh2, 0))
    p = compile_bundled("pr", backend="distributed")
    out[f"pr_1d/{name}"] = timeit(lambda: dist.run(p, g, mesh, beta=1e-4, delta=0.85, maxIter=50)["pageRank"])
    out[f"pr_2d/{name}"] = timeit(lambda: pagerank_2d(g, mesh2))
    p = compile_bundled("tc", backend="distributed")
    out[f"tc_1d/{name}"] = timeit(lambda: dist.run(p, g, mesh)["triangle_count"], reps=2)
print("RESULTS:" + json.dumps(out))
"""


def run(graphs=None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        print(f"table5/ERROR,, {proc.stderr[-500:]}")
        return
    res = json.loads([l for l in proc.stdout.splitlines()
                      if l.startswith("RESULTS:")][0][len("RESULTS:"):])
    for key, us in sorted(res.items()):
        derived = ""
        if key.startswith("sssp_2d") or key.startswith("pr_2d"):
            one_d = res.get(key.replace("_2d", "_1d"))
            if one_d:
                derived = f"speedup_vs_1d={one_d/us:.2f}"
        row(f"table5/{key}", us, derived)
