"""Paper Table 6 analogue: the many-core ('CUDA'→Pallas) backend.

On this CPU host the Pallas kernels execute in interpret mode (correctness,
not speed), so wall-clock kernel timing is meaningless; instead this table
reports per-kernel ROOFLINE-MODELED v5e time derived from exact per-call
FLOPs/bytes (the same accounting as §Roofline), plus measured wall time of
the whole DSL pallas-backend program under XLA:CPU as an end-to-end sanity
check against the local backend (paper's generated-vs-library structure)."""
from __future__ import annotations

import numpy as np

from repro.core import compile_bundled
from repro.graph.csr import to_ell
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

from .common import row, suite, timeit


def _kernel_model_us(g, kind):
    """Roofline-modeled per-sweep time on one v5e chip."""
    ell = to_ell(g, reverse=True)
    n, d = ell.cols.shape
    if kind in ("relax", "gather"):
        flops = 2.0 * n * d                      # add+min (or mul+add) per slot
        byts = (n * d * 8                        # cols + vals tiles (int32)
                + n * 4 * 2 + (n + 1) * 4)       # x gathered + y out
        return max(flops / PEAK_FLOPS, byts / HBM_BW) * 1e6
    if kind == "tc":
        nb = -(-g.num_nodes // 128) * 128
        flops = 2.0 * nb ** 3 + nb * nb          # A·A + mask-reduce
        byts = 3 * nb * nb * 4 * (nb // 128)
        return max(flops / PEAK_FLOPS, byts / HBM_BW) * 1e6
    raise ValueError(kind)


def run(graphs=None):
    graphs = graphs or suite()
    for gname, g in graphs.items():
        # end-to-end generated pallas-backend program (interpret kernels)
        prog_p = compile_bundled("sssp", backend="pallas")
        prog_l = compile_bundled("sssp", backend="local")
        us_p, out_p = timeit(lambda: prog_p(g, src=0), reps=2)
        us_l, out_l = timeit(lambda: prog_l(g, src=0), reps=2)
        assert np.array_equal(np.asarray(out_p["dist"]), np.asarray(out_l["dist"]))
        row(f"table6/sssp_pallas_e2e/{gname}", us_p,
            f"modeled_v5e_per_sweep_us={_kernel_model_us(g, 'relax'):.1f}")
        row(f"table6/pr_gather_model/{gname}", _kernel_model_us(g, "gather"),
            "roofline-modeled v5e per sweep")
        if g.num_nodes <= 4096:
            row(f"table6/tc_mxu_model/{gname}", _kernel_model_us(g, "tc"),
                "roofline-modeled v5e dense MXU count")
