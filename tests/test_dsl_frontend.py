"""DSL frontend: lexer, parser, AST shape, semantic analysis, IR lowering."""
import pytest

from repro.core import ast_nodes as A
from repro.core import ir as I
from repro.core.api import load_program_source
from repro.core.lexer import LexError, tokenize
from repro.core.lowering import LowerError, lower
from repro.core.parser import ParseError, parse
from repro.core.semantic import SemanticError, analyze

ALL_PROGRAMS = ["sssp", "sssp_pull", "pr", "tc", "bc"]


def test_lexer_basic():
    toks = tokenize("forall(v in g.nodes()) { v.dist = 0; }")
    kinds = [t.kind for t in toks]
    assert kinds[0] == "kw" and toks[0].value == "forall"
    assert toks[-1].kind == "eof"


def test_lexer_operators():
    toks = tokenize("a += b; c &&= d; e ++; <f, g>")
    vals = [t.value for t in toks if t.kind == "sym"]
    assert "+=" in vals and "&&=" in vals and "++" in vals


def test_lexer_comments():
    toks = tokenize("// comment\n/* block\ncomment */ x")
    assert [t.value for t in toks if t.kind == "id"] == ["x"]


def test_lexer_error():
    with pytest.raises(LexError):
        tokenize("a $ b")


@pytest.mark.parametrize("name", ALL_PROGRAMS)
def test_paper_programs_parse(name):
    prog = parse(load_program_source(name))
    assert len(prog.functions) == 1
    fn = prog.functions[0]
    assert fn.params[0].ty.name == "Graph"


def test_sssp_ast_structure():
    prog = parse(load_program_source("sssp"))
    fn = prog.functions[0]
    fp = [s for s in fn.body.stmts if isinstance(s, A.FixedPointStmt)]
    assert len(fp) == 1 and fp[0].var == "finished"
    outer = fp[0].body.stmts[0]
    assert isinstance(outer, A.ForallStmt) and outer.parallel
    assert isinstance(outer.filter_expr, A.BinaryOp)
    inner = outer.body.stmts[0]
    assert isinstance(inner, A.ForallStmt)
    multi = inner.body.stmts[-1]
    assert isinstance(multi, A.MultiAssignmentStmt)
    assert isinstance(multi.values[0], A.MinMaxExpr)


def test_bc_bfs_reverse_attached():
    prog = parse(load_program_source("bc"))
    fn = prog.functions[0]
    setloop = [s for s in fn.body.stmts if isinstance(s, A.ForallStmt)][0]
    bfs = [s for s in setloop.body.stmts if isinstance(s, A.IterateInBFSStmt)]
    assert len(bfs) == 1 and bfs[0].reverse is not None


def test_parse_error_missing_semicolon():
    with pytest.raises(ParseError):
        parse("function f(Graph g) { int x = 1 }")


def test_semantic_undefined_variable():
    with pytest.raises(SemanticError):
        analyze(parse("function f(Graph g) { x = 1; }"))


def test_semantic_requires_graph():
    with pytest.raises(SemanticError):
        analyze(parse("function f(int x) { int y = x; }"))


@pytest.mark.parametrize("name", ALL_PROGRAMS)
def test_paper_programs_lower(name):
    irs = lower(parse(load_program_source(name)))
    assert len(irs) == 1
    irf = irs[0]
    assert irf.graph_param == "g"


def test_sssp_ir_canonical():
    irf = lower(parse(load_program_source("sssp")))[0]
    fps = [s for s in irf.body if isinstance(s, I.IFixedPoint)]
    assert len(fps) == 1 and fps[0].conv_prop == "modified"
    vloop = fps[0].body[0]
    assert isinstance(vloop, I.IVertexLoop)
    nloop = vloop.body[0]
    assert isinstance(nloop, I.INbrLoop) and nloop.direction == "out"
    mm = nloop.body[0]
    assert isinstance(mm, I.IMinMaxUpdate)
    assert mm.prop == "dist" and mm.target == "nbr" and mm.kind == "Min"
    assert mm.extras[0][0] == "modified"


def test_reduction_folding():
    """`x = x + t` folds to a reduce-assign (paper Fig. 5)."""
    src = """function f(Graph g, propNode<float> A) {
        float acc = 0;
        forall(v in g.nodes()) { acc = acc + v.A; }
    }"""
    irf = lower(parse(src))[0]
    vloop = [s for s in irf.body if isinstance(s, I.IVertexLoop)][0]
    asg = vloop.body[0]
    assert isinstance(asg, I.IAssign) and asg.reduce_op == "+"


def test_fixed_point_requires_bool_prop():
    src = """function f(Graph g) {
        bool finished = False;
        fixedPoint until (finished : !finished) { }
    }"""
    with pytest.raises((LowerError, SemanticError)):
        lower(parse(src))


def test_written_and_read_analysis():
    irf = lower(parse(load_program_source("sssp")))[0]
    assert {"dist", "modified"} <= I.written_vars(irf.body)
    assert {"dist", "modified"} <= I.read_props(irf.body)
