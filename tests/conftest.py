import os
import sys

# The whole suite runs under 8 forced host devices so the distributed
# backend's shard matrix (tests/test_dist_agree.py, test_distributed.py)
# executes in-process — real collectives over a real multi-device mesh,
# not a subprocess bottleneck. This must happen before jax initializes
# its backends, i.e. before the repro imports below.
_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"{os.environ.get('XLA_FLAGS', '')} {_FLAG}".strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

from repro.graph import road, small_world, uniform_random


@pytest.fixture(scope="session")
def eight_devices():
    """Assert the forced 8-device host platform actually took effect (it
    fails if jax was initialized before this conftest ran)."""
    import jax
    devs = jax.devices()
    assert len(devs) >= 8, (
        f"expected >= 8 forced host devices, found {len(devs)}; was jax "
        "imported before conftest set XLA_FLAGS?")
    return devs


@pytest.fixture(scope="session")
def g_small():
    return uniform_random(64, 4, seed=0)


@pytest.fixture(scope="session")
def g_medium():
    return uniform_random(100, 5, seed=2)


@pytest.fixture(scope="session")
def g_road():
    return road(10, seed=3)


@pytest.fixture(scope="session")
def g_social():
    return small_world(96, 8, 0.2, seed=4)


@pytest.fixture(scope="session")
def graph_suite(g_medium, g_road, g_social):
    return {"UR": g_medium, "RD": g_road, "SW": g_social}
