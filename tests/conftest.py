import os
import sys

# tests see ONE device (the dry-run pins 512 in its own process only)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

from repro.graph import road, small_world, uniform_random


@pytest.fixture(scope="session")
def g_small():
    return uniform_random(64, 4, seed=0)


@pytest.fixture(scope="session")
def g_medium():
    return uniform_random(100, 5, seed=2)


@pytest.fixture(scope="session")
def g_road():
    return road(10, seed=3)


@pytest.fixture(scope="session")
def g_social():
    return small_world(96, 8, 0.2, seed=4)


@pytest.fixture(scope="session")
def graph_suite(g_medium, g_road, g_social):
    return {"UR": g_medium, "RD": g_road, "SW": g_social}
