"""Cross-backend agreement: the same DSL source must produce identical
results on local / pallas backends (distributed runs in its own process —
see test_distributed.py)."""
import numpy as np
import pytest

from repro.core import compile_bundled


@pytest.mark.parametrize("name,params", [
    ("sssp", dict(src=0)),
    ("sssp_pull", dict(src=0)),
    ("pr", dict(beta=1e-4, delta=0.85, maxIter=60)),
    ("tc", dict()),
])
@pytest.mark.parametrize("gname", ["UR", "SW"])
def test_local_vs_pallas(name, params, gname, graph_suite):
    g = graph_suite[gname]
    out_l = compile_bundled(name, backend="local")(g, **params)
    out_p = compile_bundled(name, backend="pallas")(g, **params)
    for key in out_l:
        a, b = np.asarray(out_l[key]), np.asarray(out_p[key])
        if a.dtype.kind == "f":
            np.testing.assert_allclose(a, b, atol=1e-5, err_msg=f"{name}.{key}")
        else:
            assert np.array_equal(a, b), f"{name}.{key}"


def test_bc_local_vs_pallas(graph_suite):
    g = graph_suite["UR"]
    srcs = np.array([0, 7], np.int32)
    out_l = compile_bundled("bc", backend="local")(g, sourceSet=srcs)
    out_p = compile_bundled("bc", backend="pallas")(g, sourceSet=srcs)
    np.testing.assert_allclose(np.asarray(out_l["BC"]),
                               np.asarray(out_p["BC"]), atol=1e-4)


def test_backend_sources_differ():
    l = compile_bundled("sssp", backend="local").source
    p = compile_bundled("sssp", backend="pallas").source
    assert "kops.relax_minplus" in p and "kops" not in l
