"""Cross-backend agreement: the same DSL source must produce identical
results on local / pallas backends (distributed runs in its own process —
see test_distributed.py)."""
import numpy as np
import pytest

from repro.core import Schedule, compile_bundled


@pytest.mark.parametrize("name,params", [
    ("sssp", dict(src=0)),
    ("sssp_pull", dict(src=0)),
    ("pr", dict(beta=1e-4, delta=0.85, maxIter=60)),
    ("tc", dict()),
    ("lp", dict()),
    ("kcore", dict(k=2)),
    ("ppr", dict(beta=1e-4, delta=0.85, maxIter=60,
                 sourceSet=np.array([0, 7, 23], np.int32))),
])
@pytest.mark.parametrize("gname", ["UR", "SW"])
def test_local_vs_pallas(name, params, gname, graph_suite):
    g = graph_suite[gname]
    out_l = compile_bundled(name, backend="local")(g, **params)
    out_p = compile_bundled(name, backend="pallas")(g, **params)
    for key in out_l:
        a, b = np.asarray(out_l[key]), np.asarray(out_p[key])
        if a.dtype.kind == "f":
            np.testing.assert_allclose(a, b, atol=1e-5, err_msg=f"{name}.{key}")
        else:
            assert np.array_equal(a, b), f"{name}.{key}"


def test_bc_local_vs_pallas(graph_suite):
    g = graph_suite["UR"]
    srcs = np.array([0, 7], np.int32)
    out_l = compile_bundled("bc", backend="local")(g, sourceSet=srcs)
    out_p = compile_bundled("bc", backend="pallas")(g, sourceSet=srcs)
    np.testing.assert_allclose(np.asarray(out_l["BC"]),
                               np.asarray(out_p["BC"]), atol=1e-4)


def test_backend_sources_differ():
    l = compile_bundled("sssp", backend="local").source
    p = compile_bundled("sssp", backend="pallas").source
    assert "kops.relax_minplus" in p and "kops" not in l


# --- frontier-aware engine: power-law / edge-case coverage -------------------
# The degree-bucketed sliced-ELL layout and the push/pull direction switch
# only exercise their interesting paths on skewed graphs (multiple buckets,
# hub fallback) and degenerate frontiers; the suite graphs above are too
# uniform for that.

@pytest.fixture(scope="module")
def g_powerlaw():
    from repro.graph import preferential_attachment
    return preferential_attachment(600, m=6, seed=11)


@pytest.mark.parametrize("name,params", [
    ("sssp", dict(src=0)),
    ("sssp_pull", dict(src=0)),
    ("pr", dict(beta=1e-4, delta=0.85, maxIter=60)),
])
def test_powerlaw_local_vs_pallas(name, params, g_powerlaw):
    g = g_powerlaw
    # the generator must actually produce a bucketed view with a hub tail
    from repro.graph import to_sliced_ell
    ell = to_sliced_ell(g, reverse=True)
    assert len(ell.cols) >= 2, "power-law graph should span several buckets"
    out_l = compile_bundled(name, backend="local")(g, **params)
    out_p = compile_bundled(name, backend="pallas")(g, **params)
    for key in out_l:
        a, b = np.asarray(out_l[key]), np.asarray(out_p[key])
        if a.dtype.kind == "f":
            np.testing.assert_allclose(a, b, atol=1e-5, err_msg=f"{name}.{key}")
        else:
            assert np.array_equal(a, b), f"{name}.{key}"


def test_powerlaw_sssp_vs_oracle(g_powerlaw):
    from repro.graph.algorithms_ref import sssp_ref
    out = compile_bundled("sssp", backend="pallas")(g_powerlaw, src=0)
    assert np.array_equal(np.asarray(out["dist"]),
                          sssp_ref(g_powerlaw, 0).astype(np.int32))


def test_empty_frontier_isolated_source():
    """Source with no out-edges: the frontier empties after one step and the
    push branch (always selected at occupancy 1) must be a clean no-op."""
    from repro.graph import from_edges
    g = from_edges(8, np.array([1, 2, 3]), np.array([2, 3, 4]),
                   np.array([5, 5, 5]))
    for backend in ["local", "pallas"]:
        out = compile_bundled("sssp", backend=backend)(g, src=7)
        dist = np.asarray(out["dist"])
        assert dist[7] == 0 and (dist[:7] >= 2**30).all(), backend
        assert bool(out["finished"])


# --- batched multi-source engine: batched vs sequential agreement ------------
# ENGINE.batch_sources turns `forall(src in sourceSet)` into chunked [B, N]
# batched passes; these pin the batched lowering to the per-source fori_loop
# (batch_sources=1) on both backends, including partial final chunks,
# power-law graphs, and disconnected components.

@pytest.fixture(scope="module")
def g_disconnected():
    from repro.graph import from_edges
    src = np.array([0, 1, 2, 8, 9, 10])
    dst = np.array([1, 2, 3, 9, 10, 11])
    return from_edges(16, src, dst, np.ones(6, np.int64), undirected=True)


@pytest.mark.parametrize("backend", ["local", "pallas"])
@pytest.mark.parametrize("gfix", ["powerlaw", "disconnected"])
def test_bc_batched_vs_sequential(backend, gfix, g_powerlaw, g_disconnected):
    g = g_powerlaw if gfix == "powerlaw" else g_disconnected
    # more sources than one chunk of the default B=4 → exercises padding too
    srcs = np.arange(0, g.num_nodes, max(g.num_nodes // 9, 1), np.int32)
    seq = compile_bundled("bc", backend=backend, batch_sources=1)
    bat = compile_bundled("bc", backend=backend, batch_sources=4)
    assert "rt.bfs_levels_batch" in bat.source and "rt.bfs_levels_batch" not in seq.source
    out_s = seq(g, sourceSet=srcs)
    out_b = bat(g, sourceSet=srcs)
    np.testing.assert_allclose(np.asarray(out_b["BC"]), np.asarray(out_s["BC"]),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("gfix", ["powerlaw", "disconnected"])
def test_sssp_batched_columns_match_per_source(gfix, g_powerlaw, g_disconnected):
    """rt.sssp_multi answers B queries per sweep; every column must equal the
    single-source engine's run for that source."""
    from repro.core import runtime as rt
    g = g_powerlaw if gfix == "powerlaw" else g_disconnected
    srcs = np.arange(0, g.num_nodes, max(g.num_nodes // 7, 1), np.int32)
    dist = np.asarray(rt.sssp_multi(g, srcs))
    for i, s in enumerate(srcs):
        out = compile_bundled("sssp", backend="local")(g, src=int(s))
        assert np.array_equal(dist[i], np.asarray(out["dist"])), f"src {s}"


# --- beyond-paper programs (ppr / lp / kcore) vs their oracles ---------------
# ppr exercises the batched per-source do-while (lane scalars + frozen
# converged lanes); lp the two-sided Min relax; kcore the host-level while
# around a filtered peel.

@pytest.mark.parametrize("backend", ["local", "pallas"])
@pytest.mark.parametrize("gname", ["UR", "SW"])
def test_ppr_vs_oracle(backend, gname, graph_suite):
    from repro.graph.algorithms_ref import ppr_ref
    g = graph_suite[gname]
    srcs = np.array([0, 7, 23], np.int32)
    out = compile_bundled("ppr", backend=backend)(
        g, beta=1e-4, delta=0.85, maxIter=60, sourceSet=srcs)
    np.testing.assert_allclose(
        np.asarray(out["ppr"]), ppr_ref(g, srcs, max_iter=60),
        rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", ["local", "pallas"])
def test_ppr_batched_vs_sequential(backend, graph_suite):
    """The [B, N]-lane do-while (converged lanes frozen mid-batch) must
    reproduce the per-source sequential loop exactly, partial final chunk
    included (5 sources over B=4)."""
    g = graph_suite["UR"]
    srcs = np.array([3, 11, 0, 42, 77], np.int32)
    params = dict(beta=1e-4, delta=0.85, maxIter=60, sourceSet=srcs)
    seq = compile_bundled("ppr", backend=backend, batch_sources=1)
    bat = compile_bundled("ppr", backend=backend, batch_sources=4)
    assert "while_loop" in bat.source
    np.testing.assert_allclose(np.asarray(bat(g, **params)["ppr"]),
                               np.asarray(seq(g, **params)["ppr"]),
                               rtol=1e-4, atol=1e-5)


def test_ppr_multi_rows_match_singleton_sets(graph_suite):
    """PPR is linear in the restart vector: rt.ppr_multi's row b must equal
    the compiled program's aggregate over the singleton set {sources[b]}
    (the contract the serving layer's single-query path relies on)."""
    from repro.core import runtime as rt
    g = graph_suite["SW"]
    srcs = np.array([2, 9, 31], np.int32)
    rows = np.asarray(rt.ppr_multi(g, srcs))
    prog = compile_bundled("ppr", backend="local")
    for i, s in enumerate(srcs):
        out = prog(g, beta=1e-4, delta=0.85, maxIter=100,
                   sourceSet=np.array([s], np.int32))
        np.testing.assert_allclose(rows[i], np.asarray(out["ppr"]),
                                   rtol=1e-4, atol=1e-5, err_msg=f"src {s}")


@pytest.mark.parametrize("backend", ["local", "pallas"])
def test_lp_vs_oracle(backend, g_powerlaw):
    from repro.graph.algorithms_ref import label_propagation_ref
    out = compile_bundled("lp", backend=backend)(g_powerlaw)
    assert np.array_equal(np.asarray(out["label"]),
                          label_propagation_ref(g_powerlaw))


def test_lp_under_delta_schedule(graph_suite):
    """lp's unweighted Min relax is delta-steppable (like cc): same fixed
    point under the priority schedule."""
    g = graph_suite["UR"]
    base = compile_bundled("lp", backend="local")(g)
    sched = Schedule(priority="delta", delta_bucket=8)
    out = compile_bundled("lp", backend="local", schedule=sched)(g)
    assert np.array_equal(np.asarray(out["label"]), np.asarray(base["label"]))


@pytest.mark.parametrize("backend", ["local", "pallas"])
@pytest.mark.parametrize("k", [1, 2, 3])
def test_kcore_vs_oracle(backend, k, graph_suite):
    # k=2 leaves a nontrivial survivor set on UR; k=3 cascades to empty
    # (0-out-degree vertices peel their in-neighbors); k=1 peels only sinks
    from repro.graph.algorithms_ref import kcore_ref
    g = graph_suite["UR"]
    out = compile_bundled("kcore", backend=backend)(g, k=k)
    assert np.array_equal(np.asarray(out["core"]), kcore_ref(g, k)), k


# --- delta-stepping priority schedule ----------------------------------------
# priority="delta" reorders the relaxation (bucket by bucket) but must reach
# the same fixed point as the monotonic lowering on every backend, under
# every direction policy, for any bucket width — including Δ=1 (near-Dijkstra,
# maximal bucket count) and Δ larger than any distance (degenerates to the
# monotonic sweep).

@pytest.fixture(scope="module")
def g_grid():
    from repro.graph.generators import road
    return road(24, seed=7)


@pytest.fixture(scope="module")
def grid_sssp_ref(g_grid):
    from repro.graph.algorithms_ref import sssp_ref
    return sssp_ref(g_grid, 0).astype(np.int32)


@pytest.mark.parametrize("backend", ["local", "pallas"])
@pytest.mark.parametrize("direction", ["auto", "push", "pull"])
@pytest.mark.parametrize("delta", [1, 64, 100000])
def test_sssp_delta_matches_oracle(backend, direction, delta, g_grid,
                                   grid_sssp_ref):
    sched = Schedule(priority="delta", delta_bucket=delta, direction=direction)
    out = compile_bundled("sssp", backend=backend, schedule=sched)(g_grid,
                                                                   src=0)
    assert np.array_equal(np.asarray(out["dist"]), grid_sssp_ref)


@pytest.mark.parametrize("name", ["sssp", "sssp_pull", "cc"])
def test_delta_schedule_powerlaw_agrees_with_monotonic(name, g_powerlaw):
    """Power-law graph: the hub row can push the forward-ELL view past its
    blowup cap, taking the dense relax fallback — same fixed point. cc's
    unweighted Min relax goes through the same bucketed machinery."""
    params = dict(src=0) if name.startswith("sssp") else {}
    base = compile_bundled(name, backend="local")(g_powerlaw, **params)
    sched = Schedule(priority="delta", delta_bucket=120)
    out = compile_bundled(name, backend="local", schedule=sched)(
        g_powerlaw, **params)
    for key in base:
        assert np.array_equal(np.asarray(out[key]), np.asarray(base[key])), \
            f"{name}.{key}"


def test_bc_under_delta_schedule_rejected_at_compile_time():
    """bc has no monotone Min-relax fixedPoint, so priority="delta" is a
    static SP201 error — previously the delta lowering was silently skipped
    (batched lanes advance buckets independently); now the analysis gate
    rejects the unsound knob before any code is generated."""
    from repro.core.analysis import DiagnosticError
    sched = Schedule(priority="delta", delta_bucket=64, batch_sources=4)
    with pytest.raises(DiagnosticError) as ei:
        compile_bundled("bc", backend="local", schedule=sched)
    assert "SP201" in ei.value.codes


def test_delta_schedules_differ_in_source_only_by_knobs(g_grid):
    """Same algorithm, two bucket widths: byte-identical source except the
    baked Δ literal — the schedule-as-literal contract extends to priority."""
    a = compile_bundled("sssp", schedule=Schedule(priority="delta",
                                                  delta_bucket=41)).source
    b = compile_bundled("sssp", schedule=Schedule(priority="delta",
                                                  delta_bucket=73)).source
    assert a != b and a.replace("41", "73") == b
    mono = compile_bundled("sssp").source
    assert "_bk" in a and "_bk" not in mono


def test_single_hub_star_graph():
    """Star graph: the hub's in-row exceeds every bucket width and must be
    handled entirely by the COO hub fallback."""
    from repro.graph import ENGINE, from_edges
    n = ENGINE.min_width * ENGINE.growth ** (ENGINE.num_buckets - 1) + 64
    spokes = np.arange(1, n)
    g = from_edges(n, spokes, np.zeros(n - 1, np.int64),
                   np.ones(n - 1, np.int64), undirected=True)
    from repro.graph import to_sliced_ell
    ell = to_sliced_ell(g, reverse=True)
    assert ell.hub_rows.shape[0] == n - 1          # hub row in COO fallback
    out_l = compile_bundled("sssp", backend="local")(g, src=1)
    out_p = compile_bundled("sssp", backend="pallas")(g, src=1)
    assert np.array_equal(np.asarray(out_l["dist"]), np.asarray(out_p["dist"]))
    d = np.asarray(out_p["dist"])
    assert d[1] == 0 and d[0] == 1 and (d[2:] == 2).all()
