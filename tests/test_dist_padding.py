"""Padded-tail edge cases of the 1-D distributed partition.

The last block is padded (paper §4.2 "we pad temporary vertices for the
last process"); with small N whole shards own nothing but padding. These
tests pin that the compact/gather exchange paths never let padded slots
influence results: unit tests seed the padding with poison values and
assert it stays inert, and end-to-end runs cover N % P != 0, N < P, a
shard owning only padding, and isolated vertices — on both the dense and
the frontier-compressed exchange.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import Schedule, compile_bundled, dist, runtime_dist as rtd
from repro.graph import from_edges, uniform_random
from repro.graph.algorithms_ref import sssp_ref

POLICIES = ["dense", "compact", "auto"]


def _sssp_dist(g, shards, policy):
    prog = compile_bundled("sssp", backend="distributed",
                           schedule=Schedule(dist_frontier=policy))
    return np.asarray(
        prog.bind(g, mesh=dist.make_mesh_1d(shards))(src=0)["dist"])


@pytest.mark.parametrize("policy", POLICIES)
def test_n_not_divisible_by_shards(eight_devices, policy):
    g = uniform_random(101, 5, seed=2)            # 101 % 8 = 5
    assert np.array_equal(_sssp_dist(g, 8, policy),
                          sssp_ref(g, 0).astype(np.int32))


@pytest.mark.parametrize("policy", POLICIES)
def test_shards_owning_only_padding(eight_devices, policy):
    # N=9, P=8: block=2, shards 5..7 own nothing but padding
    g = uniform_random(9, 3, seed=5)
    assert np.array_equal(_sssp_dist(g, 8, policy),
                          sssp_ref(g, 0).astype(np.int32))


@pytest.mark.parametrize("policy", POLICIES)
def test_n_smaller_than_shard_count(eight_devices, policy):
    g = uniform_random(5, 2, seed=7)              # N=5 < P=8, block=1
    assert np.array_equal(_sssp_dist(g, 8, policy),
                          sssp_ref(g, 0).astype(np.int32))


@pytest.mark.parametrize("policy", POLICIES)
def test_isolated_vertices(eight_devices, policy):
    # vertices 7..9 have no edges at all; 0..6 form a weighted path
    src = np.arange(6)
    dst = np.arange(1, 7)
    w = np.arange(1, 7)
    g = from_edges(10, src, dst, w)
    out = _sssp_dist(g, 8, policy)
    ref = sssp_ref(g, 0).astype(np.int32)
    assert np.array_equal(out, ref)
    assert (out[7:] == ref[7:]).all() and (ref[7:] == ref[7]).all()  # all INF


# --------------------------------------------------------------------------
# poison: padding slots must pass through the exchange untouched
# --------------------------------------------------------------------------

POISON = np.int32(-777777)


def _run_exchange(full_prev, blk, own_ids, mesh, frac, skip_empty):
    def body(fp, b, o):
        return rtd.exchange(fp, b[0], o[0], frac, skip_empty=skip_empty)
    fn = jax.jit(rtd.shard_map(body, mesh=mesh,
                               in_specs=(P(), P("data"), P("data")),
                               out_specs=(P(), P())))
    return fn(full_prev, blk, own_ids)


@pytest.mark.parametrize("frac,skip", [(0.25, True), (0.25, False),
                                       (1.0, True)])
def test_exchange_never_reads_poisoned_padding(eight_devices, frac, skip):
    """Seed the padded tail (slots >= n_true) of both the carried full view
    and the owning blocks with poison. Initialized-but-never-written
    padding never differs between block and full view, so the compact
    selection must not transmit it: after an exchange that moves real
    changes, the true slots are exact and every poison slot is bit-equal
    untouched."""
    p, block, n_true = 8, 4, 27                   # n_pad=32, 5 poison slots
    n_pad = p * block
    own_ids = jnp.arange(n_pad, dtype=jnp.int32).reshape(p, block)
    rng = np.random.default_rng(3)
    full = rng.integers(0, 100, n_pad).astype(np.int32)
    full[n_true:] = POISON
    blk = full.reshape(p, block).copy()
    # real changes on three different shards (true slots only)
    blk[0, 1] = 41
    blk[3, 2] = 42
    blk[6, 1] = 43
    mesh = dist.make_mesh_1d(p)
    out, elems = _run_exchange(jnp.asarray(full), jnp.asarray(blk),
                               own_ids, mesh, frac, skip)
    out = np.asarray(out)
    assert np.array_equal(out[:n_true], blk.reshape(-1)[:n_true])
    assert (out[n_true:] == POISON).all(), "padding was rewritten"
    assert int(elems) > 0


def test_exchange_skips_when_nothing_changed(eight_devices):
    p, block = 8, 4
    n_pad = p * block
    own_ids = jnp.arange(n_pad, dtype=jnp.int32).reshape(p, block)
    full = jnp.asarray(np.full(n_pad, POISON, np.int32))
    blk = full.reshape(p, block)
    mesh = dist.make_mesh_1d(p)
    out, elems = _run_exchange(full, blk, own_ids, mesh, 0.25, True)
    assert int(elems) == 0
    assert np.array_equal(np.asarray(out), np.asarray(full))


def test_exchange_dense_fallback_on_overflow(eight_devices):
    """When a shard's change count overflows the compact buffer the
    exchange must fall back to the dense gather (correctness over
    volume) — and report the dense element count."""
    p, block = 8, 8
    n_pad = p * block
    own_ids = jnp.arange(n_pad, dtype=jnp.int32).reshape(p, block)
    full = jnp.zeros(n_pad, jnp.int32)
    blk = jnp.arange(1, n_pad + 1, dtype=jnp.int32).reshape(p, block)  # all change
    mesh = dist.make_mesh_1d(p)
    out, elems = _run_exchange(full, blk, own_ids, mesh, 0.25, True)
    assert int(elems) == n_pad
    assert np.array_equal(np.asarray(out), np.asarray(blk).reshape(-1))


@pytest.mark.parametrize("policy", POLICIES)
def test_batched_bc_on_padded_tail(eight_devices, policy):
    """Batched source lanes ([S, B] blocks) across a padded tail: BC over
    a source set on N=9 / P=8 agrees with the local backend."""
    from repro.graph.algorithms_ref import bc_ref
    g = uniform_random(9, 3, seed=5)
    srcs = np.array([0, 3, 7], np.int32)
    prog = compile_bundled("bc", backend="distributed",
                           schedule=Schedule(dist_frontier=policy))
    out = prog.bind(g, mesh=dist.make_mesh_1d(8))(sourceSet=srcs)["BC"]
    np.testing.assert_allclose(np.asarray(out), bc_ref(g, srcs.tolist()),
                               atol=1e-3)
