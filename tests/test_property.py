"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed; skipping "
                    "property-based tests (the rest of the suite still runs)")
from hypothesis import given, settings, strategies as st

from repro.core import Schedule, compile_bundled
from repro.graph import from_edges
from repro.graph.csr import INF_I32, to_ell
from repro.graph.partition import block_partition_1d, partition_2d


def graphs(max_n=24, max_e=80):
    @st.composite
    def _g(draw):
        n = draw(st.integers(2, max_n))
        e = draw(st.integers(1, max_e))
        src = draw(st.lists(st.integers(0, n - 1), min_size=e, max_size=e))
        dst = draw(st.lists(st.integers(0, n - 1), min_size=e, max_size=e))
        w = draw(st.lists(st.integers(1, 50), min_size=e, max_size=e))
        return from_edges(n, np.array(src), np.array(dst), np.array(w),
                          drop_self_loops=True)
    return _g()


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_csr_roundtrip(g):
    """CSR → COO → CSR preserves the edge set; degrees sum to E."""
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.indices)
    assert int(np.asarray(g.out_degree).sum()) == g.num_edges
    assert int(np.asarray(g.in_degree).sum()) == g.num_edges
    g2 = from_edges(g.num_nodes, src, dst, np.asarray(g.weights))
    assert np.array_equal(np.asarray(g2.indptr), np.asarray(g.indptr))
    assert np.array_equal(np.asarray(g2.indices), np.asarray(g.indices))


@settings(max_examples=20, deadline=None)
@given(graphs())
def test_partition_covers_all_edges(g):
    for p in (2, 3, 4):
        part = block_partition_1d(g, p)
        assert int(part.valid.sum()) == g.num_edges
    part2 = partition_2d(g, 2, 2)
    assert int(part2.valid.sum()) == g.num_edges


@settings(max_examples=15, deadline=None)
@given(graphs())
def test_sssp_triangle_inequality_and_fixpoint(g):
    """dist[v] ≤ dist[u] + w(u,v) for every edge, and dist is a fixed point."""
    prog = compile_bundled("sssp")
    out = prog(g, src=0)
    dist = np.asarray(out["dist"]).astype(np.int64)
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.indices)
    w = np.asarray(g.weights).astype(np.int64)
    reachable = dist[src] < INF_I32
    assert np.all(dist[dst][reachable] <= (dist[src] + w)[reachable])
    assert dist[0] == 0
    out2 = prog(g, src=0)   # idempotent
    assert np.array_equal(np.asarray(out2["dist"]), dist.astype(np.int32))


@settings(max_examples=10, deadline=None)
@given(graphs())
def test_pagerank_mass(g):
    """PR values positive; sum ≤ 1 + ε (dangling mass leaks, never grows)."""
    prog = compile_bundled("pr")
    pr = np.asarray(prog(g, beta=1e-5, delta=0.85, maxIter=100)["pageRank"])
    assert np.all(pr >= 0)
    assert pr.sum() <= 1.0 + 1e-3


@settings(max_examples=10, deadline=None)
@given(graphs(), st.randoms())
def test_tc_invariant_under_edge_permutation(g, rnd):
    """Triangle count is a graph invariant — edge insertion order must not
    matter (exercises CSR construction + dedup)."""
    prog = compile_bundled("tc")
    base = int(prog(g)["triangle_count"])
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.indices)
    w = np.asarray(g.weights)
    perm = np.array(rnd.sample(range(len(src)), len(src)), np.int64)
    g2 = from_edges(g.num_nodes, src[perm], dst[perm], w[perm])
    assert int(prog(g2)["triangle_count"]) == base


@settings(max_examples=15, deadline=None)
@given(graphs())
def test_bfs_levels_valid(g):
    """Every BFS tree edge spans exactly one level; unreached stay -1."""
    from repro.core.runtime import bfs_levels
    level, depth = bfs_levels(g, 0)
    level = np.asarray(level)
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.indices)
    on = (level[src] >= 0)
    assert np.all(level[dst][on] >= 0)                    # reachability closed
    assert np.all(level[dst][on] <= level[src][on] + 1)   # no level skipping
    assert level[0] == 0


def dist_schedules():
    """Valid Schedules spanning the distributed knob plane (plus the knobs
    the dist codegen shares with the other backends)."""
    return st.builds(
        Schedule,
        direction=st.sampled_from(["auto", "push", "pull"]),
        dist_frontier=st.sampled_from(["dense", "compact", "auto"]),
        dist_gather_frac=st.sampled_from([1 / 16, 0.25, 0.5, 1.0]),
        push_threshold_frac=st.sampled_from([0.0, 1 / 16, 1.0]),
        batch_sources=st.sampled_from([0, 2, 32]),
        priority=st.sampled_from(["none", "delta"]),
        delta_bucket=st.sampled_from([1, 7, 64, 500]),
    )


@settings(max_examples=10, deadline=None)
@given(graphs(max_n=16, max_e=40), dist_schedules(),
       st.sampled_from([2, 4, 8]))
def test_distributed_sssp_matches_oracle_under_any_schedule(g, sched, shards):
    """Random graph x random valid Schedule x shard count: the distributed
    result equals the NumPy oracle. Frontier-compressed and dense-gather
    supersteps exchange the same values by construction, so every point of
    the knob plane must agree exactly."""
    from repro.core import dist
    from repro.graph.algorithms_ref import sssp_ref
    prog = compile_bundled("sssp", backend="distributed", schedule=sched)
    out = prog.bind(g, mesh=dist.make_mesh_1d(shards))(src=0)
    assert np.array_equal(np.asarray(out["dist"]),
                          sssp_ref(g, 0).astype(np.int32)), sched


@settings(max_examples=6, deadline=None)
@given(graphs(max_n=14, max_e=30), dist_schedules())
def test_distributed_bc_matches_oracle_under_any_schedule(g, sched):
    """BC exercises the batched source lanes (batch_sources > 1) and the
    sequential fallback (0) over the BFS forward/reverse passes."""
    from repro.core import dist
    from repro.graph.algorithms_ref import bc_ref
    srcs = np.arange(min(3, g.num_nodes), dtype=np.int32)
    # bc has no monotone Min relax, so priority="delta" is now a
    # compile-time SP201 error (covered in test_backends_agree /
    # test_analysis); this test sweeps the remaining knob plane
    sched = sched.replace(priority="none")
    prog = compile_bundled("bc", backend="distributed", schedule=sched)
    out = prog.bind(g, mesh=dist.make_mesh_1d(4))(sourceSet=srcs)
    np.testing.assert_allclose(np.asarray(out["BC"]),
                               bc_ref(g, srcs.tolist()), atol=1e-3,
                               err_msg=repr(sched))


def _dijkstra(edges: dict, n: int, src: int) -> np.ndarray:
    """Oracle SSSP over a {(u, v): w} edge dict."""
    import heapq
    adj = {}
    for (u, v), w in edges.items():
        adj.setdefault(u, []).append((v, w))
    dist = np.full(n, int(INF_I32), np.int64)
    dist[src] = 0
    pq = [(0, src)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for v, w in adj.get(u, ()):
            nd = d + w
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    return dist


@settings(max_examples=6, deadline=None)
@given(graphs(max_n=20, max_e=60), st.data())
def test_service_interleaved_updates_match_oracle(g, data):
    """Random interleavings of write batches and queries against a
    GraphService graph, under random schedules: every query answer equals
    the oracle's from-scratch replay of the edge set at that instant
    (`g.update` semantics: dels first, adds replace, last write wins)."""
    import asyncio

    from repro.serve import GraphService, ServiceConfig

    n = g.num_nodes
    sched = data.draw(st.builds(
        Schedule,
        refresh_threshold_frac=st.sampled_from([0.0, 0.25, 1.0]),
        num_buckets=st.sampled_from([1, 4]),
        batch_sources=st.sampled_from([0, 2, 32]),
    ))
    vertex = st.integers(0, n - 1)
    ops = data.draw(st.lists(st.one_of(
        st.tuples(st.just("query"), vertex),
        st.tuples(st.just("update"),
                  st.lists(st.tuples(vertex, vertex, st.integers(1, 9)),
                           max_size=4),
                  st.lists(st.tuples(vertex, vertex), max_size=4)),
    ), min_size=1, max_size=6))

    edges = {(int(u), int(v)): int(w)
             for u, v, w in zip(np.asarray(g.edge_src),
                                np.asarray(g.indices),
                                np.asarray(g.weights))}

    async def run():
        async with GraphService(ServiceConfig(max_wait_ms=0.0)) as svc:
            svc.register_graph("g", g, schedule=sched, kinds=["sssp"])
            for op in ops:
                if op[0] == "query":
                    got = np.asarray(await svc.query("g", "sssp", src=op[1]),
                                     np.int64)
                    want = _dijkstra(edges, n, op[1])
                    assert np.array_equal(got, want), (sched, op)
                else:
                    _, adds, dels = op
                    for u, v in dels:
                        edges.pop((u, v), None)
                    for u, v, w in adds:
                        edges[(u, v)] = w
                    delta = await svc.update_graph(
                        "g", adds=[(u, v) for u, v, _ in adds] or None,
                        dels=dels or None,
                        weights=[w for _, _, w in adds] or None)
                    assert delta.graph.num_edges == len(edges)

    asyncio.run(run())


@settings(max_examples=15, deadline=None)
@given(graphs())
def test_ell_view_preserves_edges(g):
    ell = to_ell(g)
    cols = np.asarray(ell.cols)
    n = g.num_nodes
    got = sorted((i, int(c)) for i in range(n) for c in cols[i] if c < n)
    want = sorted(zip(np.asarray(g.edge_src).tolist(),
                      np.asarray(g.indices).tolist()))
    assert got == want
