"""Compile-time effect & legality analysis: SPxxx codes, the compile gate,
the bad-program corpus, analyzer determinism, and the effects snapshots.

The corpus under tests/programs_bad/ is golden: each .sp file documents the
defect class in a header comment and must keep yielding exactly its SPxxx
code — these are the analysis layer's regression anchors.
"""
import json
import os

import numpy as np
import pytest

from repro.core import compile_bundled, load_program_source
from repro.core.analysis import (ERROR, REGISTRY, WARNING, Diagnostic,
                                 DiagnosticError, analysis_cache_clear,
                                 check_schedule, program_analysis)
from repro.core.analysis.cli import main as analyze_main
from repro.core.api import compile_program
from repro.core.parser import parse
from repro.core.semantic import SemanticError, analyze
from repro.schedule import Schedule

BAD_DIR = os.path.join(os.path.dirname(__file__), "programs_bad")
ALL_PROGRAMS = ["bc", "cc", "kcore", "lp", "ppr", "pr", "sssp",
                "sssp_pull", "tc"]


def _bad(name):
    with open(os.path.join(BAD_DIR, f"{name}.sp")) as f:
        return f.read()


def _only_fx(source):
    return next(iter(program_analysis(source).functions.values()))


# --- the golden bad-program corpus -----------------------------------------

@pytest.mark.parametrize("name,code,severity", [
    ("race_cross_write", "SP101", ERROR),
    ("scalar_race", "SP102", WARNING),
    ("nonterminating_fixedpoint", "SP151", ERROR),
    ("nonmonotone_fixedpoint", "SP153", WARNING),
])
def test_bad_corpus_program_diagnostics(name, code, severity):
    fx = _only_fx(_bad(name))
    assert [d.code for d in fx.diagnostics] == [code]
    d = fx.diagnostics[0]
    assert d.severity == severity
    assert d.line > 0
    assert d.source_line.strip(), "diagnostic must quote the offending line"


@pytest.mark.parametrize("name,sched,backend,code", [
    ("delta_unweighted", Schedule(priority="delta"), "local", "SP202"),
    ("frontier_no_loop", Schedule(dist_frontier="compact"), "distributed",
     "SP203"),
    ("refresh_no_loop", Schedule(refresh_threshold_frac=0.5), "local",
     "SP208"),
])
def test_bad_corpus_schedule_diagnostics(name, sched, backend, code):
    fx = _only_fx(_bad(name))
    assert fx.diagnostics == []     # the program alone is fine
    assert [d.code for d in check_schedule(fx, sched, backend)] == [code]


def test_race_corpus_rejected_by_compile_gate():
    with pytest.raises(DiagnosticError) as ei:
        compile_program(_bad("race_cross_write"))
    assert ei.value.codes == ["SP101"]
    assert isinstance(ei.value, ValueError)   # uniform error shape


def test_warning_corpus_compiles_unless_strict():
    prog = compile_program(_bad("scalar_race"))
    assert [d.code for d in prog.diagnostics] == ["SP102"]
    with pytest.raises(DiagnosticError):
        compile_program(_bad("scalar_race"), strict=True)


# --- bundled programs are clean ---------------------------------------------

@pytest.mark.parametrize("name", ALL_PROGRAMS)
def test_bundled_programs_strict_clean(name):
    """Every bundled program passes --strict analysis under the default
    schedule on every backend (the CI analyze step enforces the same)."""
    fx = _only_fx(load_program_source(name))
    assert fx.diagnostics == []
    for backend in ("local", "pallas", "distributed"):
        assert check_schedule(fx, Schedule(), backend) == []


def test_refresh_unsafe_flag_never_a_diagnostic():
    """SP209 is an ERROR in the registry but is raised only by
    `bound.refresh`: the analyzer flags kcore's self-gated peeling loop
    refresh-unsafe without emitting any diagnostic, so the strict analyze
    CI step stays clean while compile keeps working."""
    fx = _only_fx(load_program_source("kcore"))
    assert fx.refresh_unsafe
    assert fx.refresh_unsafe_line > 0
    assert "core" in fx.refresh_unsafe_reason
    assert fx.diagnostics == []
    assert REGISTRY["SP209"][0] == ERROR
    # programs whose while/fixedPoint bodies are not self-gated stay safe
    for name in ("pr", "ppr", "lp", "cc", "sssp"):
        assert not _only_fx(load_program_source(name)).refresh_unsafe, name


# --- schedule legality through the compile gate -----------------------------

def test_delta_on_tc_rejected_at_compile_time():
    with pytest.raises(DiagnosticError) as ei:
        compile_bundled("tc", schedule=Schedule(priority="delta"))
    assert "SP201" in ei.value.codes


def test_delta_on_tc_rejected_even_after_permissive_compile():
    """The gate runs before the compile cache: a prior legal compile must
    not let an illegal (schedule, program) combination slip through."""
    compile_bundled("tc")
    for _ in range(2):
        with pytest.raises(DiagnosticError):
            compile_bundled("tc", schedule=Schedule(priority="delta"))


def test_delta_on_unweighted_cc_warns_but_compiles():
    prog = compile_bundled("cc", schedule=Schedule(priority="delta"))
    assert [d.code for d in prog.diagnostics] == ["SP202"]
    with pytest.raises(DiagnosticError) as ei:
        compile_bundled("cc", schedule=Schedule(priority="delta"),
                        strict=True)
    assert "SP202" in ei.value.codes


@pytest.mark.parametrize("kwargs,backend,code", [
    (dict(delta_bucket=8), "local", "SP207"),
    (dict(direction="push"), "local", "SP205"),
    (dict(dist_frontier="compact", dist_gather_frac=0.75), "distributed",
     "SP206"),
    (dict(batch_sources=4), "local", "SP204"),
    (dict(refresh_threshold_frac=0.5), "local", "SP208"),
])
def test_schedule_warnings_on_tc(kwargs, backend, code):
    fx = _only_fx(load_program_source("tc"))
    codes = [d.code for d in check_schedule(fx, Schedule(**kwargs), backend)]
    assert code in codes


def test_default_batch_sources_not_flagged():
    """The ambient default (batch_sources=32) must not warn on programs
    without a source-set loop — only explicit nonstandard values do."""
    fx = _only_fx(load_program_source("sssp"))
    assert check_schedule(fx, Schedule(), "local") == []


# --- entry errors share the Diagnostic shape --------------------------------

def test_unknown_backend_is_sp301():
    with pytest.raises(DiagnosticError) as ei:
        compile_program(load_program_source("sssp"), backend="cuda")
    assert ei.value.codes == ["SP301"]


def test_unknown_fn_is_sp302():
    with pytest.raises(DiagnosticError) as ei:
        compile_program(load_program_source("sssp"), fn_name="nope")
    assert ei.value.codes == ["SP302"]
    assert "Compute_SSSP" in str(ei.value)


def test_unknown_bundled_is_sp303():
    with pytest.raises(DiagnosticError) as ei:
        load_program_source("dijkstra")
    assert ei.value.codes == ["SP303"]


# --- determinism and snapshots ----------------------------------------------

def test_analyzer_is_deterministic():
    for name in ALL_PROGRAMS:
        src = load_program_source(name)
        analysis_cache_clear()
        a = json.dumps(program_analysis(src).summary(), sort_keys=True)
        analysis_cache_clear()
        b = json.dumps(program_analysis(src).summary(), sort_keys=True)
        assert a == b, name


# (reads, writes, reductions, minmax kinds) per property in the function
# root region, plus the structural flags — the effects-sets snapshot for
# every bundled program. Update deliberately when the analysis changes.
SNAPSHOT = {
    "bc": {
        "flags": dict(has_set_loop=True, has_bfs=True, has_iter_loop=True,
                      has_relax=True, refresh_unsafe=False,
                      delta_target=None),
        "props": {"BC": (0, 2, ["+"], []), "delta": (2, 2, ["+"], []),
                  "sigma": (3, 3, ["+"], [])},
        "fixedpoints": [],
    },
    "cc": {
        "flags": dict(has_set_loop=False, has_bfs=False, has_iter_loop=True,
                      has_relax=True, refresh_unsafe=False,
                      delta_target="comp"),
        "props": {"comp": (2, 3, [], ["Min"]), "modified": (2, 2, [], [])},
        "fixedpoints": [("modified", [("comp", "Min", "int32", False, True)])],
    },
    "kcore": {
        # the self-gated peeling loop: `core` is plain-written inside the
        # while sweep AND read by the forall filters — refresh-unsafe
        "flags": dict(has_set_loop=False, has_bfs=False, has_iter_loop=True,
                      has_relax=False, refresh_unsafe=True,
                      delta_target=None),
        "props": {"core": (2, 2, [], [])},
        "fixedpoints": [],
    },
    "lp": {
        "flags": dict(has_set_loop=False, has_bfs=False, has_iter_loop=True,
                      has_relax=True, refresh_unsafe=False,
                      delta_target="label"),
        "props": {"label": (4, 4, [], ["Min"]), "modified": (3, 3, [], [])},
        "fixedpoints": [("modified",
                         [("label", "Min", "int32", False, True)])],
    },
    "ppr": {
        "flags": dict(has_set_loop=True, has_bfs=False, has_iter_loop=True,
                      has_relax=False, refresh_unsafe=False,
                      delta_target=None),
        "props": {"ppr": (0, 2, ["+"], []), "rank": (3, 3, [], []),
                  "rank_nxt": (1, 2, [], []), "restart": (1, 2, [], [])},
        "fixedpoints": [],
    },
    "pr": {
        "flags": dict(has_set_loop=False, has_bfs=False, has_iter_loop=True,
                      has_relax=False, refresh_unsafe=False,
                      delta_target=None),
        "props": {"pageRank": (2, 2, [], []), "pageRank_nxt": (1, 1, [], [])},
        "fixedpoints": [],
    },
    "sssp": {
        "flags": dict(has_set_loop=False, has_bfs=False, has_iter_loop=True,
                      has_relax=True, refresh_unsafe=False,
                      delta_target="dist"),
        "props": {"dist": (2, 3, [], ["Min"]), "modified": (2, 3, [], []),
                  "weight": (1, 0, [], [])},
        "fixedpoints": [("modified", [("dist", "Min", "int32", True, True)])],
    },
    "sssp_pull": {
        "flags": dict(has_set_loop=False, has_bfs=False, has_iter_loop=True,
                      has_relax=True, refresh_unsafe=False,
                      delta_target="dist"),
        "props": {"dist": (2, 3, [], ["Min"]), "modified": (2, 3, [], []),
                  "weight": (1, 0, [], [])},
        "fixedpoints": [("modified", [("dist", "Min", "int32", True, True)])],
    },
    "tc": {
        "flags": dict(has_set_loop=False, has_bfs=False, has_iter_loop=False,
                      has_relax=False, refresh_unsafe=False,
                      delta_target=None),
        "props": {},
        "fixedpoints": [],
    },
}


@pytest.mark.parametrize("name", ALL_PROGRAMS)
def test_effects_snapshot(name):
    fx = _only_fx(load_program_source(name))
    want = SNAPSHOT[name]
    s = fx.summary()
    assert s["flags"] == want["flags"], name
    got_props = {p: (v["reads"], v["self_writes"] + v["cross_writes"],
                     v["reductions"], v["minmax"])
                 for p, v in s["region"]["props"].items()}
    assert got_props == want["props"], name
    got_fps = [(fp.conv_prop,
                [(t.prop, t.kind, t.dtype, t.weighted, t.monotone)
                 for t in fp.targets]) for fp in fx.fixedpoints]
    assert got_fps == want["fixedpoints"], name


# --- source positions --------------------------------------------------------

def test_semantic_error_quotes_source_line():
    with pytest.raises(SemanticError) as ei:
        analyze(parse("function f(Graph g) {\n  oops = 1;\n}"))
    msg = str(ei.value)
    assert "line 2" in msg and "oops = 1;" in msg


def test_race_diagnostic_quotes_source_line():
    fx = _only_fx(_bad("race_cross_write"))
    d = fx.diagnostics[0]
    assert "nbr.label" in d.source_line
    assert f"line {d.line}" in d.format()


# --- Diagnostic value object -------------------------------------------------

def test_diagnostic_round_trip():
    fx = _only_fx(_bad("nonmonotone_fixedpoint"))
    for d in fx.diagnostics:
        assert Diagnostic.from_dict(d.to_dict()) == d


def test_registry_severities_are_valid():
    for code, (sev, desc) in REGISTRY.items():
        assert sev in (ERROR, WARNING), code
        assert desc, code
        assert code.startswith("SP") and code[2:].isdigit(), code


# --- CLI ---------------------------------------------------------------------

def test_cli_bundled_strict_clean(capsys):
    assert analyze_main(["--bundled", "--strict"]) == 0
    out = capsys.readouterr().out
    assert "0 error(s), 0 warning(s)" in out


def test_cli_reports_error_exit(capsys):
    path = os.path.join(BAD_DIR, "race_cross_write.sp")
    assert analyze_main([path]) == 1
    assert "SP101" in capsys.readouterr().out


def test_cli_strict_promotes_warnings(capsys):
    path = os.path.join(BAD_DIR, "scalar_race.sp")
    assert analyze_main([path]) == 0
    assert analyze_main([path, "--strict"]) == 1


def test_cli_schedule_knobs(capsys):
    assert analyze_main(["tc", "--schedule", "priority=delta"]) == 1
    assert "SP201" in capsys.readouterr().out


def test_cli_json_round_trip(capsys):
    path = os.path.join(BAD_DIR, "nonmonotone_fixedpoint.sp")
    assert analyze_main([path, "--json"]) == 0   # SP153 is a warning
    payload = json.loads(capsys.readouterr().out)
    [target] = payload["targets"]
    diags = [Diagnostic.from_dict(d) for d in target["diagnostics"]]
    assert [d.code for d in diags] == ["SP153"]
    # summaries are JSON-stable
    assert json.loads(json.dumps(target["functions"])) == target["functions"]


# --- autotune integration ----------------------------------------------------

def test_tuning_record_gains_pruned_candidates_field():
    from repro.autotune import TuningRecord
    rec = TuningRecord(source_digest="d", backend="local",
                       graph_fingerprint="f", fn_name="fn", schedule={},
                       best_ms=1.0, default_ms=1.0, trials=[], budget=1,
                       seed=0)
    assert rec.pruned_candidates == 0
    # old persisted records (no field) load with the default
    d = rec.to_dict()
    d.pop("pruned_candidates")
    assert TuningRecord.from_dict(d).pruned_candidates == 0


def test_autotune_prunes_illegal_delta_candidates():
    """On a deep weighted grid the search space proposes priority="delta"
    candidates; for bc (no monotone Min relax) every one is statically
    illegal and must be pruned unmeasured rather than exploding in
    DiagnosticError mid-measurement."""
    from repro.autotune import autotune, search_space
    from repro.core.context import get_context
    from repro.graph.generators import road
    g = road(24, seed=3)   # deep enough for delta-stepping candidates
    stats = get_context(g).stats()
    prog = compile_bundled("bc")
    n_delta = sum(1 for c in search_space(stats, base=prog.schedule,
                                          tune_batch=True)
                  if c.priority == "delta")
    if n_delta == 0:
        pytest.skip("search space proposed no delta candidates here")
    srcs = np.arange(4, dtype=np.int32)
    r = autotune(prog, g, budget=32, seed=0,
                 params={"sourceSet": srcs},
                 measure=lambda bound, p: 1.0)
    assert r.record.pruned_candidates >= n_delta
    assert all(t["schedule"]["priority"] == "none"
               for t in r.record.trials)
