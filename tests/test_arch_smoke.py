"""Per-architecture smoke tests (assignment requirement): instantiate a
REDUCED same-family config, run one forward + one train step on CPU,
assert output shapes + finiteness; plus a decode step per arch."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import build
from repro.train import OptimizerConfig, init_state, make_train_step
from repro.train.data import DataConfig, batch_at, embeds_batch_at

ARCH_NAMES = list(ARCHS)


def _smoke_batch(cfg, b=2, s=32):
    dc = DataConfig(vocab=cfg.vocab, seq_len=s, global_batch=b)
    if cfg.input_kind == "embeddings" or cfg.family == "encdec":
        return embeds_batch_at(dc, 0, cfg.d_model)
    return batch_at(dc, 0)


@pytest.fixture(scope="module")
def smoke_models():
    return {}


def _get(smoke_models, name):
    if name not in smoke_models:
        cfg = ARCHS[name].smoke()
        m = build(cfg)
        params = m.init(jax.random.PRNGKey(0))
        smoke_models[name] = (cfg, m, params)
    return smoke_models[name]


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_finite(smoke_models, name):
    cfg, m, params = _get(smoke_models, name)
    batch = _smoke_batch(cfg)
    logits, aux = m.forward(params, batch, impl="ref", remat=False)
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_finite(smoke_models, name):
    cfg, m, params = _get(smoke_models, name)
    state = init_state(m, jax.random.PRNGKey(1))
    oc = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    step = jax.jit(make_train_step(m, oc, microbatches=1, impl="ref",
                                   remat=True))
    batch = _smoke_batch(cfg)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state.step) == 1
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(init_state(m, jax.random.PRNGKey(1)).params)))
    assert delta > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step(smoke_models, name):
    cfg, m, params = _get(smoke_models, name)
    b, maxlen = 2, 16
    cache = m.init_cache(b, maxlen, 8) if cfg.family == "encdec" \
        else m.init_cache(b, maxlen)
    logits, cache2 = m.decode_step(params, jnp.ones((b, 1), jnp.int32), cache,
                                   jnp.int32(0))
    assert logits.shape == (b, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())


def test_exact_configs_match_assignment():
    """Pin the exact assigned hyperparameters."""
    c = ARCHS["qwen2.5-3b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (36, 2048, 16, 2, 11008, 151936) and c.qkv_bias
    c = ARCHS["minicpm-2b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (40, 2304, 36, 5760, 122753) and c.wsd_schedule
    c = ARCHS["mistral-large-123b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (88, 12288, 96, 8, 28672, 32768)
    c = ARCHS["phi4-mini-3.8b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 3072, 24, 8, 8192, 200064)
    c = ARCHS["seamless-m4t-large-v2"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (24, 1024, 16, 8192, 256206) and c.family == "encdec"
    c = ARCHS["chameleon-34b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (48, 8192, 64, 8, 22016, 65536)
    c = ARCHS["qwen3-moe-235b-a22b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.n_experts, c.moe_top_k) == (94, 4096, 64, 4, 1536, 151936, 128, 8)
    c = ARCHS["deepseek-moe-16b"]
    assert (c.n_layers, c.d_model, c.n_experts, c.n_shared_experts,
            c.moe_top_k, c.d_ff, c.vocab) == (28, 2048, 64, 2, 6, 1408, 102400)
    c = ARCHS["zamba2-1.2b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab,
            c.ssm_state) == (38, 2048, 32, 8192, 32000, 64)
    c = ARCHS["xlstm-1.3b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (48, 2048, 4, 0, 50304)


def test_long_context_skip_policy():
    """long_500k runs only for SSM/hybrid families (DESIGN.md §5)."""
    from repro.configs.base import shape_cells_for
    for name, cfg in ARCHS.items():
        names = [c.name for c in shape_cells_for(cfg)]
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in names, name
        else:
            assert "long_500k" not in names, name
