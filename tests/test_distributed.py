"""Distributed backend vs the NumPy oracles — in-process on the 8 forced
host devices (see conftest.py), under the default dense-gather schedule.
The frontier-compressed exchange policies are covered by
test_dist_agree.py / test_dist_padding.py / test_property.py; this module
pins the paper-faithful baseline plus the beyond-paper 2-D and
pod-parallel paths.
"""
import jax
import numpy as np
import pytest

from repro.core import compile_bundled, dist
from repro.core.dist2d import pagerank_2d, sssp_2d
from repro.graph import road, uniform_random
from repro.graph.algorithms_ref import (bc_ref, pagerank_ref, sssp_ref,
                                        triangle_count_ref)


@pytest.fixture(scope="module")
def g(eight_devices):
    return uniform_random(100, 5, seed=2)


@pytest.fixture(scope="module")
def mesh8(eight_devices):
    return dist.make_mesh_1d(8)


def test_sssp_1d(g, mesh8):
    p = compile_bundled("sssp", backend="distributed")
    out = dist.run(p, g, mesh8, src=0)
    assert np.array_equal(np.asarray(out["dist"]),
                          sssp_ref(g, 0).astype(np.int32))


def test_sssp_pull_1d(g, mesh8):
    p = compile_bundled("sssp_pull", backend="distributed")
    out = dist.run(p, g, mesh8, src=0)
    assert np.array_equal(np.asarray(out["dist"]),
                          sssp_ref(g, 0).astype(np.int32))


def test_pr_1d(g, mesh8):
    p = compile_bundled("pr", backend="distributed")
    out = dist.run(p, g, mesh8, beta=1e-4, delta=0.85, maxIter=60)
    assert np.allclose(np.asarray(out["pageRank"]), pagerank_ref(g),
                       atol=1e-5)


def test_tc_1d(g, mesh8):
    p = compile_bundled("tc", backend="distributed")
    assert int(dist.run(p, g, mesh8)["triangle_count"]) == triangle_count_ref(g)


def test_bc_1d(g, mesh8):
    p = compile_bundled("bc", backend="distributed")
    srcs = np.array([0, 7, 23], np.int32)
    out = dist.run(p, g, mesh8, sourceSet=srcs)
    assert np.allclose(np.asarray(out["BC"]), bc_ref(g, [0, 7, 23]),
                       atol=1e-3)


def test_sssp_1d_road(mesh8):
    gr = road(10, seed=3)     # large diameter — many BSP supersteps
    p = compile_bundled("sssp", backend="distributed")
    out = dist.run(p, gr, mesh8, src=0)
    assert np.array_equal(np.asarray(out["dist"]),
                          sssp_ref(gr, 0).astype(np.int32))


def test_sssp_2d(g, eight_devices):
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    assert np.array_equal(np.asarray(sssp_2d(g, mesh2, 0)),
                          sssp_ref(g, 0).astype(np.int32))


def test_pr_2d(g, eight_devices):
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    assert np.allclose(np.asarray(pagerank_2d(g, mesh2)), pagerank_ref(g),
                       atol=1e-5)


def test_bc_pod_parallel(g, eight_devices):
    mesh3 = jax.make_mesh((2, 4), ("pod", "data"))
    p = compile_bundled("bc", backend="distributed")
    srcs4 = np.array([0, 7, 23, 41], np.int32)
    out = dist.run_pod_parallel(p, g, mesh3, srcs4)
    assert np.allclose(np.asarray(out["BC"]), bc_ref(g, srcs4.tolist()),
                       atol=1e-3)
    # the communication counter is psum'd across pods: it must equal the
    # sum of the two per-pod (4-shard) runs, not one arbitrary pod's count
    mesh4 = dist.make_mesh_1d(4)
    per_pod = sum(
        float(p.bind(g, mesh=mesh4)(sourceSet=s)["_gather_elems"])
        for s in (srcs4[:2], srcs4[2:]))
    assert float(out["_gather_elems"]) == per_pod
