"""Distributed backend tests — run in a subprocess with 8 host devices so
the main pytest process keeps a single device."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import numpy as np, jax, json
from repro.core import compile_bundled, dist
from repro.core.dist2d import sssp_2d, pagerank_2d
from repro.graph import uniform_random, road
from repro.graph.algorithms_ref import sssp_ref, pagerank_ref, triangle_count_ref, bc_ref

results = {}
mesh = dist.make_mesh_1d(8)
g = uniform_random(100, 5, seed=2)
gr = road(10, seed=3)

p = compile_bundled("sssp", backend="distributed")
results["sssp_1d"] = bool(np.array_equal(
    np.asarray(dist.run(p, g, mesh, src=0)["dist"]), sssp_ref(g, 0).astype(np.int32)))
p = compile_bundled("sssp_pull", backend="distributed")
results["sssp_pull_1d"] = bool(np.array_equal(
    np.asarray(dist.run(p, g, mesh, src=0)["dist"]), sssp_ref(g, 0).astype(np.int32)))
p = compile_bundled("pr", backend="distributed")
out = dist.run(p, g, mesh, beta=1e-4, delta=0.85, maxIter=60)
results["pr_1d"] = bool(np.allclose(np.asarray(out["pageRank"]), pagerank_ref(g), atol=1e-5))
p = compile_bundled("tc", backend="distributed")
results["tc_1d"] = int(dist.run(p, g, mesh)["triangle_count"]) == triangle_count_ref(g)
p = compile_bundled("bc", backend="distributed")
srcs = np.array([0, 7, 23], np.int32)
results["bc_1d"] = bool(np.allclose(
    np.asarray(dist.run(p, g, mesh, sourceSet=srcs)["BC"]), bc_ref(g, [0, 7, 23]), atol=1e-3))

# road graph (large diameter — many BSP steps)
p = compile_bundled("sssp", backend="distributed")
results["sssp_1d_road"] = bool(np.array_equal(
    np.asarray(dist.run(p, gr, mesh, src=0)["dist"]), sssp_ref(gr, 0).astype(np.int32)))

# 2-D beyond-paper path
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
results["sssp_2d"] = bool(np.array_equal(np.asarray(sssp_2d(g, mesh2, 0)),
                                         sssp_ref(g, 0).astype(np.int32)))
results["pr_2d"] = bool(np.allclose(np.asarray(pagerank_2d(g, mesh2)),
                                    pagerank_ref(g), atol=1e-5))

# pod-parallel BC (multi-pod story)
mesh3 = jax.make_mesh((2, 4), ("pod", "data"))
p = compile_bundled("bc", backend="distributed")
srcs4 = np.array([0, 7, 23, 41], np.int32)
out = dist.run_pod_parallel(p, g, mesh3, srcs4)
results["bc_pod_parallel"] = bool(np.allclose(
    np.asarray(out["BC"]), bc_ref(g, srcs4.tolist()), atol=1e-3))

print("RESULTS:" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")][0]
    return json.loads(line[len("RESULTS:"):])


@pytest.mark.parametrize("key", [
    "sssp_1d", "sssp_pull_1d", "pr_1d", "tc_1d", "bc_1d", "sssp_1d_road",
    "sssp_2d", "pr_2d", "bc_pod_parallel",
])
def test_distributed(dist_results, key):
    assert dist_results[key], f"{key} mismatch vs oracle"
