"""Local backend: every DSL-compiled algorithm vs the numpy/networkx oracles,
across the graph families (paper §5 structure)."""
import numpy as np
import pytest

from repro.core import compile_bundled
from repro.graph import from_edges
from repro.graph.algorithms_ref import (bc_ref, pagerank_ref, sssp_ref,
                                        triangle_count_ref)


@pytest.fixture(scope="module")
def progs():
    return {name: compile_bundled(name) for name in
            ["sssp", "sssp_pull", "pr", "tc", "bc"]}


@pytest.mark.parametrize("gname", ["UR", "RD", "SW"])
@pytest.mark.parametrize("variant", ["sssp", "sssp_pull"])
def test_sssp(progs, graph_suite, gname, variant):
    g = graph_suite[gname]
    out = progs[variant](g, src=0)
    assert np.array_equal(np.asarray(out["dist"]),
                          sssp_ref(g, 0).astype(np.int32))
    assert bool(out["finished"])


@pytest.mark.parametrize("gname", ["UR", "RD", "SW"])
def test_pagerank(progs, graph_suite, gname):
    g = graph_suite[gname]
    out = progs["pr"](g, beta=1e-4, delta=0.85, maxIter=100)
    ref = pagerank_ref(g, 0.85, 1e-4, 100)
    np.testing.assert_allclose(np.asarray(out["pageRank"]), ref, atol=2e-5)


@pytest.mark.parametrize("gname", ["UR", "RD", "SW"])
def test_triangle_count(progs, graph_suite, gname):
    g = graph_suite[gname]
    assert int(progs["tc"](g)["triangle_count"]) == triangle_count_ref(g)


@pytest.mark.parametrize("gname", ["UR", "SW"])
def test_bc(progs, graph_suite, gname):
    g = graph_suite[gname]
    srcs = np.array([0, 7, 23], np.int32)
    out = progs["bc"](g, sourceSet=srcs)
    ref = bc_ref(g, srcs.tolist())
    np.testing.assert_allclose(np.asarray(out["BC"]), ref, rtol=1e-3, atol=1e-3)


def test_sssp_unreachable(progs):
    # two components: nodes 4.. are unreachable from 0
    g = from_edges(8, np.array([0, 1, 4, 5]), np.array([1, 2, 5, 6]),
                   np.array([3, 4, 1, 1]))
    out = progs["sssp"](g, src=0)
    dist = np.asarray(out["dist"])
    assert dist[2] == 7 and dist[4] >= 2**30 and dist[7] >= 2**30


def test_sssp_source_choice(progs, g_medium):
    for src in [0, 13, 57]:
        out = progs["sssp"](g_medium, src=src)
        assert np.array_equal(np.asarray(out["dist"]),
                              sssp_ref(g_medium, src).astype(np.int32))


def test_pr_iteration_cap(progs, g_medium):
    out = progs["pr"](g_medium, beta=0.0, delta=0.85, maxIter=7)
    assert int(out["iterCount"]) == 7      # beta=0 never converges; cap binds


def test_generated_source_is_inspectable(progs):
    src = progs["sssp"].source
    assert "jax.lax.while_loop" in src     # fixedPoint lowering
    assert "scatter_min" in src            # Min construct lowering
    assert "def Compute_SSSP" in src
