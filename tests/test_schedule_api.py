"""Schedule / GraphContext / compile-cache public API (algorithm–schedule
separation).

Covers: the compile cache (identity on repeated calls, keyed by schedule);
schedule determinism (same Schedule -> byte-identical generated source);
schedule coexistence (two programs under different schedules in one
process, both correct); the deprecated ENGINE shim (snapshot semantics,
validation, post-compile mutation is inert); knob validation with
actionable errors; the uniform `prog.bind(g)` calling convention on all
three backends; and the `prepare` warm-up entry point.
"""
import gc
import warnings

import numpy as np
import pytest

from repro.core import (Schedule, bind_cache_clear, bind_cache_size,
                        compile_bundled, compile_cache_clear, compile_program,
                        get_context, load_program_source, prepare)
from repro.graph import ENGINE, preferential_attachment
from repro.graph.algorithms_ref import bc_ref, sssp_ref


@pytest.fixture(scope="module")
def g_pl():
    return preferential_attachment(400, m=5, seed=3)


@pytest.fixture()
def engine_guard():
    """Snapshot/restore the deprecated ENGINE shim around mutation tests."""
    saved = ENGINE.snapshot()
    yield
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for k in ("num_buckets", "min_width", "growth",
                  "push_threshold_frac", "batch_sources"):
            setattr(ENGINE, k, getattr(saved, k))


# --- compile cache ------------------------------------------------------------

@pytest.mark.parametrize("backend", ["local", "pallas", "distributed"])
def test_compile_cache_returns_same_object(backend):
    a = compile_bundled("sssp", backend=backend)
    b = compile_bundled("sssp", backend=backend)
    assert a is b, "identical (source, backend, schedule) must be memoized"


def test_compile_cache_keys_on_schedule_and_backend():
    base = compile_bundled("sssp", backend="local")
    assert compile_bundled("sssp", backend="pallas") is not base
    assert compile_bundled("sssp", backend="local",
                           schedule=Schedule(direction="pull")) is not base
    assert compile_bundled("sssp", backend="local",
                           batch_sources=2) is not base


def test_same_schedule_byte_identical_source():
    for backend in ["local", "pallas", "distributed"]:
        compile_cache_clear()
        a = compile_bundled("bc", backend=backend, schedule=Schedule())
        compile_cache_clear()
        b = compile_bundled("bc", backend=backend, schedule=Schedule())
        assert a is not b              # genuinely recompiled...
        assert a.source == b.source    # ...to byte-identical source


def test_distributed_knobs_are_source_literals():
    """The distributed codegen consumes the Schedule as literals: distinct
    dist knobs produce distinct source, and the policy strings are visible
    in the generated text (the PR-3 contract, extended to the third
    backend)."""
    base = compile_bundled("sssp", backend="distributed",
                           schedule=Schedule())
    comp = compile_bundled("sssp", backend="distributed",
                           schedule=Schedule(dist_frontier="auto",
                                             dist_gather_frac=1 / 8))
    pull = compile_bundled("sssp", backend="distributed",
                           schedule=Schedule(direction="pull"))
    assert len({base.source, comp.source, pull.source}) == 3
    assert "rtd.exchange" in comp.source
    assert "rtd.exchange" not in base.source       # dense: plain gathers
    assert "0.125" in comp.source                  # the gather_frac literal
    # batched distributed source lanes are schedule-driven too
    bseq = compile_bundled("bc", backend="distributed",
                           schedule=Schedule(batch_sources=0))
    bbat = compile_bundled("bc", backend="distributed",
                           schedule=Schedule(batch_sources=4))
    assert "rtd.bfs_levels_1d_batch" in bbat.source
    assert "rtd.bfs_levels_1d_batch" not in bseq.source


# --- schedules coexist --------------------------------------------------------

def test_two_schedules_coexist_and_agree(g_pl):
    """Push-pinned, pull-pinned, and auto SSSP all in one process: three
    distinct programs (the schedule is baked into the source), identical
    results (direction never changes the relaxation)."""
    ref = sssp_ref(g_pl, 0).astype(np.int32)
    progs = {d: compile_bundled("sssp", backend="local",
                                schedule=Schedule(direction=d))
             for d in ("auto", "push", "pull")}
    assert len({id(p) for p in progs.values()}) == 3
    assert len({p.source for p in progs.values()}) == 3
    for d, p in progs.items():
        assert np.array_equal(np.asarray(p(g_pl, src=0)["dist"]), ref), d


def test_two_layouts_coexist_on_one_graph(g_pl):
    """Two pallas programs with different bucket layouts share the graph's
    GraphContext but each gets its own sliced-ELL view."""
    ref = sssp_ref(g_pl, 0).astype(np.int32)
    s1, s2 = Schedule(), Schedule(min_width=16, num_buckets=3)
    p1 = compile_bundled("sssp", backend="pallas", schedule=s1)
    p2 = compile_bundled("sssp", backend="pallas", schedule=s2)
    assert np.array_equal(np.asarray(p1(g_pl, src=0)["dist"]), ref)
    assert np.array_equal(np.asarray(p2(g_pl, src=0)["dist"]), ref)
    ctx = get_context(g_pl)
    v1 = ctx.sliced_ell(s1)
    v2 = ctx.sliced_ell(s2)
    assert v1 is not v2 and v1.widths != v2.widths
    assert ctx.sliced_ell(s1) is v1, "views must be memoized per layout"


def test_batch_width_is_per_program(g_pl):
    srcs = np.array([0, 7, 19, 31, 44], np.int32)
    seq = compile_bundled("bc", backend="local",
                          schedule=Schedule(batch_sources=0))
    bat = compile_bundled("bc", backend="local",
                          schedule=Schedule(batch_sources=4))
    assert "rt.bfs_levels_batch" in bat.source
    assert "rt.bfs_levels_batch" not in seq.source
    np.testing.assert_allclose(np.asarray(bat(g_pl, sourceSet=srcs)["BC"]),
                               np.asarray(seq(g_pl, sourceSet=srcs)["BC"]),
                               rtol=1e-4, atol=1e-4)


# --- the deprecated ENGINE shim -----------------------------------------------

def test_engine_mutation_after_compile_is_inert(g_pl, engine_guard):
    """The schedule is snapshotted at compile time; the compiled program
    must not observe later ENGINE mutation (knobs are source literals)."""
    prog = compile_bundled("sssp", backend="local")
    before = np.asarray(prog(g_pl, src=0)["dist"])
    src_before = prog.source
    with pytest.warns(DeprecationWarning):
        ENGINE.push_threshold_frac = 1.0
    with pytest.warns(DeprecationWarning):
        ENGINE.batch_sources = 0
    assert prog.source == src_before
    assert np.array_equal(np.asarray(prog(g_pl, src=0)["dist"]), before)
    # ...but a NEW default-schedule compile snapshots the mutated shim
    fresh = compile_bundled("sssp", backend="local")
    assert fresh is not prog
    assert "1.0" in fresh.source


def test_engine_mutation_inert_on_distributed(g_pl, engine_guard):
    """Post-compile ENGINE mutation must stay inert on the distributed
    backend too — its knobs are baked literals like the other backends'."""
    from repro.graph.algorithms_ref import sssp_ref
    prog = compile_bundled("sssp", backend="distributed")
    src_before = prog.source
    with pytest.warns(DeprecationWarning):
        ENGINE.push_threshold_frac = 1.0
    assert prog.source == src_before
    out = np.asarray(prog.bind(g_pl)(src=0)["dist"])
    assert np.array_equal(out, sssp_ref(g_pl, 0).astype(np.int32))


def test_engine_shim_validates_before_committing(engine_guard):
    with pytest.raises(ValueError, match="growth"):
        ENGINE.growth = 1
    assert ENGINE.growth != 1, "a rejected mutation must not take effect"
    with pytest.raises(AttributeError, match="no knob"):
        ENGINE.bucket_count = 3


# --- Schedule validation ------------------------------------------------------

@pytest.mark.parametrize("bad,match", [
    (dict(num_buckets=0), "num_buckets"),
    (dict(min_width=0), "min_width"),
    (dict(min_width=7), "multiple of 8"),
    (dict(growth=1), "growth"),
    (dict(push_threshold_frac=1.5), "push_threshold_frac"),
    (dict(push_threshold_frac=-0.1), "push_threshold_frac"),
    (dict(batch_sources=-1), "batch_sources"),
    (dict(direction="sideways"), "direction"),
    (dict(dist_frontier="sparse"), "dist_frontier"),
    (dict(dist_gather_frac=1.5), "dist_gather_frac"),
    (dict(dist_gather_frac=-0.1), "dist_gather_frac"),
    (dict(priority="fifo"), "priority"),
    (dict(delta_bucket=0), "delta_bucket"),
    (dict(delta_bucket=-8), "delta_bucket"),
])
def test_schedule_validation_is_actionable(bad, match):
    with pytest.raises(ValueError, match=match):
        Schedule(**bad)


def test_schedule_is_hashable_and_normalized():
    assert Schedule(push_threshold_frac=0) == Schedule(push_threshold_frac=0.0)
    assert hash(Schedule()) == hash(Schedule())
    assert Schedule().replace(batch_sources=4).batch_sources == 4
    assert Schedule().bucket_widths() == (8, 32, 128, 512)
    # numpy scalars (autotuning sweeps) normalize to canonical python values
    npsched = Schedule(batch_sources=np.int32(8), min_width=np.int64(16),
                       push_threshold_frac=np.float32(0.25))
    assert npsched == Schedule(batch_sources=8, min_width=16,
                               push_threshold_frac=0.25)
    assert type(npsched.batch_sources) is int
    with pytest.raises(ValueError, match="integer"):
        Schedule(batch_sources=True)


def test_engine_shim_snapshot_is_default_schedule():
    assert ENGINE.snapshot() == Schedule(), \
        "an unmutated shim must materialize exactly the default Schedule"


# --- error messages -----------------------------------------------------------

def test_unknown_fn_name_raises_value_error_with_names():
    with pytest.raises(ValueError, match="Compute_SSSP"):
        compile_program(load_program_source("sssp"), fn_name="nope")


def test_unknown_bundled_program_lists_bundled():
    with pytest.raises(ValueError, match="sssp_pull"):
        load_program_source("dijkstra")


# --- bind: the uniform calling convention -------------------------------------

@pytest.mark.parametrize("backend", ["local", "pallas", "distributed"])
def test_bind_uniform_across_backends(backend, g_pl):
    """`prog.bind(g)(**params)` answers identically on every backend —
    including distributed, where bind folds in the mesh/partition/dist_meta
    plumbing (single-shard mesh in-process)."""
    ref = sssp_ref(g_pl, 0).astype(np.int32)
    prog = compile_bundled("sssp", backend=backend)
    bound = prog.bind(g_pl)
    assert np.array_equal(np.asarray(bound(src=0)["dist"]), ref)
    # a second query reuses the bound plumbing (partition, jitted runner)
    assert np.array_equal(np.asarray(bound(src=7)["dist"]),
                          sssp_ref(g_pl, 7).astype(np.int32))


def test_bind_distributed_bc_matches_oracle(g_pl):
    srcs = np.array([0, 7, 23], np.int32)
    bound = compile_bundled("bc", backend="distributed").bind(g_pl)
    np.testing.assert_allclose(np.asarray(bound(sourceSet=srcs)["BC"]),
                               bc_ref(g_pl, srcs.tolist()), atol=1e-3)


def test_bind_rejects_mesh_on_single_device_backends(g_pl):
    with pytest.raises(ValueError, match="mesh"):
        compile_bundled("sssp", backend="local").bind(g_pl, mesh=object())


def test_bind_is_memoized_per_program_and_graph(g_pl):
    """Repeated binds on a serving query path return the SAME BoundProgram
    (no re-warming views, no rebuilding the jitted runner) — but the cache
    holds everything weakly, so dropping the bound runner releases the
    entry instead of pinning every graph ever bound."""
    bind_cache_clear()
    local = compile_bundled("sssp", backend="local")
    pallas = compile_bundled("sssp", backend="pallas")
    bound = local.bind(g_pl)
    assert local.bind(g_pl) is bound
    other = pallas.bind(g_pl)
    assert other is not bound            # distinct program -> its own entry
    assert pallas.bind(g_pl) is other
    assert bind_cache_size() == 2
    g2 = preferential_attachment(60, m=2, seed=9)
    assert local.bind(g2) is not bound   # distinct graph -> its own entry
    # all-weak entries: dropping the bound runner evicts, next bind rebuilds
    del bound
    gc.collect()
    assert bind_cache_size() == 1        # only the still-held bind survives
    rebound = local.bind(g_pl)
    assert np.array_equal(np.asarray(rebound(src=0)["dist"]),
                          sssp_ref(g_pl, 0).astype(np.int32))


# --- prepare (explicit warm-up) -----------------------------------------------

def test_prepare_warms_the_views_bind_reuses(g_pl):
    sched = Schedule(min_width=24, num_buckets=2)
    ctx = prepare(g_pl, sched, backend="pallas")
    assert ctx is get_context(g_pl)
    view = ctx.sliced_ell(sched)
    prog = compile_bundled("sssp", backend="pallas", schedule=sched)
    prog.bind(g_pl)
    assert ctx.sliced_ell(sched) is view, "bind must reuse the warm view"


def test_prepare_unknown_backend():
    g = preferential_attachment(40, m=2, seed=1)
    with pytest.raises(ValueError, match="backend"):
        prepare(g, backend="cuda")


def test_prepare_program_warms_needs_ell_partition():
    """`prepare(g, program=prog)` must warm the exact partition bind will
    request — including the replicated-ELL variant TC's distributed body
    needs — not a duplicate ell-less one."""
    g = preferential_attachment(120, m=3, seed=9)   # fresh, private context
    prog = compile_bundled("tc", backend="distributed")
    assert (prog.dist_meta or {}).get("needs_ell")
    ctx = prepare(g, program=prog)
    keys = [k for k in ctx.view_keys() if k[0] == "dist_1d"]
    assert keys and all(k[2] is True for k in keys), keys
    prog.bind(g)   # must reuse the warm view, not build ell=False too
    keys = [k for k in ctx.view_keys() if k[0] == "dist_1d"]
    assert len(keys) == 1
