// SP208 (under --schedule refresh_threshold_frac=0.5): one-shot degree
// counts converge in a single pass — there is no iterative construct for
// `BoundProgram.refresh` to warm-start, so the incremental-recompute
// cutoff can never bind and refresh raises on this program.
function Bad_Refresh(Graph g, propNode<int> deg) {
    g.attachNodeProperty(deg = 0);
    forall(v in g.nodes()) {
        forall(nbr in g.neighbors(v)) {
            v.deg += 1;
        }
    }
}
