// SP101: plain cross-vertex write under a parallel forall — two vertices
// sharing a neighbor race on nbr.label (no reduction, no Min/Max sync).
function Bad_Race(Graph g, propNode<int> label) {
    forall(v in g.nodes()) {
        forall(nbr in g.neighbors(v)) {
            nbr.label = v.label;
        }
    }
}
