// SP202 (under --schedule priority=delta): a monotone Min relax with no
// edge weight in the candidate — every relaxation lands in the current
// bucket, so delta-stepping degenerates to plain sweeps.
function Bad_DeltaUnweighted(Graph g, propNode<int> comp, propNode<bool> modified) {
    g.attachNodeProperty(comp = 0, modified = True);
    forall(v in g.nodes()) {
        v.comp = v;
    }
    bool finished = False;
    fixedPoint until (finished : !modified) {
        forall(v in g.nodes()) {
            forall(nbr in g.nodesTo(v).filter(modified == True)) {
                <v.comp, v.modified> = <Min(v.comp, nbr.comp), True>;
            }
        }
    }
}
