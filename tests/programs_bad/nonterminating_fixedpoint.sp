// SP151: the convergence property `modified` is never written inside the
// loop body — the fixedPoint can never terminate.
function Bad_Converge(Graph g, propNode<int> dist, propNode<bool> modified) {
    g.attachNodeProperty(dist = INF, modified = True);
    bool finished = False;
    fixedPoint until (finished : !modified) {
        forall(v in g.nodes()) {
            v.dist = 0;
        }
    }
}
