// SP102: a function-scope scalar plain-assigned inside a parallel loop —
// last-writer-wins, the result depends on iteration order.
function Bad_ScalarRace(Graph g) {
    int last = 0;
    forall(v in g.nodes()) {
        last = v;
    }
}
