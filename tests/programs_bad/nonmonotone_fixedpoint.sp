// SP153: `dist` is updated through both Min and Max inside one fixedPoint —
// the value can oscillate, so convergence is not provable and priority
// scheduling would be unsound.
function Bad_Monotone(Graph g, propNode<int> dist, propNode<bool> modified) {
    g.attachNodeProperty(dist = INF, modified = True);
    bool finished = False;
    fixedPoint until (finished : !modified) {
        forall(v in g.nodes()) {
            forall(nbr in g.nodesTo(v).filter(modified == True)) {
                <v.dist, v.modified> = <Min(v.dist, nbr.dist + 1), True>;
                <v.dist, v.modified> = <Max(v.dist, nbr.dist - 1), True>;
            }
        }
    }
}
