// SP203 (under --backend distributed --schedule dist_frontier=compact):
// the writes are one-shot degree counts, not frontier-carried state — there
// is no iterative construct for the compact exchange to carry views across.
function Bad_Frontier(Graph g, propNode<int> deg) {
    g.attachNodeProperty(deg = 0);
    forall(v in g.nodes()) {
        forall(nbr in g.neighbors(v)) {
            v.deg += 1;
        }
    }
}
