"""Autotuner behavior: determinism, persistence, and staleness rejection.

Wall-clock timing is inherently noisy, so the determinism tests inject a
deterministic `measure=` cost model (keyed off the trial's schedule); the
contract under test is that everything *around* the measurement —
candidate derivation, trial order, truncation, tie-breaking, record
contents — is exactly reproducible given (graph, seed, budget).
"""
import json

import numpy as np
import pytest

from repro.autotune import (TuningRecord, TuningStore, autotune,
                            default_params, schedule_from_dict,
                            schedule_to_dict, search_space, source_digest)
from repro.core import Schedule, compile_bundled, get_context
from repro.graph import preferential_attachment
from repro.graph.algorithms_ref import sssp_ref
from repro.graph.generators import road


@pytest.fixture(scope="module")
def g_pl():
    return preferential_attachment(300, m=5, seed=3)


@pytest.fixture(scope="module")
def g_road():
    # big enough that the BFS probe's peak frontier stays under the
    # always-sparse threshold (peak ~ 2/side of N on a grid)
    return road(32, seed=7)


@pytest.fixture(scope="module")
def sssp_prog():
    return compile_bundled("sssp", backend="local")


def fake_measure(bound, params):
    """Deterministic, schedule-dependent cost: no wall clock involved."""
    s = bound.program.schedule
    return 1.0 + (hash(s) % 1000) / 1000.0


# --------------------------------------------------------------------------
# search space
# --------------------------------------------------------------------------

def test_search_space_base_first_and_deduped(g_pl):
    stats = get_context(g_pl).stats()
    cands = search_space(stats)
    assert cands[0] == Schedule()
    assert len(cands) == len(set(cands))
    assert all(isinstance(c, Schedule) for c in cands)


def test_search_space_prunes_by_family(g_pl, g_road):
    pl = search_space(get_context(g_pl).stats())
    rd = search_space(get_context(g_road).stats())
    # power-law: explores deep bucket layouts; road: collapses to 1 bucket
    assert any(c.num_buckets >= 5 for c in pl)
    assert any(c.num_buckets == 1 for c in rd)
    # road frontiers stay sparse -> a pinned-push candidate appears
    assert any(c.direction == "push" for c in rd)
    assert not any(c.direction == "push" for c in pl)
    assert pl != rd


def test_search_space_batch_dim_gated(g_pl):
    stats = get_context(g_pl).stats()
    without = search_space(stats)
    with_batch = search_space(stats, tune_batch=True)
    extra = [c for c in with_batch if c not in without]
    assert extra and all(c.batch_sources != Schedule().batch_sources
                         for c in extra)


# --------------------------------------------------------------------------
# determinism
# --------------------------------------------------------------------------

def test_autotune_deterministic_same_seed_budget(sssp_prog, g_pl):
    r1 = autotune(sssp_prog, g_pl, budget=6, seed=0, measure=fake_measure)
    r2 = autotune(sssp_prog, g_pl, budget=6, seed=0, measure=fake_measure)
    assert r1.schedule == r2.schedule
    assert r1.record.trials == r2.record.trials
    assert r1.record.key() == r2.record.key()


def test_autotune_budget_truncates_trials(sssp_prog, g_pl):
    r = autotune(sssp_prog, g_pl, budget=3, seed=0, measure=fake_measure)
    assert len(r.record.trials) == 3
    # trial #0 is always the program's own schedule
    assert r.record.trials[0]["schedule"] == schedule_to_dict(
        sssp_prog.schedule)


def test_autotune_never_measured_worse_than_base(sssp_prog, g_pl):
    r = autotune(sssp_prog, g_pl, budget=8, seed=0, measure=fake_measure)
    assert r.record.best_ms <= r.record.default_ms
    assert r.speedup >= 1.0


def test_autotune_result_correct(sssp_prog, g_pl):
    """The tuned program still computes SSSP exactly (schedules only change
    execution, never results)."""
    r = autotune(sssp_prog, g_pl, budget=6, seed=0, measure=fake_measure)
    out = np.asarray(r.program.bind(g_pl)(src=0)["dist"])
    assert np.array_equal(out, sssp_ref(g_pl, 0).astype(np.int32))


def test_autotune_reuses_compile_cache(sssp_prog, g_pl):
    from repro.core import compile_cache_size
    autotune(sssp_prog, g_pl, budget=6, seed=0, measure=fake_measure)
    size1 = compile_cache_size()
    autotune(sssp_prog, g_pl, budget=6, seed=0, measure=fake_measure)
    assert compile_cache_size() == size1   # second sweep: all cache hits


def test_recompile_own_schedule_is_identity(sssp_prog):
    """Trial #0 recompiles the program under its own schedule — that must
    be a cache hit on the SAME object (no duplicate compile, no fresh jit
    wrapper), even though the program was compiled with fn_name=None."""
    assert sssp_prog.recompile(sssp_prog.schedule) is sssp_prog


def test_default_params_from_ir(g_pl):
    p = default_params(compile_bundled("sssp"), g_pl, seed=0)
    assert p == {"src": 0}
    p = default_params(compile_bundled("bc"), g_pl, seed=0)
    assert p["sourceSet"].dtype == np.int32
    p2 = default_params(compile_bundled("bc"), g_pl, seed=0)
    assert np.array_equal(p["sourceSet"], p2["sourceSet"])   # seeded
    p = default_params(compile_bundled("pr"), g_pl, seed=0)
    assert p["maxIter"] == 20 and 0 < p["delta"] < 1


# --------------------------------------------------------------------------
# records: JSON round-trip
# --------------------------------------------------------------------------

def test_schedule_dict_round_trip_through_json():
    for s in (Schedule(), Schedule(block_rows=(64, 64, 128, 256)),
              Schedule(direction="push", push_threshold_frac=0.25)):
        thawed = schedule_from_dict(
            json.loads(json.dumps(schedule_to_dict(s))))
        assert thawed == s


def test_schedule_from_dict_rejects_unknown_fields():
    d = schedule_to_dict(Schedule())
    d["warp_size"] = 32
    with pytest.raises(ValueError, match="warp_size"):
        schedule_from_dict(d)


def test_tuning_record_json_round_trip(sssp_prog, g_pl):
    rec = autotune(sssp_prog, g_pl, budget=4, seed=0,
                   measure=fake_measure).record
    thawed = TuningRecord.from_json(rec.to_json())
    assert thawed == rec
    assert thawed.best_schedule() == rec.best_schedule()
    assert isinstance(thawed.best_schedule(), Schedule)


# --------------------------------------------------------------------------
# store: persistence + staleness rejection
# --------------------------------------------------------------------------

def test_store_hit_skips_measurement(sssp_prog, g_pl, tmp_path):
    path = str(tmp_path / "tuned.json")
    r1 = autotune(sssp_prog, g_pl, budget=5, seed=0, measure=fake_measure,
                  store=path)
    assert not r1.from_store

    calls = []

    def counting_measure(bound, params):
        calls.append(1)
        return fake_measure(bound, params)

    r2 = autotune(sssp_prog, g_pl, budget=5, seed=0,
                  measure=counting_measure, store=path)
    assert r2.from_store and not calls
    assert r2.schedule == r1.schedule


def _tamper(path, field, value):
    with open(path) as f:
        data = json.load(f)
    assert data["records"], "store unexpectedly empty"
    data["records"][0][field] = value
    with open(path, "w") as f:
        json.dump(data, f)


@pytest.mark.parametrize("field", ["source_digest", "graph_fingerprint"])
def test_store_rejects_mismatched_record(sssp_prog, g_pl, tmp_path, field):
    """A record whose digest/fingerprint no longer matches (source or graph
    changed since it was written) is rejected and the tuner re-measures."""
    path = str(tmp_path / "tuned.json")
    autotune(sssp_prog, g_pl, budget=4, seed=0, measure=fake_measure,
             store=path)
    _tamper(path, field, "0badc0ffee0badc0")

    calls = []

    def counting_measure(bound, params):
        calls.append(1)
        return fake_measure(bound, params)

    r = autotune(sssp_prog, g_pl, budget=4, seed=0,
                 measure=counting_measure, store=path)
    assert not r.from_store and len(calls) == 4   # re-tuned, full sweep


def test_corrupt_store_file_is_a_miss_not_a_crash(sssp_prog, g_pl, tmp_path):
    """A truncated/hand-edited store file means "never tuned": the tuner
    re-measures and the next save rewrites a clean file."""
    path = str(tmp_path / "tuned.json")
    with open(path, "w") as f:
        f.write('{"version": 1, "records": [{"trunc')
    r = autotune(sssp_prog, g_pl, budget=3, seed=0, measure=fake_measure,
                 store=path)
    assert not r.from_store and len(r.record.trials) == 3
    assert len(TuningStore(path)) == 1   # clean file rewritten


def test_invalid_stored_schedule_is_a_miss(sssp_prog, g_pl, tmp_path):
    """A key-valid record whose schedule no longer validates (written by a
    different Schedule version) is re-tuned, not raised."""
    path = str(tmp_path / "tuned.json")
    autotune(sssp_prog, g_pl, budget=3, seed=0, measure=fake_measure,
             store=path)
    _tamper(path, "schedule", {"direction": "sideways"})
    r = autotune(sssp_prog, g_pl, budget=3, seed=0, measure=fake_measure,
                 store=path)
    assert not r.from_store and len(r.record.trials) == 3


def test_different_graph_is_a_store_miss(sssp_prog, g_pl, tmp_path):
    path = str(tmp_path / "tuned.json")
    autotune(sssp_prog, g_pl, budget=4, seed=0, measure=fake_measure,
             store=path)
    g2 = preferential_attachment(300, m=5, seed=99)   # different contents
    r = autotune(sssp_prog, g2, budget=4, seed=0, measure=fake_measure,
                 store=path)
    assert not r.from_store
    store = TuningStore(path)
    assert len(store) == 2   # both graphs now recorded side by side


def test_fingerprint_is_content_addressed():
    a = preferential_attachment(200, m=4, seed=5)
    b = preferential_attachment(200, m=4, seed=5)
    c = preferential_attachment(200, m=4, seed=6)
    assert get_context(a).fingerprint() == get_context(b).fingerprint()
    assert get_context(a).fingerprint() != get_context(c).fingerprint()


def test_stats_shape(g_pl, g_road):
    s = get_context(g_pl).stats()
    for k in ("num_nodes", "avg_degree", "skew", "deg_cv", "probe_depth",
              "probe_max_frontier_frac", "probe_growth", "probe_reach_frac"):
        assert k in s, k
    assert get_context(g_pl).stats() is s          # memoized
    assert get_context(g_road).stats()["deg_cv"] < 0.3 < s["deg_cv"]


# --------------------------------------------------------------------------
# cost-model seeding: nearest-stats-neighbor warm starts
# --------------------------------------------------------------------------

def test_stats_distance_identity_and_family_ordering(g_pl, g_road):
    from repro.autotune import stats_distance
    s_pl = get_context(g_pl).stats()
    s_rd = get_context(g_road).stats()
    assert stats_distance(s_pl, s_pl) == 0.0
    # a same-family graph sits nearer than a different family
    g_pl2 = preferential_attachment(330, m=5, seed=8)
    s_pl2 = get_context(g_pl2).stats()
    assert stats_distance(s_pl, s_pl2) < stats_distance(s_pl, s_rd)


def test_nearest_record_matches_graph_family(sssp_prog, g_pl, g_road,
                                             tmp_path):
    from repro.autotune import nearest_record
    path = str(tmp_path / "tuned.json")
    autotune(sssp_prog, g_pl, budget=4, seed=0, measure=fake_measure,
             store=path)
    autotune(sssp_prog, g_road, budget=4, seed=0, measure=fake_measure,
             store=path)
    store = TuningStore(path)
    digest = source_digest(sssp_prog.dsl_source)
    g_probe = preferential_attachment(300, m=5, seed=21)
    probe = get_context(g_probe).stats()
    rec = nearest_record(store, digest, "local", probe)
    assert rec is not None
    assert rec.graph_fingerprint == get_context(g_pl).fingerprint()
    # nothing comparable for another backend
    assert nearest_record(store, digest, "distributed", probe) is None


def test_autotune_seeds_unseen_graph_from_store(sssp_prog, g_pl, tmp_path):
    """Store miss + populated store: the stats-nearest record proposes its
    winner as trial #0 (provenance recorded), the program's own schedule is
    still measured, and the result is never measured-worse than default."""
    path = str(tmp_path / "tuned.json")
    r1 = autotune(sssp_prog, g_pl, budget=6, seed=0, measure=fake_measure,
                  store=path)
    g2 = preferential_attachment(300, m=5, seed=11)    # unseen graph
    r2 = autotune(sssp_prog, g2, budget=6, seed=0, measure=fake_measure,
                  store=path)
    assert not r2.from_store
    rec = r2.record
    assert rec.seeded_from == get_context(g_pl).fingerprint()
    assert rec.trials[0]["source"] == "seeded"
    assert schedule_from_dict(rec.trials[0]["schedule"]) == r1.schedule
    assert all(t["source"] == "search" for t in rec.trials[1:])
    # the own-schedule baseline is measured too, so seeding only helps
    assert any(schedule_from_dict(t["schedule"]) == sssp_prog.schedule
               for t in rec.trials)
    assert rec.best_ms <= rec.default_ms


def test_seeding_needs_store_and_budget(sssp_prog, g_pl, tmp_path):
    r = autotune(sssp_prog, g_pl, budget=4, seed=0, measure=fake_measure)
    assert r.record.seeded_from == ""
    assert all(t["source"] == "search" for t in r.record.trials)
    # budget=1 leaves no room to measure both seed and baseline: no seed,
    # trial #0 stays the program's own schedule
    path = str(tmp_path / "tuned.json")
    autotune(sssp_prog, g_pl, budget=4, seed=0, measure=fake_measure,
             store=path)
    g2 = preferential_attachment(300, m=5, seed=12)
    r1 = autotune(sssp_prog, g2, budget=1, seed=0, measure=fake_measure,
                  store=path)
    assert r1.record.seeded_from == ""
    assert r1.record.trials[0]["schedule"] == schedule_to_dict(
        sssp_prog.schedule)
    assert r1.record.trials[0]["source"] == "search"


def test_seeded_from_round_trips_and_old_records_load(sssp_prog, g_pl,
                                                      tmp_path):
    path = str(tmp_path / "tuned.json")
    autotune(sssp_prog, g_pl, budget=4, seed=0, measure=fake_measure,
             store=path)
    g2 = preferential_attachment(300, m=5, seed=13)
    rec = autotune(sssp_prog, g2, budget=4, seed=0, measure=fake_measure,
                   store=path).record
    assert rec.seeded_from
    thawed = TuningRecord.from_json(rec.to_json())
    assert thawed == rec and thawed.seeded_from == rec.seeded_from
    # records written before the field existed load with the default
    d = json.loads(rec.to_json())
    del d["seeded_from"]
    assert TuningRecord.from_dict(d).seeded_from == ""


def test_default_params_sources_without_replacement():
    """Set-valued params draw distinct sources: a duplicated source would
    fill two batch lanes with the same query (and double-count one
    contribution in set-semantics programs like BC)."""
    g_small = road(3, seed=0)          # 9 nodes < the 16-source default
    p = default_params(compile_bundled("bc"), g_small, seed=0)
    srcs = p["sourceSet"]
    assert len(srcs) == g_small.num_nodes
    assert len(np.unique(srcs)) == len(srcs)
    for s in range(5):                 # distinct under any seed
        q = default_params(compile_bundled("bc"), g_small, seed=s)
        assert len(np.unique(q["sourceSet"])) == len(q["sourceSet"])


# --------------------------------------------------------------------------
# distributed backend (exclusion removed in the frontier-aware dist PR)
# --------------------------------------------------------------------------

def test_search_space_distributed_candidates(g_pl):
    stats = get_context(g_pl).stats()
    cands = search_space(stats, backend="distributed")
    # the dense-gather base is always trial #0 (never measured-worse)
    assert cands[0] == Schedule()
    assert cands[0].dist_frontier == "dense"
    assert len(cands) == len(set(cands))
    assert any(c.dist_frontier == "auto" for c in cands)
    assert any(c.dist_frontier == "compact" for c in cands)
    assert any(c.direction == "pull" for c in cands)
    # the single-device layout/kernel knobs are not the dist plane
    assert all(c.layout_key() == Schedule().layout_key() for c in cands)
    with_batch = search_space(stats, backend="distributed", tune_batch=True)
    assert any(c.batch_sources != Schedule().batch_sources
               for c in with_batch)


def test_autotune_distributed_runs_and_stays_correct(g_pl, eight_devices):
    from repro.graph.algorithms_ref import sssp_ref
    prog = compile_bundled("sssp", backend="distributed")
    r = autotune(prog, g_pl, budget=4, seed=0, measure=fake_measure)
    assert r.record.backend == "distributed"
    assert len(r.record.trials) == 4
    assert r.record.trials[0]["schedule"]["dist_frontier"] == "dense"
    assert r.record.best_ms <= r.record.default_ms
    out = np.asarray(r.program.bind(g_pl)(src=0)["dist"])
    assert np.array_equal(out, sssp_ref(g_pl, 0).astype(np.int32))


def test_digest_stability():
    src = "function f(Graph g) {}"
    assert source_digest(src) == source_digest(src)
    assert source_digest(src) != source_digest(src + " ")
