"""Dynamic graphs: `g.update()` write batches, delta-patched sliced-ELL
views, version-aware fingerprints, and incremental `refresh` agreement
with from-scratch recompute across programs × backends × graph families.
"""
import numpy as np
import pytest

from repro.autotune import RECORD_VERSION, TuningRecord, TuningStore, \
    source_digest
from repro.core import Schedule, compile_bundled, load_program_source
from repro.core.api import BoundProgram
from repro.core.context import get_context
from repro.graph import (from_edges, patch_sliced_ell, powerlaw_social, road,
                         sliced_ell_edges, to_sliced_ell)

PARAMS = {
    "sssp": dict(src=0),
    "sssp_pull": dict(src=0),
    "cc": dict(),
    "pr": dict(beta=1e-5, delta=0.85, maxIter=100),
    "lp": dict(),
}
VALUE_KEY = {"sssp": "dist", "sssp_pull": "dist", "cc": "comp",
             "pr": "pageRank", "lp": "label"}

GRAPHS = {
    "powerlaw": lambda: powerlaw_social(150, avg_degree=8, seed=7),
    "grid": lambda: road(9, seed=7),
}


def random_batch(rng, g, k_add=5, k_del=4):
    n = g.num_nodes
    adds = np.stack([rng.integers(0, n, k_add),
                     rng.integers(0, n, k_add)], 1)
    weights = rng.integers(1, 10, k_add)
    idx = rng.choice(g.num_edges, min(k_del, g.num_edges), replace=False)
    dels = np.stack([np.asarray(g.edge_src)[idx],
                     np.asarray(g.indices)[idx]], 1)
    return adds, dels, weights


def assert_same(name, ref, out):
    key = VALUE_KEY[name]
    a, b = np.asarray(ref[key]), np.asarray(out[key])
    if name == "pr":
        # both runs stop at diff <= beta, so warm/cold agree to tolerance
        np.testing.assert_allclose(a, b, atol=1e-3)
    else:
        np.testing.assert_array_equal(a, b)


# --- the agreement matrix ---------------------------------------------------

@pytest.mark.parametrize("backend", ["local", "pallas"])
@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("name", sorted(PARAMS))
def test_refresh_matches_scratch(name, gname, backend):
    """K chained random batches: refresh (forced incremental) == from
    scratch on every version, with the refreshed result feeding the next
    refresh."""
    rng = np.random.default_rng(11)
    g = GRAPHS[gname]()
    prog = compile_bundled(name, backend=backend,
                           schedule=Schedule(refresh_threshold_frac=1.0))
    prev = prog.bind(g)(**PARAMS[name])
    for _ in range(3):
        adds, dels, w = random_batch(rng, g)
        delta = g.update(adds, dels, weights=w)
        bound = prog.bind(delta.graph)
        scratch = bound(**PARAMS[name])
        refreshed = bound.refresh(prev, delta, **PARAMS[name])
        assert_same(name, scratch, refreshed)
        g, prev = delta.graph, refreshed


def test_threshold_zero_falls_back_dense():
    """refresh_threshold_frac=0.0 pins the from-scratch path — answers
    still agree (it IS the plain call)."""
    rng = np.random.default_rng(5)
    g = GRAPHS["powerlaw"]()
    prog = compile_bundled("sssp",
                          schedule=Schedule(refresh_threshold_frac=0.0))
    prev = prog.bind(g)(src=0)
    adds, dels, w = random_batch(rng, g)
    delta = g.update(adds, dels, weights=w)
    bound = prog.bind(delta.graph)
    assert delta.plan().affected_frac > 0.0
    assert_same("sssp", bound(src=0), bound.refresh(prev, delta, src=0))


def test_refresh_without_loop_raises():
    g = GRAPHS["grid"]()
    bound = compile_bundled("tc").bind(g)
    assert bound.program.refresh_fn is None
    with pytest.raises(ValueError, match="no incremental refresh"):
        bound.refresh({}, None)


def test_refresh_ppr_has_no_incremental_path():
    """ppr's do-while lives inside the source-set loop, so there is no
    top-level fixpoint to warm-start — refresh refuses up front."""
    g = GRAPHS["grid"]()
    bound = compile_bundled("ppr").bind(g)
    assert bound.program.refresh_fn is None
    with pytest.raises(ValueError, match="no incremental refresh"):
        bound.refresh({}, None)


def test_refresh_kcore_rejected_as_self_gated_peeling():
    """kcore plain-writes `core` inside the while body its own filter
    reads: SP209 — warm-starting the erosion fixpoint is unsound, so
    refresh must raise rather than silently return wrong cores."""
    from repro.core.analysis import DiagnosticError
    rng = np.random.default_rng(8)
    g = GRAPHS["grid"]()
    prog = compile_bundled("kcore",
                           schedule=Schedule(refresh_threshold_frac=1.0))
    prev = prog.bind(g)(k=2)
    adds, dels, w = random_batch(rng, g)
    delta = g.update(adds, dels, weights=w)
    with pytest.raises(DiagnosticError) as ei:
        prog.bind(delta.graph).refresh(prev, delta, k=2)
    assert "SP209" in ei.value.codes


def test_refresh_requires_post_update_bind():
    rng = np.random.default_rng(6)
    g = GRAPHS["grid"]()
    prog = compile_bundled("sssp")
    prev = prog.bind(g)(src=0)
    adds, dels, w = random_batch(rng, g)
    delta = g.update(adds, dels, weights=w)
    with pytest.raises(ValueError, match="post-update graph"):
        prog.bind(g).refresh(prev, delta, src=0)


# --- update semantics + edge cases ------------------------------------------

def test_update_is_immutable_and_versioned():
    g = GRAPHS["grid"]()
    before = np.asarray(g.indices).copy()
    delta = g.update(adds=[(0, 5)], dels=[(0, 1)])
    assert g.version == 0 and delta.graph.version == 1
    assert np.array_equal(np.asarray(g.indices), before)
    assert delta.old is g


def test_weight_replace_and_batch_dedup():
    g = from_edges(4, [0, 1], [1, 2], [3, 3])
    # add an existing pair: weight replaced; last write in the batch wins
    delta = g.update(adds=[(0, 1), (0, 1)], weights=[7, 9])
    assert delta.num_added == 1 and delta.num_removed == 1
    assert (int(delta.add_wts[0]), int(delta.del_wts[0])) == (9, 3)
    assert delta.graph.num_edges == 2


def test_delete_absent_edge_is_noop():
    g = from_edges(4, [0, 1], [1, 2], [3, 3])
    delta = g.update(dels=[(2, 3)])
    assert delta.num_added == 0 and delta.num_removed == 0
    assert delta.graph.num_edges == 2
    assert delta.plan().affected_frac == 0.0


def test_delete_then_reinsert_same_content_fresh_fingerprint():
    """A content-identical successor version must NOT alias the old
    graph's fingerprint, bind-cache entry, or tuning records."""
    g = GRAPHS["grid"]()
    e = (int(np.asarray(g.edge_src)[0]), int(np.asarray(g.indices)[0]))
    w = int(np.asarray(g.weights)[0])
    d1 = g.update(dels=[e])
    d2 = d1.graph.update(adds=[e], weights=[w])
    g2 = d2.graph
    for arr in ("indptr", "indices", "weights"):
        np.testing.assert_array_equal(np.asarray(getattr(g, arr)),
                                      np.asarray(getattr(g2, arr)))
    fps = {get_context(x).fingerprint() for x in (g, d1.graph, g2)}
    assert len(fps) == 3, "every version fingerprints distinctly"

    prog = compile_bundled("sssp")
    b_old, b_new = prog.bind(g), prog.bind(g2)
    assert b_old is not b_new
    assert prog.bind(g) is b_old, "old bind stays cached"
    assert prog.bind(g2) is b_new

    # a record tuned against the old version is a miss for the new one
    store = TuningStore()
    digest = source_digest(load_program_source("sssp"))
    store.put(TuningRecord(
        source_digest=digest, backend="local",
        graph_fingerprint=get_context(g).fingerprint(),
        fn_name="Compute_SSSP", schedule={}, best_ms=1.0, default_ms=1.0,
        trials=[], budget=1, seed=0, version=RECORD_VERSION))
    assert store.lookup(digest, "local",
                        get_context(g).fingerprint()) is not None
    assert store.lookup(digest, "local",
                        get_context(g2).fingerprint()) is None


def test_batch_emptying_a_vertex():
    """Deleting every out-edge of a vertex evacuates its forward-view row
    (degree 0 rows live nowhere) and refresh still agrees."""
    g = GRAPHS["powerlaw"]()
    sched = Schedule(refresh_threshold_frac=1.0)
    ctx = get_context(g)
    ctx.sliced_ell(sched, reverse=False)
    ctx.sliced_ell(sched, reverse=True)
    out_deg = np.diff(np.asarray(g.indptr))
    v = int(np.argmax((out_deg > 0) & (out_deg <= 4)))
    s, e = int(g.indptr[v]), int(g.indptr[v + 1])
    dels = np.stack([np.full(e - s, v), np.asarray(g.indices)[s:e]], 1)

    prog = compile_bundled("sssp", schedule=sched)
    prev = prog.bind(g)(src=0)
    delta = g.update(dels=dels)
    g2 = delta.graph
    assert int(g2.indptr[v + 1] - g2.indptr[v]) == 0
    for rev in (False, True):
        patched = get_context(g2).sliced_ell(sched, reverse=rev)
        fresh = to_sliced_ell(g2, reverse=rev, schedule=sched)
        assert sliced_ell_edges(patched) == sliced_ell_edges(fresh)
    bound = prog.bind(g2)
    assert_same("sssp", bound(src=0), bound.refresh(prev, delta, src=0))


def test_hub_tail_absorbs_migrations():
    """Under a single narrow bucket most hub-adjacent rows live in the COO
    tail; updates touching the hub and rows that overflow their bucket
    must keep the patched view semantically exact, and the pallas program
    must compute the same answers through it."""
    g = GRAPHS["powerlaw"]()
    n = g.num_nodes
    sched = Schedule(num_buckets=1, min_width=8, refresh_threshold_frac=1.0)
    ctx = get_context(g)
    view = ctx.sliced_ell(sched, reverse=True)
    assert np.asarray(view.hub_rows).size > 0, "need a populated hub tail"
    hub = int(np.asarray(view.hub_rows)[0])
    # touch the hub row AND push a bucket row past the 8-wide bucket
    in_deg = np.zeros(n, np.int64)
    np.add.at(in_deg, np.asarray(g.indices), 1)
    small = int(np.argmax((in_deg > 0) & (in_deg <= 8)))
    rng = np.random.default_rng(2)
    adds = [(int(s), small) for s in rng.choice(n, 10, replace=False)] \
        + [(int(rng.integers(0, n)), hub)]
    idx = np.flatnonzero(np.asarray(g.indices) == hub)[:2]
    dels = np.stack([np.asarray(g.edge_src)[idx],
                     np.asarray(g.indices)[idx]], 1)

    prog = compile_bundled("sssp", backend="pallas", schedule=sched)
    prev = prog.bind(g)(src=0)
    delta = g.update(adds, dels, weights=np.arange(1, len(adds) + 1))
    g2 = delta.graph
    patched = get_context(g2).sliced_ell(sched, reverse=True)
    fresh = to_sliced_ell(g2, reverse=True, schedule=sched)
    assert sliced_ell_edges(patched) == sliced_ell_edges(fresh)
    # the migrated row moved to the hub tail, keeping bucket shapes intact
    assert np.asarray(patched.hub_rows).size > np.asarray(view.hub_rows).size
    assert [c.shape for c in patched.cols] == [c.shape for c in view.cols]
    bound = prog.bind(g2)
    assert_same("sssp", bound(src=0), bound.refresh(prev, delta, src=0))


@pytest.mark.parametrize("rev", [False, True])
def test_patched_view_matches_rebuilt(rev):
    rng = np.random.default_rng(13)
    g = GRAPHS["powerlaw"]()
    sched = Schedule(num_buckets=3)
    view = get_context(g).sliced_ell(sched, reverse=rev)
    adds, dels, w = random_batch(rng, g, k_add=12, k_del=10)
    delta = g.update(adds, dels, weights=w)
    patched = patch_sliced_ell(view, delta, reverse=rev)
    fresh = to_sliced_ell(delta.graph, reverse=rev, schedule=sched)
    assert sliced_ell_edges(patched) == sliced_ell_edges(fresh)


def test_empty_delta_reuses_view():
    g = GRAPHS["grid"]()
    sched = Schedule()
    view = get_context(g).sliced_ell(sched, reverse=True)
    delta = g.update()      # no-op batch
    assert patch_sliced_ell(view, delta, reverse=True) is view


# --- refresh plan semantics -------------------------------------------------

def test_plan_insert_only_seeds_sources():
    g = GRAPHS["grid"]()
    # long-range pairs: genuinely NEW edges (re-adding an existing edge
    # with a different weight is a replacement, which resets a cone)
    delta = g.update(adds=[(3, 40), (10, 60)])
    assert delta.num_removed == 0
    plan = delta.plan()
    assert plan.cone_size == 0, "no deletions -> nothing resets"
    assert set(np.flatnonzero(plan.seed)) == {3, 10}


def test_plan_delete_cone_is_forward_closure():
    # path 0 -> 1 -> 2 -> 3; deleting (0,1) must reset {1,2,3}
    g = from_edges(5, [0, 1, 2], [1, 2, 3], [1, 1, 1])
    plan = g.update(dels=[(0, 1)]).plan()
    assert set(np.flatnonzero(plan.reset)) == {1, 2, 3}
    assert plan.cone_size == 3


def test_refresh_work_is_seed_proportional():
    """The point of the exercise: a small batch's warm frontier relaxes
    far fewer edges than the cold run from the source (host replay of the
    monotone sweep, counting frontier out-degree per iteration)."""
    g = powerlaw_social(600, avg_degree=8, seed=3)
    rng = np.random.default_rng(4)
    adds, dels, w = random_batch(rng, g, k_add=3, k_del=0)
    delta = g.update(adds, dels, weights=w)
    plan = delta.plan()

    prev = compile_bundled("sssp").bind(g)(src=0)

    def replay_edges(g2, dist0, frontier0):
        indptr = np.asarray(g2.indptr)
        out_deg = np.diff(indptr)
        indices, edge_src = np.asarray(g2.indices), np.asarray(g2.edge_src)
        wts = np.asarray(g2.weights, np.int64)
        dist = np.asarray(dist0, np.int64).copy()
        front = frontier0.copy()
        edges = 0
        while front.any():
            edges += int(out_deg[front].sum())
            fe = front[edge_src]
            cand = np.full(len(dist), 2**30, np.int64)
            np.minimum.at(cand, indices[fe], dist[edge_src[fe]] + wts[fe])
            improved = cand < dist
            dist = np.minimum(dist, cand)
            front = improved
        return edges, dist

    g2 = delta.graph
    n = g2.num_nodes
    cold_front = np.zeros(n, bool)
    cold_front[0] = True
    cold_dist = np.full(n, 2**30, np.int64)
    cold_dist[0] = 0
    cold_edges, cold = replay_edges(g2, cold_dist, cold_front)

    warm_dist = np.asarray(prev["dist"], np.int64).copy()
    warm_dist[plan.reset] = 2**30
    warm_dist[0] = 0
    warm_edges, warm = replay_edges(g2, warm_dist, plan.seed.copy())
    np.testing.assert_array_equal(cold, warm)
    assert warm_edges < cold_edges, (warm_edges, cold_edges)
