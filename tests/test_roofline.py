"""Roofline machinery: loop-aware HLO cost parser vs known-flop programs;
sharding spec rules; xla cost_analysis undercount documented."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze
from repro.launch import roofline
from repro.launch.sharding import param_specs
from jax.sharding import PartitionSpec as P


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    res = analyze(_compile(lambda a, b: a @ b, x, w).as_text())
    assert res["flops"] == 2 * 64 * 128 * 256


def test_scan_trip_count_multiplies():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
    res = analyze(_compile(scanned, x, ws).as_text())
    assert res["flops"] == 2 * 128 ** 3 * 10
    assert not res["unknown_trip_bodies"]


def test_nested_loops_multiply():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)

    def nested(x, ws):
        def outer(i, acc):
            return jax.lax.scan(lambda c, w: (c @ w, None), acc, ws)[0]
        return jax.lax.fori_loop(0, 5, outer, x)
    res = analyze(_compile(nested, x, ws).as_text())
    assert res["flops"] == 2 * 128 ** 3 * 10 * 5


def test_xla_cost_analysis_counts_bodies_once():
    """The reason hlo_cost.py exists (documented undercount)."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
    comp = _compile(scanned, x, ws)
    from repro.launch.hlo_cost import xla_cost_dict
    assert xla_cost_dict(comp)["flops"] < 2 * 128 ** 3 * 2   # ~1 body


def test_data_dependent_while_flagged():
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)

    def fixpoint(x):
        def cond(s):
            return jnp.max(s) > 1e-3
        return jax.lax.while_loop(cond, lambda s: (s @ s) * 0.5, x)
    res = analyze(_compile(fixpoint, x).as_text())
    assert res["unknown_trip_bodies"]          # honest: trips unknowable


def test_roofline_terms_and_bottleneck():
    rec = {"flops": 1.97e14, "dot_bytes": 8.19e11, "collective_bytes": 1.5e11,
           "num_devices": 256}
    t = roofline.terms(rec)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    rec["flops"] = 4e14
    assert roofline.terms(rec)["bottleneck"] == "compute"


def test_param_sharding_rules():
    from repro.configs import ARCHS
    from repro.models import build
    cfg = ARCHS["qwen2.5-3b"]
    m = build(cfg)
    shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, {"data": 16, "model": 16})
    assert specs["embed"] == P("model", "data")
    assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["layers"]["mlp"]["w_down"] == P(None, "model", "data")
    assert specs["ln_f"]["scale"] == P(None)
    # kv projection output (2 heads × 128 = 256) still divides 16 → sharded
    assert specs["layers"]["attn"]["wk"] == P(None, "data", "model")


def test_divisibility_guard():
    from repro.configs import ARCHS
    from repro.models import build
    cfg = ARCHS["xlstm-1.3b"]
    m = build(cfg)
    shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, {"data": 16, "model": 16})
    # wf: [d, 4 heads] — 4 % 16 != 0 → second dim replicated
    assert specs["mlstm"]["wf"] == P(None, "data", None)


def test_model_flops_analytic():
    from repro.configs import ARCHS
    cfg = ARCHS["qwen2.5-3b"]
    n = roofline.param_count(cfg)
    assert 2.5e9 < n < 4.0e9            # ~3B params
    moe = ARCHS["qwen3-moe-235b-a22b"]
    assert 180e9 < roofline.param_count(moe) < 280e9
    active = roofline.param_count(moe, active_only=True)
    assert 15e9 < active < 30e9         # ~22B active
