"""Sharded training driver: run on one mesh, elastic-resume on another
(subprocess with 8 host devices)."""
import os
import subprocess
import sys
import tempfile

import pytest

_SCRIPT = r"""
import sys, json
from repro.launch.train import run
d = sys.argv[1]
l1 = run("qwen2.5-3b", "4,2", 6, ckpt_dir=d, ckpt_every=3, log_every=100)
l2 = run("qwen2.5-3b", "2,2,2", 10, ckpt_dir=d, ckpt_every=100, log_every=100)
print("RESULTS:" + json.dumps({"l1": l1, "l2": l2}))
"""


@pytest.mark.slow
def test_sharded_train_and_elastic_resume():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    with tempfile.TemporaryDirectory() as d:
        proc = subprocess.run([sys.executable, "-c", _SCRIPT, d], env=env,
                              capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "resumed from step 6" in proc.stdout
    import json
    res = json.loads([l for l in proc.stdout.splitlines()
                      if l.startswith("RESULTS:")][0][len("RESULTS:"):])
    assert res["l1"] > 0 and res["l2"] > 0    # finite losses on both meshes
