"""Per-kernel shape/dtype sweeps: pallas_call (interpret=True) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph import preferential_attachment
from repro.graph.csr import INF_I32
from repro.kernels.ell_spmv.kernel import ell_spmv
from repro.kernels.ell_spmv.ops import (gather_plustimes, prepare_ell,
                                        prepare_sliced_ell, relax_minplus)
from repro.kernels.ell_spmv.ref import ell_spmv_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import gqa_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.tc_matmul.kernel import tc_matmul
from repro.kernels.tc_matmul.ops import count_triangles_dense, prepare_lower
from repro.kernels.tc_matmul.ref import tc_matmul_ref


# --- ell_spmv ---------------------------------------------------------------

@pytest.mark.parametrize("n,d,block", [(64, 8, 32), (128, 16, 64), (96, 24, 32)])
@pytest.mark.parametrize("semiring", ["minplus", "plustimes"])
def test_ell_spmv_sweep(n, d, block, semiring):
    rng = np.random.default_rng(n + d)
    dt = jnp.int32 if semiring == "minplus" else jnp.float32
    cols = jnp.asarray(rng.integers(0, n + 1, size=(n, d)), jnp.int32)
    if semiring == "minplus":
        vals = jnp.asarray(rng.integers(1, 100, size=(n, d)), dt)
        x = jnp.asarray(rng.integers(0, 1000, size=(n + 1,)), dt)
    else:
        vals = jnp.asarray(rng.random((n, d)), dt)
        x = jnp.asarray(rng.random((n + 1,)), dt)
    got = ell_spmv(cols, vals, x, semiring=semiring, block_rows=block)
    ref = ell_spmv_ref(cols, vals, x, semiring=semiring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


def test_relax_matches_bellman_ford_step(g_medium):
    g = g_medium
    cols, wts, block = prepare_ell(g, reverse=True)
    dist = jnp.full((g.num_nodes,), INF_I32, jnp.int32).at[0].set(0)
    # one kernel sweep == one full Bellman-Ford relaxation round
    got = relax_minplus(cols, wts, dist, block_rows=block)
    ref = np.asarray(dist).copy()
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.indices)
    w = np.asarray(g.weights)
    cand = np.where(ref[src] < INF_I32, ref[src] + w, INF_I32)
    np.minimum.at(ref, dst, cand)
    assert np.array_equal(np.asarray(got), ref)


def test_gather_matches_segment_sum(g_social):
    g = g_social
    cols, _, block = prepare_ell(g, reverse=True)
    contrib = jnp.asarray(np.random.default_rng(0).random(g.num_nodes), jnp.float32)
    got = gather_plustimes(cols, contrib, block_rows=block)[: g.num_nodes]
    ref = jax.ops.segment_sum(contrib[g.rev_indices], g.rev_edge_dst,
                              num_segments=g.num_nodes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)


# --- sliced-ELL (degree-bucketed engine layout) ------------------------------

@pytest.fixture(scope="module")
def g_skewed():
    return preferential_attachment(400, m=5, seed=3)


def test_sliced_relax_matches_dense(g_skewed):
    g = g_skewed
    cols, wts, block = prepare_ell(g, reverse=True)
    ell = prepare_sliced_ell(g, reverse=True)
    dist = jnp.full((g.num_nodes,), INF_I32, jnp.int32).at[0].set(0)
    for _ in range(3):   # a few sweeps so non-trivial values propagate
        dense = relax_minplus(cols, wts, dist, block_rows=block)
        sliced = relax_minplus(ell, dist)
        assert np.array_equal(np.asarray(sliced), np.asarray(dense))
        dist = dense


def test_sliced_relax_frontier_push_pull_agree(g_skewed):
    """Forcing push and pull must give bit-identical relaxations."""
    g = g_skewed
    ell = prepare_sliced_ell(g, reverse=True)
    dist = jnp.full((g.num_nodes,), INF_I32, jnp.int32).at[0].set(0)
    for _ in range(4):
        frontier = dist < INF_I32
        push = relax_minplus(ell, dist, frontier=frontier, csr=g,
                             threshold_frac=1.0)    # always push
        pull = relax_minplus(ell, dist, frontier=frontier, csr=g,
                             threshold_frac=0.0)    # always pull
        assert np.array_equal(np.asarray(push), np.asarray(pull))
        dist = push


def test_sliced_bucket_kernel_path(g_skewed, monkeypatch):
    """Force the Pallas-kernel branch of the bucket ops (interpret mode on
    CPU) — off-TPU runs otherwise only exercise the pure-jnp fallback, which
    would leave the real kernel dispatch (block sizing, x blockspec of
    length n+1) untested until first TPU contact."""
    from repro.kernels.ell_spmv import ops as kops
    monkeypatch.setattr(kops, "_USE_KERNEL", True)
    g = g_skewed
    ell = prepare_sliced_ell(g, reverse=True)
    dist = jnp.full((g.num_nodes,), INF_I32, jnp.int32).at[0].set(0)
    cols, wts, block = prepare_ell(g, reverse=True)
    for _ in range(2):
        dense = relax_minplus(cols, wts, dist, block_rows=block)
        sliced = relax_minplus(ell, dist)
        assert np.array_equal(np.asarray(sliced), np.asarray(dense))
        dist = dense
    contrib = jnp.asarray(np.random.default_rng(2).random(g.num_nodes), jnp.float32)
    got = gather_plustimes(ell, contrib)
    ref = jax.ops.segment_sum(contrib[g.rev_indices], g.rev_edge_dst,
                              num_segments=g.num_nodes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)


def test_sliced_gather_matches_segment_sum(g_skewed):
    g = g_skewed
    ell = prepare_sliced_ell(g, reverse=True)
    contrib = jnp.asarray(np.random.default_rng(1).random(g.num_nodes), jnp.float32)
    got = gather_plustimes(ell, contrib)
    ref = jax.ops.segment_sum(contrib[g.rev_indices], g.rev_edge_dst,
                              num_segments=g.num_nodes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)


def test_pad_nodes_rebuilds_edge_key():
    """The cached edge_key encodes num_nodes; pad_nodes must rebuild it or
    is_an_edge silently misses real edges on padded graphs."""
    from repro.core.runtime import is_an_edge
    from repro.graph import from_edges, pad_nodes
    g = from_edges(10, np.array([1, 2, 3]), np.array([2, 3, 4]))
    gp = pad_nodes(g, 8)
    assert gp.num_nodes == 16
    u = jnp.asarray([1, 2, 3, 4])
    w = jnp.asarray([2, 3, 4, 5])
    expect = np.array([True, True, True, False])
    assert np.array_equal(np.asarray(is_an_edge(g, u, w)), expect)
    assert np.array_equal(np.asarray(is_an_edge(gp, u, w)), expect)


def test_sliced_padded_cells_bounded(g_skewed):
    """Bucketing must keep padded slots near O(E), far under N·max_deg."""
    g = g_skewed
    ell = prepare_sliced_ell(g, reverse=True)
    dense_cells = g.num_nodes * max(g.max_in_degree, 1)
    assert ell.padded_cells() <= 0.25 * dense_cells
    assert ell.padded_cells() >= g.num_edges - ell.hub_cols.shape[0]


# --- tc_matmul ----------------------------------------------------------------

@pytest.mark.parametrize("n,block", [(64, 32), (128, 64), (128, 128)])
def test_tc_matmul_sweep(n, block):
    rng = np.random.default_rng(n)
    a = (rng.random((n, n)) < 0.1).astype(np.float32)
    lower = jnp.asarray(np.tril(a, -1))
    got = float(tc_matmul(lower, block=block))
    ref = float(tc_matmul_ref(lower))
    assert got == ref


def test_tc_dense_vs_networkx(g_social):
    import networkx as nx
    lower = prepare_lower(g_social, block=64)
    got = int(count_triangles_dense(lower, block=64))
    G = nx.Graph()
    G.add_edges_from(zip(np.asarray(g_social.edge_src).tolist(),
                         np.asarray(g_social.indices).tolist()))
    assert got == sum(nx.triangles(G).values()) // 3


# --- flash attention -------------------------------------------------------------

@pytest.mark.parametrize("bh,sq,skv,d", [
    (2, 128, 128, 64), (1, 256, 256, 32), (3, 128, 256, 64), (2, 64, 512, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(bh, sq, skv, d, causal):
    rng = np.random.default_rng(bh * sq)
    q = jnp.asarray(rng.normal(size=(bh, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, skv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, skv, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)
    assert got.dtype == jnp.bfloat16


def test_gqa_grouping():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 8, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 128, 64)), jnp.float32)
    o_k = gqa_attention(q, k, v, use_kernel=True)
    o_r = gqa_attention(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5)
