"""Model-layer numerics: chunked vs sequential linear attention, chunked vs
ref attention, train/decode consistency, checkpoint elastic restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build
from repro.models.attention import chunked_attention
from repro.models.ssm import (chunked_linear_attention, linear_attention_ref)
from repro.kernels.flash_attention.ref import attention_ref


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (128, 32)])
def test_chunked_linear_attention(s, chunk):
    rng = np.random.default_rng(s)
    b, h, n, p = 2, 3, 8, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    la = jnp.asarray(-np.abs(rng.normal(size=(b, s, h))) * 0.5, jnp.float32)
    got = chunked_linear_attention(q, k, v, la, chunk)
    ref = linear_attention_ref(q, k, v, la)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("sq,skv", [(128, 128), (128, 256), (256, 256)])
def test_chunked_attention_matches_ref(sq, skv):
    rng = np.random.default_rng(sq)
    b, h, d = 2, 4, 32
    q = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, skv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, skv, d)), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, q_chunk=64, k_chunk=64)
    ref = attention_ref(q.reshape(b * h, sq, d), k.reshape(b * h, skv, d),
                        v.reshape(b * h, skv, d), causal=True)
    np.testing.assert_allclose(np.asarray(got).reshape(b * h, sq, d),
                               np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("name", ["qwen2.5-3b", "deepseek-moe-16b", "xlstm-1.3b"])
def test_train_decode_consistency(name):
    """Teacher-forced forward's last-token logits ≈ decode-chain logits.
    MoE: capacity dropping is T-dependent by design, so the consistency
    check runs with a capacity factor large enough that nothing drops."""
    import dataclasses
    cfg = ARCHS[name].smoke()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    lf, _ = m.forward(params, {"tokens": toks}, impl="ref", remat=False)
    cache = m.init_cache(1, 8)
    ld = None
    for i in range(8):
        ld, cache = m.decode_step(params, toks[:, i:i + 1], cache, jnp.int32(i))
    err = float(jnp.max(jnp.abs(lf[0, -1] - ld[0])))
    assert err < 0.05, err          # bf16 accumulation tolerance


def test_elastic_checkpoint_restore_other_mesh():
    """Save unsharded, restore with explicit single-device shardings — the
    re-mesh path restores through host numpy + device_put."""
    import tempfile
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train import checkpoint as ckpt, init_state

    cfg = ARCHS["qwen2.5-3b"].smoke()
    m = build(cfg)
    state = init_state(m, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 0, state)
        restored = ckpt.restore(d, 0, state, shardings=shardings)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_moe_capacity_drops_gracefully():
    """With a tiny capacity factor the MoE layer still runs and routes a
    subset of tokens (overflow dropped, never NaN)."""
    import dataclasses
    cfg = dataclasses.replace(ARCHS["deepseek-moe-16b"].smoke(),
                              moe_capacity_factor=0.25)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    logits, aux = m.forward(params, {"tokens": jnp.ones((2, 16), jnp.int32)},
                            impl="ref", remat=False)
    assert bool(jnp.isfinite(logits).all()) and bool(jnp.isfinite(aux))


def test_serve_engine_generates():
    from repro.serve import ServeEngine
    cfg = ARCHS["qwen2.5-3b"].smoke()
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, max_len=32, batch_size=2)
    prompts = np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    res = eng.generate(prompts, new_tokens=6)
    assert res.tokens.shape == (2, 10)
    assert np.array_equal(res.tokens[:, :4], prompts)
