"""Beyond-paper DSL program: connected components (label propagation).
Shows the language is not hard-wired to the four published algorithms."""
import networkx as nx
import numpy as np
import pytest

from repro.core import compile_bundled


def _cc_ref(g):
    G = nx.Graph()
    G.add_nodes_from(range(g.num_nodes))
    G.add_edges_from(zip(np.asarray(g.edge_src).tolist(),
                         np.asarray(g.indices).tolist()))
    ref = np.zeros(g.num_nodes, np.int64)
    for comp in nx.connected_components(G):
        ref[list(comp)] = min(comp)
    return ref


@pytest.mark.parametrize("gname", ["RD", "SW"])   # undirected families
def test_cc_matches_networkx(graph_suite, gname):
    g = graph_suite[gname]
    out = compile_bundled("cc")(g)
    comp = np.asarray(out["comp"]).astype(np.int64)
    assert np.array_equal(comp, _cc_ref(g))
    assert bool(out["finished"])


def test_cc_two_components():
    from repro.graph import from_edges
    g = from_edges(6, np.array([0, 1, 3, 4]), np.array([1, 2, 4, 5]),
                   undirected=True)
    comp = np.asarray(compile_bundled("cc")(g)["comp"])
    assert comp.tolist() == [0, 0, 0, 3, 3, 3]


def test_cc_pallas_backend(graph_suite):
    g = graph_suite["SW"]
    out_l = compile_bundled("cc", backend="local")(g)
    out_p = compile_bundled("cc", backend="pallas")(g)
    assert np.array_equal(np.asarray(out_l["comp"]), np.asarray(out_p["comp"]))
