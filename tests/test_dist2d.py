"""2-D adjacency-partitioned kernels (core/dist2d) vs the NumPy oracles.

The 2-D path blocks the adjacency over an R x C grid and moves O(N/C)
bytes per collective instead of the 1-D backend's O(N); correctness must
not depend on the grid shape, on N dividing the device count, or on the
graph's diameter. This module sweeps those axes on the 8 forced host
devices (see conftest.py); test_distributed.py keeps the one-shape smoke
next to the 1-D agreement tests.
"""
import jax
import numpy as np
import pytest

from repro.core.dist2d import pagerank_2d, sssp_2d
from repro.graph import road, uniform_random
from repro.graph.algorithms_ref import pagerank_ref, sssp_ref

# grid shapes with 8, 4, and 2 devices: column-count c (the collective
# divisor) varies from 1 to 4, and the single-row / single-column edges
# degenerate toward 1-D partitioning in each direction
MESHES = [(4, 2), (2, 4), (2, 2), (8, 1), (1, 8), (2, 1), (1, 2)]


def _mesh(r, c):
    return jax.make_mesh((r, c), ("data", "model"))


@pytest.fixture(scope="module")
def g(eight_devices):
    # N=100 never divides 8 evenly -> every shape exercises piece padding
    return uniform_random(100, 5, seed=2)


@pytest.fixture(scope="module")
def local_refs(g):
    return {"sssp0": sssp_ref(g, 0).astype(np.int32),
            "sssp17": sssp_ref(g, 17).astype(np.int32),
            "pr": pagerank_ref(g)}


@pytest.mark.parametrize("r,c", MESHES)
def test_sssp_2d_agrees(g, local_refs, r, c):
    assert np.array_equal(np.asarray(sssp_2d(g, _mesh(r, c), 0)),
                          local_refs["sssp0"])


@pytest.mark.parametrize("r,c", [(4, 2), (1, 8)])
def test_sssp_2d_nonzero_source(g, local_refs, r, c):
    assert np.array_equal(np.asarray(sssp_2d(g, _mesh(r, c), 17)),
                          local_refs["sssp17"])


@pytest.mark.parametrize("r,c", MESHES)
def test_pagerank_2d_agrees(g, local_refs, r, c):
    assert np.allclose(np.asarray(pagerank_2d(g, _mesh(r, c))),
                       local_refs["pr"], atol=1e-5)


def test_sssp_2d_deep_graph(eight_devices):
    # high-diameter road grid: many BSP supersteps through the while_loop
    gr = road(10, seed=3)
    assert np.array_equal(np.asarray(sssp_2d(gr, _mesh(2, 4), 0)),
                          sssp_ref(gr, 0).astype(np.int32))


def test_pagerank_2d_respects_maxiter(g, eight_devices):
    # one sweep from the uniform init is the damped one-step power iterate;
    # the 2-D path must honor max_iter exactly, not just convergence
    one = np.asarray(pagerank_2d(g, _mesh(2, 2), max_iter=1))
    ref = pagerank_ref(g, max_iter=1)
    assert np.allclose(one, ref, atol=1e-6)
    assert not np.allclose(one, pagerank_ref(g), atol=1e-5)
