"""Batched multi-source traversal engine + its satellite fixes.

Covers the [B, N] runtime primitives against their sequential counterparts,
the SpMM ([N+1, B] operand) form of the ELL kernel, the weakref-keyed
per-graph ELL cache of the pallas backend, and the large-graph (N² ≥ 2³¹)
edge-membership path that replaced the int32 composite key.
"""
import gc
import weakref

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compile_bundled, runtime as rt
from repro.graph import from_edges, preferential_attachment, uniform_random
from repro.graph.csr import INF_I32
from repro.kernels.ell_spmv import ops as kops
from repro.kernels.ell_spmv.kernel import ell_spmv


@pytest.fixture(scope="module")
def g_pl():
    return preferential_attachment(500, m=5, seed=7)


# --- batched runtime primitives ---------------------------------------------

def test_bfs_levels_batch_rows_match_sequential(g_pl):
    srcs = jnp.asarray(np.array([0, 3, 250, 499], np.int32))
    lv_b, _ = rt.bfs_levels_batch(g_pl, srcs)
    for i, s in enumerate(np.asarray(srcs)):
        lv, _ = rt.bfs_levels(g_pl, int(s))
        assert np.array_equal(np.asarray(lv_b)[i], np.asarray(lv)), f"row {i}"


def test_relax_hybrid_batch_rows_match_sequential(g_pl):
    g = g_pl
    srcs = np.array([0, 17, 499], np.int32)
    b, n = len(srcs), g.num_nodes
    dist = jnp.full((b, n), INF_I32, jnp.int32).at[jnp.arange(b), jnp.asarray(srcs)].set(0)
    fr = dist == 0
    for _ in range(4):   # a few steps so push AND pull rows both occur
        dist2 = rt.relax_minplus_hybrid_batch(g, dist, fr)
        for i, s in enumerate(srcs):
            d1 = rt.relax_minplus_hybrid(g, dist[i], fr[i])
            assert np.array_equal(np.asarray(dist2)[i], np.asarray(d1)), f"row {i}"
        fr = dist2 < dist
        dist = dist2


def test_sssp_multi_matches_oracle(g_pl):
    from repro.graph.algorithms_ref import sssp_ref
    srcs = np.array([0, 100, 499], np.int32)
    dist = np.asarray(rt.sssp_multi(g_pl, srcs))
    for i, s in enumerate(srcs):
        assert np.array_equal(dist[i], sssp_ref(g_pl, int(s)).astype(np.int32))


# --- SpMM kernel ([N+1, B] operand) ------------------------------------------

@pytest.mark.parametrize("semiring", ["minplus", "plustimes"])
def test_ell_spmm_columns_match_spmv(semiring):
    rng = np.random.default_rng(5)
    n, d, b = 64, 8, 5
    dt = jnp.int32 if semiring == "minplus" else jnp.float32
    cols = jnp.asarray(rng.integers(0, n + 1, size=(n, d)), jnp.int32)
    vals = jnp.asarray(rng.integers(1, 90, size=(n, d)), dt)
    x = jnp.asarray(rng.integers(0, 900, size=(n + 1, b)), dt)
    mm = ell_spmv(cols, vals, x, semiring=semiring, block_rows=32)
    assert mm.shape == (n, b)
    for j in range(b):
        mv = ell_spmv(cols, vals, x[:, j], semiring=semiring, block_rows=32)
        np.testing.assert_allclose(np.asarray(mm)[:, j], np.asarray(mv), rtol=1e-6)


def test_batched_sliced_relax_and_gather(g_pl):
    g = g_pl
    ell = kops.prepare_sliced_ell(g, reverse=True)
    srcs = np.array([0, 9, 499], np.int32)
    b, n = len(srcs), g.num_nodes
    dist = jnp.full((b, n), INF_I32, jnp.int32).at[jnp.arange(b), jnp.asarray(srcs)].set(0)
    fr = dist == 0
    for _ in range(3):
        d2 = kops.relax_minplus(ell, dist, frontier=fr, csr=g)
        for i in range(b):
            d1 = kops.relax_minplus(ell, dist[i], frontier=fr[i], csr=g)
            assert np.array_equal(np.asarray(d2)[i], np.asarray(d1)), f"row {i}"
        fr = d2 < dist
        dist = d2
    contrib = jnp.asarray(np.random.default_rng(1).random((b, n)), jnp.float32)
    gb = kops.gather_plustimes(ell, contrib)
    for i in range(b):
        np.testing.assert_allclose(np.asarray(gb)[i],
                                   np.asarray(kops.gather_plustimes(ell, contrib[i])),
                                   atol=1e-5)


@pytest.mark.parametrize("backend", ["local", "pallas"])
def test_degenerate_source_sets(backend):
    """Empty, singleton, and duplicate source sets: the chunked batched loop
    (padding lanes, zero-trip guard) must match the sequential lowering."""
    g = from_edges(40, np.arange(39), np.arange(1, 40),
                   np.ones(39, np.int64), undirected=True)
    for srcs in [np.array([], np.int32), np.array([7], np.int32),
                 np.array([3, 3, 3], np.int32)]:
        b = compile_bundled("bc", backend=backend, batch_sources=4)(g, sourceSet=srcs)
        s = compile_bundled("bc", backend=backend, batch_sources=1)(g, sourceSet=srcs)
        np.testing.assert_allclose(np.asarray(b["BC"]), np.asarray(s["BC"]),
                                   atol=1e-5, err_msg=str(srcs))


# --- per-graph GraphContext registry (weakref regression) ---------------------
# These were originally written against the pallas backend's private
# `fn._ell_cache` closure; the derived views now live in the shared
# GraphContext registry (repro.core.context), same weakref discipline.

def test_graph_context_evicts_on_gc():
    from repro.core import context
    prog = compile_bundled("sssp", backend="pallas")
    g1 = uniform_random(64, 4, seed=11)
    g2 = uniform_random(72, 4, seed=12)
    base = context.registry_size()
    prog(g1, src=0)
    prog(g2, src=0)
    assert context.contains(g1) and context.contains(g2)
    assert context.registry_size() == base + 2
    del g1, g2
    gc.collect()
    assert context.registry_size() == base, \
        "dead graphs must not pin their derived views"


def test_graph_context_survives_id_reuse():
    """A stale registry entry under a reused id must be detected (the
    weakref no longer resolves to the argument) and rebuilt, not served as
    an alias of the dead graph's views."""
    from repro.core import context
    from repro.core.context import GraphContext
    prog = compile_bundled("sssp", backend="pallas")
    g = uniform_random(64, 4, seed=13)

    class _Dead:
        pass

    stale = GraphContext(_Dead())
    context._REGISTRY[id(g)] = (weakref.ref(_Dead()), stale)
    assert not context.contains(g)
    out = prog(g, src=0)
    assert context.contains(g)
    assert context._REGISTRY[id(g)][1] is not stale
    ref = compile_bundled("sssp", backend="local")(g, src=0)
    assert np.array_equal(np.asarray(out["dist"]), np.asarray(ref["dist"]))


# --- large-graph edge membership (int32 key would overflow) -------------------

def test_edge_membership_paths_agree(g_pl):
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.integers(0, g_pl.num_nodes, 400).astype(np.int32))
    w = jnp.asarray(rng.integers(0, g_pl.num_nodes, 400).astype(np.int32))
    keyed = np.asarray(rt._is_an_edge_keyed(g_pl, u, w))
    searched = np.asarray(rt._is_an_edge_rowsearch(g_pl, u, w))
    assert np.array_equal(keyed, searched)
    assert keyed.any(), "queries should hit at least one real edge"


def test_is_an_edge_and_tc_beyond_46k_nodes():
    """N = 47000 > 46341 ⇒ N² overflows int32: the composite-key fast path is
    invalid and is_an_edge / TC must take the row-range binary search."""
    n = 47_000
    ring_src = np.arange(n, dtype=np.int64)
    ring_dst = (ring_src + 1) % n
    # five chords i→i+2 forming triangles (i, i+1, i+2), far from the wrap
    chord_i = np.array([10, 1000, 20_000, 30_000, 46_000], np.int64)
    src = np.concatenate([ring_src, chord_i])
    dst = np.concatenate([ring_dst, chord_i + 2])
    g = from_edges(n, src, dst, np.ones(len(src), np.int64), undirected=True)
    assert not rt._edge_key_fits_i32(g.num_nodes)
    hits = np.asarray(rt.is_an_edge(
        g, jnp.asarray(np.array([10, 10, 46_000, 5], np.int32)),
        jnp.asarray(np.array([12, 13, 46_002, 9], np.int32))))
    assert hits.tolist() == [True, False, True, False]
    assert int(rt.wedge_count(g)) == len(chord_i)
    out = compile_bundled("tc", backend="local")(g)
    assert int(out["triangle_count"]) == len(chord_i)
