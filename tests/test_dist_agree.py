"""Multi-shard agreement matrix: every bundled program, distributed vs
local, across shard counts {1, 2, 4, 8} — in-process, on the 8 forced host
devices the shared conftest sets up.

N is prime (101), so no shard count > 1 divides it: every mesh in the
matrix exercises the padded last block. The distributed runs use the
frontier-compressed "auto" exchange policy (the new path); the dense
baseline is pinned against the same references in test_distributed.py,
and dense-vs-compact equivalence per schedule is covered by the
hypothesis test in test_property.py.
"""
import numpy as np
import pytest

from repro.core import Schedule, compile_bundled, dist

PROGRAMS = ["sssp", "sssp_pull", "pr", "tc", "bc", "cc", "ppr", "lp",
            "kcore"]
SHARDS = [1, 2, 4, 8]

# the distributed schedule under test: compressed exchange + adaptive
# direction — every new knob on at once
DIST_SCHED = Schedule(dist_frontier="auto", direction="auto")


def _params(name, g):
    if name in ("sssp", "sssp_pull"):
        return dict(src=0)
    if name == "pr":
        return dict(beta=1e-4, delta=0.85, maxIter=60)
    if name == "bc":
        return dict(sourceSet=np.array([0, 7, 23], np.int32))
    if name == "ppr":
        return dict(beta=1e-4, delta=0.85, maxIter=60,
                    sourceSet=np.array([0, 7, 23], np.int32))
    if name == "kcore":
        return dict(k=2)
    return {}


_OUT_KEY = {"sssp": "dist", "sssp_pull": "dist", "pr": "pageRank",
            "tc": "triangle_count", "bc": "BC", "cc": "comp",
            "ppr": "ppr", "lp": "label", "kcore": "core"}


@pytest.fixture(scope="module")
def g_prime(eight_devices):
    from repro.graph import uniform_random
    return uniform_random(101, 5, seed=2)


@pytest.fixture(scope="module")
def local_refs(g_prime):
    """One local-backend run per program — the agreement oracle."""
    refs = {}
    for name in PROGRAMS:
        prog = compile_bundled(name, backend="local")
        refs[name] = np.asarray(
            prog(g_prime, **_params(name, g_prime))[_OUT_KEY[name]])
    return refs


@pytest.mark.parametrize("shards", SHARDS)
@pytest.mark.parametrize("name", PROGRAMS)
def test_distributed_agrees_with_local(name, shards, g_prime, local_refs):
    prog = compile_bundled(name, backend="distributed", schedule=DIST_SCHED)
    mesh = dist.make_mesh_1d(shards)
    out = np.asarray(prog.bind(g_prime, mesh=mesh)(
        **_params(name, g_prime))[_OUT_KEY[name]])
    ref = local_refs[name]
    if ref.dtype.kind == "f":
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{name} @ {shards} shards")
    else:
        assert np.array_equal(out, ref), f"{name} @ {shards} shards"


def test_context_owns_per_shard_partition_views(g_prime):
    """One graph serves every mesh in the matrix through its single
    GraphContext: the 1-D partitions are memoized per shard count, so
    binding the same (program, shard count) twice builds nothing new."""
    from repro.core import get_context
    prog = compile_bundled("sssp", backend="distributed", schedule=DIST_SCHED)
    for shards in SHARDS:
        prog.bind(g_prime, mesh=dist.make_mesh_1d(shards))
    ctx = get_context(g_prime)
    keys = {k[1] for k in ctx.view_keys() if k[0] == "dist_1d"}
    assert set(SHARDS) <= keys
    before = len(ctx.view_keys())
    prog.bind(g_prime, mesh=dist.make_mesh_1d(4))   # memoized: no new views
    assert len(ctx.view_keys()) == before


def test_delta_priority_on_weighted_grid(eight_devices):
    """Delta-stepping distributed: the bucketed frontier plus the
    priority-sliced exchange must agree with the local monotonic oracle on
    the weighted-grid family the schedule targets, under both the dense
    and the compressed exchange policies."""
    from repro.graph.algorithms_ref import sssp_ref
    from repro.graph.generators import road
    g = road(16, seed=7)
    ref = sssp_ref(g, 0).astype(np.int32)
    mesh = dist.make_mesh_1d(4)
    for frontier in ("dense", "auto"):
        sched = Schedule(priority="delta", delta_bucket=150,
                         dist_frontier=frontier, direction="auto")
        prog = compile_bundled("sssp", backend="distributed", schedule=sched)
        out = prog.bind(g, mesh=mesh)(src=0)
        assert np.array_equal(np.asarray(out["dist"]), ref), frontier
        # bucket advance is collective on every policy; the exchange is
        # priority-sliced only on the compressed path (dense publishes the
        # full fresh view, which needs no slicing)
        assert "rtd.min_global" in prog.source
        assert ("within=" in prog.source) == (frontier == "auto"), frontier


def test_exchange_within_ships_only_window_entries(eight_devices):
    """Unit contract of the priority-sliced compact exchange: changed
    entries inside `within` ship; changed entries outside are withheld
    (deferred until their bucket opens — the full view stays stale for
    them); the fused pair buffer still costs exactly 2*cap*P elements."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as PS

    from repro.core import runtime_dist as rtd
    P, B = 8, 16
    n_pad = P * B                                   # 128
    mesh = dist.make_mesh_1d(P)
    idx = np.arange(n_pad)
    changed = idx % B < 4                           # 4 changed per shard
    window = idx % B < 2                            # ...2 of them in-window
    full_prev = jnp.full(n_pad, 100, jnp.int32)
    blk = jnp.where(changed, 50, 100).astype(jnp.int32)
    own = jnp.arange(n_pad, dtype=jnp.int32)

    def body(fp, b, w, o):
        return rtd.exchange(fp, b, o, 0.25, skip_empty=False, within=w)

    out, elems = jax.jit(rtd.shard_map(
        body, mesh=mesh,
        in_specs=(PS(), PS("data"), PS("data"), PS("data")),
        out_specs=(PS(), PS())))(
            full_prev, blk, jnp.asarray(window), own)
    out = np.asarray(out)
    assert (out[window] == 50).all()                # in-window changes ship
    assert (out[changed & ~window] == 100).all()    # out-of-window deferred
    assert (out[~changed] == 100).all()
    cap = rtd.compact_cap(B, 0.25)
    assert 2 * cap * P < n_pad, "setup must stay on the compact path"
    assert int(elems) == 2 * cap * P


def test_comm_volume_counter_monotone_in_policy(g_prime):
    """The generated `_gather_elems` counter: the compressed policies never
    move MORE property-exchange elements than the dense baseline, and the
    empty-skip ("auto") never more than plain compact."""
    mesh = dist.make_mesh_1d(8)
    elems = {}
    for pol in ("dense", "compact", "auto"):
        prog = compile_bundled("sssp", backend="distributed",
                               schedule=Schedule(dist_frontier=pol))
        elems[pol] = int(prog.bind(g_prime, mesh=mesh)(src=0)["_gather_elems"])
    assert elems["compact"] <= elems["dense"]
    assert elems["auto"] <= elems["compact"]
    assert elems["auto"] < elems["dense"], elems
