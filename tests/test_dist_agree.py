"""Multi-shard agreement matrix: every bundled program, distributed vs
local, across shard counts {1, 2, 4, 8} — in-process, on the 8 forced host
devices the shared conftest sets up.

N is prime (101), so no shard count > 1 divides it: every mesh in the
matrix exercises the padded last block. The distributed runs use the
frontier-compressed "auto" exchange policy (the new path); the dense
baseline is pinned against the same references in test_distributed.py,
and dense-vs-compact equivalence per schedule is covered by the
hypothesis test in test_property.py.
"""
import numpy as np
import pytest

from repro.core import Schedule, compile_bundled, dist

PROGRAMS = ["sssp", "sssp_pull", "pr", "tc", "bc", "cc"]
SHARDS = [1, 2, 4, 8]

# the distributed schedule under test: compressed exchange + adaptive
# direction — every new knob on at once
DIST_SCHED = Schedule(dist_frontier="auto", direction="auto")


def _params(name, g):
    if name in ("sssp", "sssp_pull"):
        return dict(src=0)
    if name == "pr":
        return dict(beta=1e-4, delta=0.85, maxIter=60)
    if name == "bc":
        return dict(sourceSet=np.array([0, 7, 23], np.int32))
    return {}


_OUT_KEY = {"sssp": "dist", "sssp_pull": "dist", "pr": "pageRank",
            "tc": "triangle_count", "bc": "BC", "cc": "comp"}


@pytest.fixture(scope="module")
def g_prime(eight_devices):
    from repro.graph import uniform_random
    return uniform_random(101, 5, seed=2)


@pytest.fixture(scope="module")
def local_refs(g_prime):
    """One local-backend run per program — the agreement oracle."""
    refs = {}
    for name in PROGRAMS:
        prog = compile_bundled(name, backend="local")
        refs[name] = np.asarray(
            prog(g_prime, **_params(name, g_prime))[_OUT_KEY[name]])
    return refs


@pytest.mark.parametrize("shards", SHARDS)
@pytest.mark.parametrize("name", PROGRAMS)
def test_distributed_agrees_with_local(name, shards, g_prime, local_refs):
    prog = compile_bundled(name, backend="distributed", schedule=DIST_SCHED)
    mesh = dist.make_mesh_1d(shards)
    out = np.asarray(prog.bind(g_prime, mesh=mesh)(
        **_params(name, g_prime))[_OUT_KEY[name]])
    ref = local_refs[name]
    if ref.dtype.kind == "f":
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{name} @ {shards} shards")
    else:
        assert np.array_equal(out, ref), f"{name} @ {shards} shards"


def test_context_owns_per_shard_partition_views(g_prime):
    """One graph serves every mesh in the matrix through its single
    GraphContext: the 1-D partitions are memoized per shard count, so
    binding the same (program, shard count) twice builds nothing new."""
    from repro.core import get_context
    prog = compile_bundled("sssp", backend="distributed", schedule=DIST_SCHED)
    for shards in SHARDS:
        prog.bind(g_prime, mesh=dist.make_mesh_1d(shards))
    ctx = get_context(g_prime)
    keys = {k[1] for k in ctx.view_keys() if k[0] == "dist_1d"}
    assert set(SHARDS) <= keys
    before = len(ctx.view_keys())
    prog.bind(g_prime, mesh=dist.make_mesh_1d(4))   # memoized: no new views
    assert len(ctx.view_keys()) == before


def test_comm_volume_counter_monotone_in_policy(g_prime):
    """The generated `_gather_elems` counter: the compressed policies never
    move MORE property-exchange elements than the dense baseline, and the
    empty-skip ("auto") never more than plain compact."""
    mesh = dist.make_mesh_1d(8)
    elems = {}
    for pol in ("dense", "compact", "auto"):
        prog = compile_bundled("sssp", backend="distributed",
                               schedule=Schedule(dist_frontier=pol))
        elems[pol] = int(prog.bind(g_prime, mesh=mesh)(src=0)["_gather_elems"])
    assert elems["compact"] <= elems["dense"]
    assert elems["auto"] <= elems["compact"]
    assert elems["auto"] < elems["dense"], elems
