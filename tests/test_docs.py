"""Docs lint: the schedule knob table tracks `Schedule`, and links resolve.

This is the CI "docs-lint" step: documentation for the tuning surface is
load-bearing (the autotuner, benchmarks, and README all point at it), so
drift between `docs/schedule.md` and `dataclasses.fields(Schedule)` — or
a dead relative link anywhere under docs/ — fails the suite.
"""
import dataclasses
import os
import re

import pytest

from repro.schedule import Schedule
from repro.serve import ServiceConfig

DOCS_DIR = os.path.join(os.path.dirname(__file__), "..", "docs")
REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

DOC_PAGES = ["architecture.md", "schedule.md", "dsl.md", "serving.md",
             "analysis.md"]


def _read(page):
    with open(os.path.join(DOCS_DIR, page)) as f:
        return f.read()


def test_docs_pages_exist():
    for page in DOC_PAGES:
        assert os.path.exists(os.path.join(DOCS_DIR, page)), page


def test_schedule_knob_table_matches_dataclass_fields():
    """Every `Schedule` field has a knob-table row in docs/schedule.md and
    vice versa — adding/removing a knob without documenting it fails."""
    text = _read("schedule.md")
    # knob-table rows: "| `name` | type | default | ..."
    documented = set(re.findall(r"^\| `([a-z_]+)` \|", text, re.MULTILINE))
    actual = {f.name for f in dataclasses.fields(Schedule)}
    assert documented == actual, (
        f"docs/schedule.md knob table is out of sync with Schedule: "
        f"undocumented={sorted(actual - documented)}, "
        f"stale={sorted(documented - actual)}")


def test_schedule_knob_defaults_documented_correctly():
    """The `default` column restates the real dataclass defaults."""
    text = _read("schedule.md")
    rows = re.findall(r"^\| `([a-z_]+)` \| [^|]+ \| `([^`]+)`", text,
                      re.MULTILINE)
    defaults = {f.name: f.default for f in dataclasses.fields(Schedule)}
    assert rows, "knob table not found"
    for name, doc_default in rows:
        actual = defaults[name]
        # the doc may annotate the value (e.g. "0.0625 (1/16)"); the literal
        # before any annotation must equal repr/str of the actual default
        lead = doc_default.split()[0].strip('"')
        assert lead in (repr(actual), str(actual)), (
            f"documented default for {name!r} is {doc_default!r}, "
            f"actual is {actual!r}")


def _serving_knob_section():
    """The text of docs/serving.md's ServiceConfig section only (the page
    has other tables — query kinds — that are not knob rows)."""
    text = _read("serving.md")
    m = re.search(r"## ServiceConfig knobs\n(.*?)(?:\n## |\Z)", text,
                  re.DOTALL)
    assert m, "docs/serving.md lost its '## ServiceConfig knobs' section"
    return m.group(1)


def test_serving_knob_table_matches_service_config_fields():
    """Every `ServiceConfig` field has a knob-table row in docs/serving.md
    and vice versa — adding a serving knob without documenting it fails."""
    documented = set(re.findall(r"^\| `([a-z_]+)` \|",
                                _serving_knob_section(), re.MULTILINE))
    actual = {f.name for f in dataclasses.fields(ServiceConfig)}
    assert documented == actual, (
        f"docs/serving.md knob table is out of sync with ServiceConfig: "
        f"undocumented={sorted(actual - documented)}, "
        f"stale={sorted(documented - actual)}")


def test_serving_knob_defaults_documented_correctly():
    rows = re.findall(r"^\| `([a-z_]+)` \| [^|]+ \| `([^`]+)`",
                      _serving_knob_section(), re.MULTILINE)
    defaults = {f.name: f.default for f in dataclasses.fields(ServiceConfig)}
    assert len(rows) == len(defaults), "knob table rows missing or unparsed"
    for name, doc_default in rows:
        actual = defaults[name]
        lead = doc_default.split()[0].strip('"')
        assert lead in (repr(actual), str(actual)), (
            f"documented default for {name!r} is {doc_default!r}, "
            f"actual is {actual!r}")


@pytest.mark.parametrize("page", DOC_PAGES)
def test_relative_links_resolve(page):
    """Every relative markdown link in docs/*.md points at a real file
    (anchors are stripped; absolute URLs are skipped)."""
    text = _read(page)
    links = re.findall(r"\[[^\]]*\]\(([^)]+)\)", text)
    assert links, f"{page} has no links at all?"
    for target in links:
        if target.startswith(("http://", "https://", "#")):
            continue
        path = target.split("#")[0]
        resolved = os.path.normpath(os.path.join(DOCS_DIR, path))
        assert os.path.exists(resolved), (
            f"{page}: dead relative link {target!r} -> {resolved}")


def test_readme_links_docs_pages():
    with open(os.path.join(REPO_ROOT, "README.md")) as f:
        readme = f.read()
    for page in DOC_PAGES:
        assert f"docs/{page}" in readme, f"README does not link docs/{page}"
    # the inline knob section was replaced by the docs pointer — knob
    # documentation lives in one place now
    assert "docs/schedule.md" in readme


def test_readme_relative_links_resolve():
    with open(os.path.join(REPO_ROOT, "README.md")) as f:
        text = f.read()
    for target in re.findall(r"\[[^\]]*\]\(([^)]+)\)", text):
        if target.startswith(("http://", "https://", "#")):
            continue
        path = target.split("#")[0]
        resolved = os.path.normpath(os.path.join(REPO_ROOT, path))
        assert os.path.exists(resolved), f"README: dead link {target!r}"


def test_analysis_code_table_matches_registry():
    """Every `SPxxx` code in the diagnostics registry has a table row in
    docs/analysis.md with the matching severity, and vice versa — adding a
    diagnostic without documenting it fails."""
    from repro.core.analysis import REGISTRY
    rows = re.findall(r"^\| `(SP\d+)` \| (error|warning) \|",
                      _read("analysis.md"), re.MULTILINE)
    documented = {code: sev for code, sev in rows}
    actual = {code: sev for code, (sev, _) in REGISTRY.items()}
    assert documented == actual, (
        f"docs/analysis.md code table is out of sync with the diagnostics "
        f"registry: undocumented={sorted(set(actual) - set(documented))}, "
        f"stale={sorted(set(documented) - set(actual))}, "
        f"severity_drift={sorted(c for c in set(actual) & set(documented) if actual[c] != documented[c])}")


def test_docs_wikilinks_resolve():
    """`[[page]]`-style cross-references (if any are ever used) resolve to
    docs pages."""
    for page in DOC_PAGES:
        for ref in re.findall(r"\[\[([^\]]+)\]\]", _read(page)):
            name = ref if ref.endswith(".md") else f"{ref}.md"
            assert os.path.exists(os.path.join(DOCS_DIR, name)), (
                f"{page}: unresolved [[{ref}]]")
