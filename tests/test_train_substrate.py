"""Training substrate: optimizer schedules, convergence, checkpoint
fault-tolerance (restart + elastic re-mesh), data determinism."""
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build
from repro.train import (OptimizerConfig, checkpoint as ckpt, init_state,
                         lr_at, make_train_step)
from repro.train.data import DataConfig, batch_at


def test_wsd_schedule_shape():
    oc = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                         schedule="wsd", wsd_decay_frac=0.2, min_lr_frac=0.1)
    assert float(lr_at(oc, 0)) == 0.0
    assert float(lr_at(oc, 10)) == pytest.approx(1.0)
    assert float(lr_at(oc, 50)) == pytest.approx(1.0)      # stable plateau
    assert float(lr_at(oc, 79)) == pytest.approx(1.0, abs=0.06)
    assert float(lr_at(oc, 100)) == pytest.approx(0.1)     # decayed floor


def test_cosine_schedule_monotone_tail():
    oc = OptimizerConfig(lr=1.0, warmup_steps=5, total_steps=50, schedule="cosine")
    lrs = [float(lr_at(oc, s)) for s in range(5, 51, 5)]
    assert all(a >= b for a, b in zip(lrs, lrs[1:]))


def test_loss_decreases_20_steps():
    cfg = ARCHS["qwen2.5-3b"].smoke()
    m = build(cfg)
    state = init_state(m, jax.random.PRNGKey(0))
    oc = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    step = jax.jit(make_train_step(m, oc, microbatches=2, impl="ref"))
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, structure=8)
    first = last = None
    for i in range(20):
        state, metrics = step(state, batch_at(dc, i))
        if i == 0:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first


def test_grad_accumulation_consistency():
    """microbatches=1 vs 4 must produce (nearly) identical updates."""
    cfg = ARCHS["qwen2.5-3b"].smoke()
    m = build(cfg)
    oc = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10, grad_clip=0.0)
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8)
    batch = batch_at(dc, 0)
    outs = []
    for mb in (1, 4):
        state = init_state(m, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(m, oc, microbatches=mb, impl="ref"))
        state, metrics = step(state, batch)
        outs.append((float(metrics["loss"]),
                     np.asarray(jax.tree.leaves(state.params)[0], np.float32)))
    assert outs[0][0] == pytest.approx(outs[1][0], rel=1e-3)
    np.testing.assert_allclose(outs[0][1], outs[1][1], atol=5e-3)


def test_checkpoint_restart_resumes_identically():
    """Train 6 steps straight vs train 3 + crash + restore + 3 (fault
    tolerance): identical final states (data pipeline is stateless)."""
    cfg = ARCHS["qwen2.5-3b"].smoke()
    m = build(cfg)
    oc = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    step = jax.jit(make_train_step(m, oc, impl="ref"))

    state = init_state(m, jax.random.PRNGKey(0))
    for i in range(6):
        state, _ = step(state, batch_at(dc, i))
    straight = state

    with tempfile.TemporaryDirectory() as d:
        state = init_state(m, jax.random.PRNGKey(0))
        for i in range(3):
            state, _ = step(state, batch_at(dc, i))
        ckpt.save(d, 3, state)
        del state                                   # "crash"
        resumed = ckpt.restore(d, ckpt.latest_step(d),
                               init_state(m, jax.random.PRNGKey(0)))
        for i in range(3, 6):
            resumed, _ = step(resumed, batch_at(dc, i))

    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_checkpoint_atomicity_and_retention():
    cfg = ARCHS["qwen2.5-3b"].smoke()
    m = build(cfg)
    state = init_state(m, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            ckpt.save(d, s, state, keep=2)
        assert sorted(ckpt.all_steps(d)) == [3, 4]
        assert not any(x.startswith("tmp-") for x in os.listdir(d))


def test_data_pipeline_deterministic_and_sharded():
    dc = DataConfig(vocab=100, seq_len=16, global_batch=8)
    b1 = batch_at(dc, 5)
    b2 = batch_at(dc, 5)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # shards are disjoint slices of the same global batch definition
    s0 = batch_at(DataConfig(vocab=100, seq_len=16, global_batch=8,
                             num_shards=2, shard=0), 5)
    s1 = batch_at(DataConfig(vocab=100, seq_len=16, global_batch=8,
                             num_shards=2, shard=1), 5)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(s0["tokens"]), np.asarray(s1["tokens"]))
    # labels are next-token shifted
    assert np.array_equal(np.asarray(b1["tokens"][:, 1:]),
                          np.asarray(b1["labels"][:, :-1]))
