"""The async serving layer: coalescing, admission, deadlines, the graph
pool's memory-bounded eviction, bind memoization on the query path, and
TuningStore concurrent-writer safety.

Async tests run real event loops via `asyncio.run` (no plugin dependency);
sweeps execute in worker threads exactly as in production.
"""
import asyncio
import dataclasses
import gc
import os
import time
import weakref

import numpy as np
import pytest

from repro.autotune import (TuningRecord, TuningStore, schedule_to_dict,
                            source_digest)
from repro.core import Schedule, get_context, load_program_source
from repro.graph import preferential_attachment
from repro.graph.algorithms_ref import bc_ref, bfs_levels_ref, sssp_ref
from repro.serve import (GraphService, QueryKind, ServiceConfig,
                         ServiceError, ServiceOverloaded, ServiceTimeout,
                         UnknownGraph, UnknownQueryKind)
from repro.serve.pool import GraphPool


@pytest.fixture(scope="module")
def g_a():
    return preferential_attachment(300, m=4, seed=3)


@pytest.fixture(scope="module")
def g_b():
    return preferential_attachment(200, m=3, seed=5)


class SlowKind(QueryKind):
    """Test kind: a sweep that takes `delay` seconds (off-loop, like jax)."""

    name = "slow"
    per_source = True
    program = None

    def __init__(self, delay=0.25):
        self.delay = delay

    def make_runner(self, handle, sched, width):
        def run(params_list):
            time.sleep(self.delay)
            return [np.int32(p["src"]) for p in params_list]
        return run


class FailKind(QueryKind):
    name = "fail"
    per_source = True
    program = None

    def make_runner(self, handle, sched, width):
        def run(params_list):
            raise ValueError("kaboom")
        return run


# --- the service smoke: 2 graphs, interleaved concurrent queries, oracles ----

def test_service_interleaved_two_graphs_match_oracles(g_a, g_b):
    async def main():
        async with GraphService(ServiceConfig(max_wait_ms=10.0)) as svc:
            svc.register_graph("a", g_a)
            svc.register_graph("b", g_b)
            jobs, expect = [], []
            for s in (0, 5, 9, 17, 42):
                jobs.append(svc.query("a", "sssp", src=s))
                expect.append(("sssp", g_a, s))
                jobs.append(svc.query("b", "sssp", src=s))
                expect.append(("sssp", g_b, s))
                jobs.append(svc.query("a", "bfs", src=s))
                expect.append(("bfs", g_a, s))
            jobs.append(svc.query("b", "bc",
                                  sourceSet=np.array([0, 3, 7], np.int32)))
            res = await asyncio.gather(*jobs)
            for (kind, g, s), out in zip(expect, res):
                ref = (sssp_ref(g, s).astype(np.int32) if kind == "sssp"
                       else bfs_levels_ref(g, s))
                assert np.array_equal(np.asarray(out), ref), (kind, s)
            np.testing.assert_allclose(np.asarray(res[-1]),
                                       bc_ref(g_b, [0, 3, 7]), atol=1e-3)
            return svc.stats()

    st = asyncio.run(main())
    assert st["served"] == 16
    # coalescing actually packed lanes: strictly fewer sweeps than queries
    assert st["sweeps"] < st["served"]
    assert st["max_batch"] > 1
    assert st["rejected"] == 0 and st["timeouts"] == 0


def test_lone_query_flushes_at_deadline_not_full_lane(g_a):
    """A single query must never starve waiting for batch_sources - 1
    lane-mates that will never arrive."""
    async def main():
        cfg = ServiceConfig(max_wait_ms=5.0,
                            schedule=Schedule(batch_sources=64))
        async with GraphService(cfg) as svc:
            svc.register_graph("a", g_a)
            t0 = asyncio.get_running_loop().time()
            out = await svc.query("a", "sssp", src=3)
            dt = asyncio.get_running_loop().time() - t0
            assert np.array_equal(np.asarray(out),
                                  sssp_ref(g_a, 3).astype(np.int32))
            return dt, svc.stats()

    dt, st = asyncio.run(main())
    assert st["sweeps"] == 1 and st["mean_batch"] == 1.0
    assert dt < 30.0    # flushed on the 5 ms deadline (plus sweep + trace)


def test_coalescing_packs_concurrent_queries(g_a):
    async def main():
        cfg = ServiceConfig(schedule=Schedule(batch_sources=8),
                            max_wait_ms=20.0)
        async with GraphService(cfg) as svc:
            svc.register_graph("a", g_a, kinds=["sssp"])
            res = await asyncio.gather(
                *(svc.query("a", "sssp", src=s % 11) for s in range(16)))
            for s, out in zip(range(16), res):
                assert np.array_equal(
                    np.asarray(out), sssp_ref(g_a, s % 11).astype(np.int32))
            return svc.stats()

    st = asyncio.run(main())
    assert st["served"] == 16
    assert st["sweeps"] <= 8            # 16 queries, 8-wide lanes, slack
    assert st["max_batch"] >= 2


def test_coalesce_false_serves_one_query_per_sweep(g_a):
    async def main():
        cfg = ServiceConfig(coalesce=False,
                            schedule=Schedule(batch_sources=8))
        async with GraphService(cfg) as svc:
            svc.register_graph("a", g_a, kinds=["sssp"])
            await asyncio.gather(
                *(svc.query("a", "sssp", src=s) for s in range(6)))
            return svc.stats()

    st = asyncio.run(main())
    assert st["sweeps"] == st["served"] == 6
    assert st["max_batch"] == 1


# --- personalized PageRank through the service --------------------------------

def test_ppr_kind_coalesces_and_matches_oracle(g_a):
    """Concurrent per-user PPR queries pack into one `rt.ppr_multi` sweep;
    every user gets exactly their own restart vector's ranks."""
    from repro.graph.algorithms_ref import ppr_matrix_ref

    async def main():
        cfg = ServiceConfig(schedule=Schedule(batch_sources=4),
                            max_wait_ms=20.0)
        async with GraphService(cfg) as svc:
            svc.register_graph("a", g_a, kinds=["ppr"])
            srcs = [0, 7, 23, 42]
            res = await asyncio.gather(
                *(svc.query("a", "ppr", src=s) for s in srcs))
            ref = ppr_matrix_ref(g_a, srcs)
            for row, out in zip(ref, res):
                np.testing.assert_allclose(np.asarray(out), row,
                                           rtol=1e-4, atol=1e-5)
            return svc.stats()

    st = asyncio.run(main())
    assert st["served"] == 4
    assert st["max_batch"] > 1          # lanes actually shared a sweep


def test_ppr_lone_query_matches_singleton_program(g_a):
    """A lone PPR request takes the compiled singleton-set path (a
    one-element seed set's aggregate IS the user's row)."""
    from repro.graph.algorithms_ref import ppr_matrix_ref

    async def main():
        async with GraphService(ServiceConfig(max_wait_ms=0.0)) as svc:
            svc.register_graph("a", g_a, kinds=["ppr"])
            out = await svc.query("a", "ppr", src=5)
            np.testing.assert_allclose(np.asarray(out),
                                       ppr_matrix_ref(g_a, [5])[0],
                                       rtol=1e-4, atol=1e-5)
            return svc.stats()

    st = asyncio.run(main())
    assert st["sweeps"] == 1 and st["mean_batch"] == 1.0


def test_zero_wait_lone_request_flushes_immediately(g_a):
    """max_wait_ms=0 disables coalesce-waiting entirely: a lone admitted
    request must flush on the first gather pass (deadline already expired),
    never spin or starve waiting for lane-mates."""
    async def main():
        cfg = ServiceConfig(max_wait_ms=0.0,
                            schedule=Schedule(batch_sources=64))
        async with GraphService(cfg) as svc:
            svc.register_graph("a", g_a, kinds=["sssp"])
            t0 = asyncio.get_running_loop().time()
            out = await svc.query("a", "sssp", src=2)
            dt = asyncio.get_running_loop().time() - t0
            assert np.array_equal(np.asarray(out),
                                  sssp_ref(g_a, 2).astype(np.int32))
            return dt, svc.stats()

    dt, st = asyncio.run(main())
    assert st["served"] == 1 and st["mean_batch"] == 1.0
    assert dt < 30.0    # bounded by sweep + trace time, not a hang


# --- admission control, timeouts, failure scatter -----------------------------

def test_admission_sheds_load_beyond_max_pending(g_a):
    async def main():
        cfg = ServiceConfig(max_pending=2, max_wait_ms=0.0)
        svc = GraphService(cfg)
        svc.register_kind(SlowKind(delay=0.3))
        svc.register_graph("a", g_a, kinds=["slow"])
        async with svc:
            t1 = asyncio.create_task(svc.query("a", "slow", src=1))
            t2 = asyncio.create_task(svc.query("a", "slow", src=2))
            await asyncio.sleep(0.05)   # both admitted and in flight
            with pytest.raises(ServiceOverloaded):
                await svc.query("a", "slow", src=3)
            assert svc.stats()["rejected"] == 1
            assert [int(await t) for t in (t1, t2)] == [1, 2]
            # load shed, not wedged: capacity freed, queries flow again
            assert int(await svc.query("a", "slow", src=4)) == 4

    asyncio.run(main())


def test_request_timeout_raises_and_service_recovers(g_a):
    async def main():
        svc = GraphService(ServiceConfig(max_wait_ms=0.0))
        svc.register_kind(SlowKind(delay=0.4))
        svc.register_graph("a", g_a, kinds=["slow"])
        async with svc:
            with pytest.raises(ServiceTimeout):
                await svc.query("a", "slow", src=1, timeout=0.05)
            assert svc.stats()["timeouts"] == 1
            # the timed-out request's sweep result is discarded, the next
            # query is served normally
            assert int(await svc.query("a", "slow", src=2)) == 2

    asyncio.run(main())


def test_sweep_failure_scatters_to_waiters_only(g_a):
    async def main():
        svc = GraphService(ServiceConfig())
        svc.register_kind(FailKind())
        svc.register_graph("a", g_a, kinds=["fail", "sssp"])
        async with svc:
            with pytest.raises(ServiceError, match="kaboom"):
                await svc.query("a", "fail", src=0)
            # other lanes are unaffected
            out = await svc.query("a", "sssp", src=0)
            assert np.array_equal(np.asarray(out),
                                  sssp_ref(g_a, 0).astype(np.int32))

    asyncio.run(main())


def test_unknown_graph_and_kind_errors(g_a):
    async def main():
        async with GraphService() as svc:
            svc.register_graph("a", g_a, kinds=["sssp"])
            with pytest.raises(UnknownGraph, match="nope"):
                await svc.query("nope", "sssp", src=0)
            with pytest.raises(UnknownQueryKind, match="bc"):
                await svc.query("a", "bc", sourceSet=np.array([0]))
            with pytest.raises(ValueError, match="src"):
                await svc.query("a", "sssp", source=3)

    asyncio.run(main())


@pytest.mark.parametrize("bad,match", [
    (dict(backend="distributed"), "backend"),
    (dict(max_wait_ms=-1.0), "max_wait_ms"),
    (dict(max_pending=0), "max_pending"),
    (dict(default_timeout_s=0.0), "default_timeout_s"),
    (dict(max_concurrent_sweeps=0), "max_concurrent_sweeps"),
    (dict(view_budget_bytes=0), "view_budget_bytes"),
])
def test_service_config_validation(bad, match):
    with pytest.raises(ValueError, match=match):
        ServiceConfig(**bad)


# --- GraphContext pool: accounting, LRU eviction, pinning ---------------------

def test_context_view_accounting_and_selective_drop():
    g = preferential_attachment(150, m=3, seed=7)
    ctx = get_context(g)
    ctx.fingerprint()
    ctx.stats()
    assert ctx.total_view_nbytes() == 0       # metadata views are free
    view = ctx.ell()
    assert ctx.total_view_nbytes() > 0
    assert ctx.view_nbytes()[("ell", False)] >= view.cols.nbytes
    freed = ctx.drop_derived_views()
    assert freed > 0 and ctx.total_view_nbytes() == 0
    # metadata survives eviction (it keys persisted tuning records)
    assert ("fingerprint",) in ctx.view_keys()
    assert ("stats",) in ctx.view_keys()
    assert ("ell", False) not in ctx.view_keys()
    assert ctx.ell() is not view              # rebuilt lazily on demand


def test_pool_lru_eviction_frees_views_weakref_observed():
    g1 = preferential_attachment(150, m=3, seed=1)
    g2 = preferential_attachment(150, m=3, seed=2)
    pool = GraphPool(view_budget_bytes=1)
    ctx1, ctx2 = pool.add("one", g1), pool.add("two", g2)
    wref = weakref.ref(ctx1.ell())
    ctx2.ell()
    pool.get("two")                            # "one" is now LRU
    with pool.pin("two"):
        evicted = pool.enforce_budget()
    assert evicted == ["one"], "LRU unpinned graph's views go first"
    gc.collect()
    assert wref() is None, "evicted view must actually be freed"
    assert ctx1.total_view_nbytes() == 0
    assert ctx2.total_view_nbytes() > 0        # pinned graph kept its views


def test_pool_never_evicts_pinned_graph():
    g = preferential_attachment(100, m=3, seed=4)
    pool = GraphPool(view_budget_bytes=1)
    ctx = pool.add("g", g)
    ctx.ell()
    with pool.pin("g"):
        assert pool.enforce_budget() == []
        assert ctx.total_view_nbytes() > 0     # mid-sweep views untouched
    assert pool.enforce_budget() == ["g"]


def test_eviction_then_query_transparently_reprepares(g_a, g_b):
    """Under a 1-byte view budget every sweep evicts the other graph's
    views; queries keep answering correctly (lazy re-prepare), eviction is
    observable in stats, and the evicted sliced-ELL view object dies."""
    async def main():
        cfg = ServiceConfig(backend="pallas", view_budget_bytes=1)
        async with GraphService(cfg) as svc:
            svc.register_graph("a", g_a, kinds=["bc"])
            svc.register_graph("b", g_b, kinds=["bc"])
            wref = weakref.ref(
                svc.handle("a").ctx.sliced_ell(Schedule(), reverse=True))
            srcs = np.array([0, 3], np.int32)
            for name, g in (("a", g_a), ("b", g_b), ("a", g_a)):
                out = await svc.query(name, "bc", sourceSet=srcs)
                np.testing.assert_allclose(np.asarray(out),
                                           bc_ref(g, srcs.tolist()),
                                           atol=1e-3)
            return wref, svc.stats()

    wref, st = asyncio.run(main())
    assert st["evictions"], "the 1-byte budget must have evicted views"
    gc.collect()
    assert wref() is None, "evicted sliced-ELL view must be freed"


# --- TuningStore: warm-reload + concurrent writers ----------------------------

def _record(digest, fingerprint, schedule):
    return TuningRecord(
        source_digest=digest, backend="local", graph_fingerprint=fingerprint,
        fn_name="f", schedule=schedule_to_dict(schedule), best_ms=1.0,
        default_ms=2.0, trials=[], budget=1, seed=0)


def test_tuning_store_concurrent_writers_merge(tmp_path):
    path = str(tmp_path / "store.json")
    a, b = TuningStore(path), TuningStore(path)   # both loaded empty
    a.put(_record("a" * 16, "f" * 16, Schedule()))
    a.save()
    b.put(_record("b" * 16, "f" * 16, Schedule(direction="pull")))
    b.save()    # reload-merge: must NOT truncate a's record
    c = TuningStore(path)
    assert len(c) == 2
    assert c.lookup("a" * 16, "local", "f" * 16) is not None
    assert c.lookup("b" * 16, "local", "f" * 16) is not None
    # memory wins key conflicts on merge
    b.put(_record("a" * 16, "f" * 16, Schedule(direction="push")))
    b.save()
    c = TuningStore(path)
    assert c.lookup("a" * 16, "local",
                    "f" * 16).best_schedule().direction == "push"
    # merge=False restores explicit-overwrite semantics (pruning)
    fresh = TuningStore(path)
    fresh._records = {}
    fresh.save(merge=False)
    assert len(TuningStore(path)) == 0
    # atomic write leaves no temp droppings behind
    assert [p for p in os.listdir(tmp_path) if p.endswith(".tmp")] == []


def test_service_warm_reloads_tuned_schedule(tmp_path, g_a):
    """A persisted tuning record keyed (program digest, backend, graph
    fingerprint) supplies the serving schedule at registration — the first
    query hits the tuned path with no measurement sweep."""
    tuned = Schedule(direction="pull", batch_sources=4)
    store = TuningStore(str(tmp_path / "t.json"))
    store.put(_record(source_digest(load_program_source("sssp")),
                      get_context(g_a).fingerprint(), tuned))
    store.save()

    async def main():
        svc = GraphService(ServiceConfig(backend="local"),
                           tune_store=str(tmp_path / "t.json"))
        async with svc:
            h = svc.register_graph("a", g_a, kinds=["sssp", "bfs"])
            assert h.tuned == ["sssp"]
            assert h.schedules["sssp"] == tuned
            assert h.schedules["bfs"] == Schedule()   # no record -> default
            out = await svc.query("a", "sssp", src=5)
            assert np.array_equal(np.asarray(out),
                                  sssp_ref(g_a, 5).astype(np.int32))

    asyncio.run(main())


def test_register_graph_rejects_duplicates_and_unknown_kind(g_a):
    svc = GraphService()
    svc.register_graph("a", g_a, kinds=["sssp"])
    with pytest.raises(ValueError, match="already registered"):
        svc.register_graph("a", g_a)
    with pytest.raises(UnknownQueryKind, match="katz"):
        svc.register_graph("b", g_a, kinds=["katz"])
    assert "b" not in svc.graphs()    # failed registration fully rolled back


def test_dataclass_record_roundtrip_guard():
    """_record helper stays in sync with TuningRecord's fields."""
    rec = _record("a" * 16, "f" * 16, Schedule())
    assert TuningRecord.from_dict(dataclasses.asdict(rec)) == rec


# --- write batches (g.update through the service) ----------------------------

def test_update_after_eviction_reprepares_and_answers(g_a, g_b):
    """An updated graph whose derived views were LRU-evicted still serves
    correct answers: view adoption is a no-op on an empty context and the
    next query transparently re-prepares against the new version."""
    async def main():
        cfg = ServiceConfig(backend="pallas", view_budget_bytes=1)
        async with GraphService(cfg) as svc:
            svc.register_graph("a", g_a, kinds=["sssp"])
            svc.register_graph("b", g_b, kinds=["sssp"])  # evicts a's views
            assert any(n == "a" for n, _ in svc.stats()["evictions"])
            e_src = np.asarray(g_a.edge_src)
            e_dst = np.asarray(g_a.indices)
            delta = await svc.update_graph(
                "a", adds=[(1, 7), (3, 11)], weights=[2, 2],
                dels=[(int(e_src[0]), int(e_dst[0]))])
            assert svc.handle("a").graph is delta.graph
            out = await svc.query("a", "sssp", src=1)
            assert np.array_equal(np.asarray(out),
                                  sssp_ref(delta.graph, 1).astype(np.int32))
            assert svc.stats()["updates"] == 1

    asyncio.run(main())


class BlockingKind(QueryKind):
    """Sweep blocks until released; reports the graph version it ran on."""

    name = "block"
    per_source = True
    program = None

    def __init__(self):
        import threading
        self.entered = threading.Event()
        self.release = threading.Event()

    def make_runner(self, handle, sched, width):
        g = handle.graph          # the version this runner was built for

        def run(params_list):
            self.entered.set()
            self.release.wait(10)
            return [np.int32(g.version) for _ in params_list]

        return run


def test_update_defers_until_pinned_sweep_unpins(g_a):
    """A write batch arriving mid-sweep must wait for the pin to drop: the
    in-flight sweep finishes against the old version, the update applies
    the moment the last pin releases, and later queries see the new one."""
    async def main():
        kind = BlockingKind()
        async with GraphService(ServiceConfig(max_wait_ms=0.0)) as svc:
            svc.register_kind(kind)
            svc.register_graph("a", g_a, kinds=["block", "sssp"])
            q = asyncio.create_task(svc.query("a", "block", src=0))
            await asyncio.to_thread(kind.entered.wait, 10)  # sweep pinned
            upd = asyncio.create_task(svc.update_graph("a", adds=[(0, 1)],
                                                       weights=[2]))
            await asyncio.sleep(0.05)
            assert not upd.done(), "update applied while the graph was pinned"
            assert svc.handle("a").graph.version == 0
            kind.release.set()
            swept_version = int(await q)
            delta = await upd
            assert swept_version == 0, "sweep must see the pre-update version"
            assert delta.graph.version == 1
            assert svc.handle("a").graph is delta.graph
            out = await svc.query("a", "sssp", src=0)
            assert np.array_equal(np.asarray(out),
                                  sssp_ref(delta.graph, 0).astype(np.int32))

    asyncio.run(main())
