"""chameleon-34b — early-fusion VLM decoder, VQ image tokens [arXiv:2405.09818; unverified].

Image tokens are ordinary ids inside the 65536 vocab (VQ codes produced
upstream); qk-norm stabilizes the early-fusion softmax per the paper."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65_536, head_dim=128,
    qk_norm=True,
    notes="early-fusion VLM: modality frontend is the VQ tokenizer (stub)",
)
