"""minicpm-2b — dense llama-like, WSD schedule + mup scaling [arXiv:2404.06395]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122_753, head_dim=64,
    tie_embeddings=True, wsd_schedule=True,
    scale_emb=12.0, scale_depth=1.4,
    notes="WSD schedule in train/optimizer.py; mup-style scale_emb/scale_depth",
)
