"""The paper's own workload configs: graph suite x algorithm x backend."""
GRAPH_CONFIGS = {
    "algorithms": ("sssp", "sssp_pull", "pr", "tc", "bc"),
    "backends": ("local", "distributed", "pallas"),
    "suite": ("TW", "SW", "OK", "WK", "LJ", "PK", "US", "GR", "RM", "UR"),
}
