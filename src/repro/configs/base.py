"""Model/config schema for the assigned-architecture zoo.

One `ModelConfig` per architecture (exact shapes from the assignment table)
plus a `smoke()` reduction used by per-arch CPU tests. The dry-run consumes
the full config as ShapeDtypeStructs only — no allocation.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_loss: float = 0.01
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4
    attn_every: int = 0          # hybrid: one shared attn block every k blocks
    # --- xLSTM ---
    slstm_every: int = 0         # sLSTM block every k blocks (rest mLSTM)
    # --- enc-dec ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # --- numerics / schedule hints ---
    dtype: str = "bfloat16"
    scale_emb: float = 1.0       # minicpm-style mup scaling
    scale_depth: float = 0.0     # minicpm residual scaling (0 = off)
    wsd_schedule: bool = False   # minicpm warmup-stable-decay
    # --- modality frontend stub ---
    input_kind: str = "tokens"   # tokens | embeddings (audio/vision stubs)
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded to 256 so embedding/unembedding shard cleanly on the
        'model' axis (e.g. minicpm's 122753 is odd). Labels always index
        below the true vocab; pad logits are dead weight only."""
        return -(-self.vocab // 256) * 256

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=min(self.n_layers, 2),
            n_enc_layers=min(self.n_enc_layers, 2),
            n_dec_layers=min(self.n_dec_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=self.d_ff and 256,
            vocab=512,
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_top_k=min(self.moe_top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32,
            ssm_chunk=16,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
        )


# Shape cells from the assignment (per-arch shape set)
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode | long_decode


LM_SHAPES = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "long_decode"),
)

# long_500k only for sub-quadratic archs (SSM / hybrid); skips per DESIGN.md
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_cells_for(cfg: ModelConfig):
    cells = []
    for cell in LM_SHAPES:
        if cell.kind == "long_decode" and cfg.family not in LONG_CONTEXT_FAMILIES:
            continue   # pure full-attention archs skip long_500k (DESIGN.md §5)
        cells.append(cell)
    return tuple(cells)
