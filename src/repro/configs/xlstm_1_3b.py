"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0 per the assignment: gating is internal to the xLSTM cells (no
separate MLP); mLSTM = matrix-memory linear attention (runs long_500k)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50_304, head_dim=512,
    slstm_every=7,   # one sLSTM block every 7 (positions per xLSTM[7:1])
    ssm_chunk=128,
    notes="mLSTM chunked linear attention; sLSTM recurrent scan",
)
