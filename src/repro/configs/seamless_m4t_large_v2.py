"""seamless-m4t-large-v2 — enc-dec multimodal backbone [arXiv:2308.11596].

The audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S, d_model) to the encoder."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=24, n_dec_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256_206, head_dim=64,
    input_kind="embeddings",
    notes="enc-dec; audio frontend stubbed as precomputed embeddings",
)
