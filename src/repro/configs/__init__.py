"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

from .base import LM_SHAPES, ModelConfig, ShapeCell, shape_cells_for
from .qwen2_5_3b import CONFIG as qwen2_5_3b
from .minicpm_2b import CONFIG as minicpm_2b
from .mistral_large_123b import CONFIG as mistral_large_123b
from .phi4_mini_3_8b import CONFIG as phi4_mini_3_8b
from .seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2
from .chameleon_34b import CONFIG as chameleon_34b
from .qwen3_moe_235b_a22b import CONFIG as qwen3_moe_235b_a22b
from .deepseek_moe_16b import CONFIG as deepseek_moe_16b
from .zamba2_1_2b import CONFIG as zamba2_1_2b
from .xlstm_1_3b import CONFIG as xlstm_1_3b
from .starplat_graph import GRAPH_CONFIGS

ARCHS = {
    c.name: c for c in [
        qwen2_5_3b, minicpm_2b, mistral_large_123b, phi4_mini_3_8b,
        seamless_m4t_large_v2, chameleon_34b, qwen3_moe_235b_a22b,
        deepseek_moe_16b, zamba2_1_2b, xlstm_1_3b,
    ]
}


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]
