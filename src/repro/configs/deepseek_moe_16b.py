"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6 [arXiv:2401.06066]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102_400, head_dim=128,
    n_experts=64, n_shared_experts=2, moe_top_k=6,
    notes="fine-grained experts; shared experts always active",
)
