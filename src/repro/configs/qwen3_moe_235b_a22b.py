"""qwen3-moe-235b-a22b — MoE 128 experts top-8 [hf:Qwen/Qwen3-*; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151_936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
    n_experts=128, moe_top_k=8,
    notes="per-expert d_ff=1536; experts sharded on the model axis",
)
