"""zamba2-1.2b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32_000, head_dim=64,
    ssm_state=64, ssm_head_dim=64, conv_width=4,
    attn_every=6,   # one shared transformer block application every 6 mamba blocks
    notes="Mamba2 backbone; SHARED attn block weights, separate KV per call; runs long_500k",
)
