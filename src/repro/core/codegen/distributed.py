"""Distributed backend — the paper's MPI code generator, on shard_map.

Faithful to the paper's §3.2 BSP structure with 1-D block vertex
partitioning (§4.2 "quick index-based partitioning", last block padded):

  paper MPI                         generated JAX (per device, in shard_map)
  ---------                         ----------------------------------------
  local vertex block                property arrays of shape [B]
  scatter/gather send-recv          jax.lax.all_gather (tiled) of properties
  send-buffer + aggregation (§4.2)  local scatter-min into [N_pad] + lax.pmin
  MPI_Barrier / BSP step            the collective itself (BSP by construction)
  is_finished over all ranks        psum of the local OR (global OR)

The generated function body runs per device; `repro.core.dist.run()` wraps
it in `jax.shard_map` over the mesh's 'data' axis.
"""
from __future__ import annotations

from .. import ir as I
from ..ir import read_props
from .base import BFSCtx, CodegenError, EdgeCtx, ExprEmitter, HostCtx, VertexCtx
from .local_jax import LocalCodegen

_PARTITIONED_KEYS = ["esrc", "edst", "ew", "evalid", "esrc_local",
                     "idst", "isrc", "iw", "ivalid", "idst_local", "own_ids"]
_REPLICATED_KEYS = ["out_degree_rep", "in_degree_rep", "edge_key_rep", "n_true_rep"]


class DistExprEmitter(ExprEmitter):
    """Property reads: block arrays in vertex context, gathered `_full`
    arrays when indexed by global edge-endpoint ids."""

    full_mode = False   # filter emission over the full (gathered) arrays

    def expr(self, e, ctx):
        if isinstance(e, I.IProp):
            arr = self.prop_read(e.prop)
            if e.target is None:
                return arr
            idx = self.index_of(e.target, ctx)
            if idx == "_vids":
                return f"{arr}_full" if self.full_mode else arr
            return f"{arr}_full[{idx}]"
        if isinstance(e, (I.IIterId, I.INodeParam)):
            sidx = self.index_of(e.name, ctx)
            if sidx == "_vids" and self.full_mode:
                return "_vids_full"
            return sidx
        return super().expr(e, ctx)

    def call(self, e, ctx):
        if e.fn == "num_nodes":
            return "n_true"
        if e.fn in ("count_out_nbrs", "count_in_nbrs"):
            table = "out_degree_rep" if e.fn == "count_out_nbrs" else "in_degree_rep"
            idx = self.expr(e.args[0], ctx)
            if idx == "_vids":
                return f"{table}[own_ids]"
            if idx == "_vids_full":
                return table
            return f"{table}[{idx}]"
        return super().call(e, ctx)


class DistCodegen(LocalCodegen):
    backend_name = "distributed"
    VLEN = "B"
    # properties are device-sharded [B]-blocks here; the [B, N] source
    # batching of the local/pallas backends does not apply
    supports_source_batching = False

    def __init__(self, irfn: I.IRFunction, schedule=None):
        super().__init__(irfn, schedule=schedule)
        self.ex = DistExprEmitter(irfn, graph_var=irfn.graph_param)
        self.needs_ell = False

    # ------------------------------------------------------------------ entry
    def generate(self) -> str:
        f, em = self.f, self.em
        args = [p.name for p in f.params]
        sig = ", ".join([args[0]] + [f"{a}=None" for a in args[1:]])
        em.w(f"def {f.name}({sig}):")
        with em.block():
            gd = f.graph_param
            for k in _PARTITIONED_KEYS:
                em.w(f"{k} = {gd}['{k}'][0]")
            em.w(f"if 'ell_cols' in {gd}: ell_cols = {gd}['ell_cols'][0]")
            for k in _REPLICATED_KEYS:
                em.w(f"{k} = {gd}['{k}']")
            em.w("n_true = n_true_rep")
            em.w("B = own_ids.shape[0]")
            em.w("P = rtd.axis_size('data')")
            em.w("N_PAD = B * P")
            em.w("_vids = own_ids")
            em.w("_vids_full = jnp.arange(N_PAD, dtype=jnp.int32)")
            for p in f.params:
                if p.kind == "prop_node":
                    self.declare(p.name, p.dtype)
                    em.w(f"if {p.name} is None:")
                    with em.block():
                        em.w(f"{p.name} = rt.init_prop(B, {self.jdt(p.dtype)})")
                elif p.kind == "scalar":
                    self.dtypes[p.name] = p.dtype
            for s in f.body:
                self.stmt(s, HostCtx())
            rets = ", ".join(f"'{v}': {v}" for v in self.declared)
            em.w(f"return {{{rets}}}")
        return em.source()

    # ------------------------------------------------------------------ helpers
    def emit_gathers(self, stmts):
        """BSP property exchange: all-gather everything the step reads.
        This is the paper's scatter/gather communication phase; emitting it
        at loop entry gives exactly one exchange per BSP superstep."""
        for p in sorted(read_props(stmts)):
            if p in self.dtypes:   # known property
                self.em.w(f"{p}_full = rtd.gather({p})")

    def emit_finished(self, var: str, conv: str):
        self.em.w(f"{var} = ~rtd.any_global({conv})")

    # ------------------------------------------------------------------ attach
    def s_IAttach(self, s: I.IAttach, ctx):
        if s.kind != "node":
            raise CodegenError("edge properties not supported")
        for prop, dtype, init in s.props:
            self.declare(prop, dtype)
            if init is None:
                self.em.w(f"{prop} = rt.init_prop(B, {self.jdt(dtype)})")
            elif isinstance(init, I.IConst) and init.kind == "inf":
                self.em.w(f"{prop} = rt.init_prop(B, {self.jdt(dtype)}, rt.inf_for({self.jdt(dtype)}))")
            else:
                self.em.w(f"{prop} = rt.init_prop(B, {self.jdt(dtype)}, {self.ex.expr(init, ctx)})")

    def s_IWriteProp(self, s: I.IWriteProp, ctx):
        # single-node write: only the owning device's block slot changes
        node = self.ex.expr(s.node, ctx)
        val = self.ex.expr(s.expr, ctx)
        p = self.wtarget(s.prop)
        self.em.w(f"{p} = jnp.where(own_ids == {node}, {val}, {p})")

    def s_ICopyProp(self, s: I.ICopyProp, ctx):
        self.em.w(f"{self.wtarget(s.dst)} = {s.src}")

    # ------------------------------------------------------------------ loops
    def s_IVertexLoop(self, s: I.IVertexLoop, ctx):
        em = self.em
        self.emit_gathers([s])
        mask = mask_full = None
        if s.filter is not None:
            mask_full = em.uid("vmf")
            self.ex.full_mode = True
            em.w(f"{mask_full} = {self.ex.expr(s.filter, VertexCtx(it=s.it, mask=None, parent=ctx))}")
            self.ex.full_mode = False
            mask = em.uid("vm")
            em.w(f"{mask} = {mask_full}[own_ids]")
        vctx = VertexCtx(it=s.it, mask=mask, parent=ctx)
        vctx.mask_full = mask_full
        self.body(s.body, vctx)

    def _edge_arrays(self, direction: str):
        if direction == "out":
            return dict(vid="esrc", nid="edst", w="ew", seg="esrc_local",
                        valid="evalid")
        return dict(vid="idst", nid="isrc", w="iw", seg="idst_local",
                    valid="ivalid")

    def s_INbrLoop(self, s: I.INbrLoop, ctx):
        em = self.em
        vctx = self._vertex_ctx(ctx)
        if vctx is None:
            raise CodegenError("neighbor loop outside a vertex context")
        if self._try_wedge(s, ctx):
            return
        if isinstance(vctx, BFSCtx):
            return self._bfs_nbr_loop(s, ctx, vctx)
        a = self._edge_arrays(s.direction)
        ectx = EdgeCtx(it=s.it, source=s.source, direction=s.direction,
                       vid=a["vid"], nid=a["nid"], w=a["w"], seg=a["seg"],
                       seg_sorted=False, mask=None, parent=ctx)
        terms = [a["valid"]]
        mf = getattr(vctx, "mask_full", None)
        if mf:
            terms.append(f"{mf}[{ectx.vid}]")
        if s.filter is not None:
            terms.append(self.ex.expr(s.filter, ectx))
        mask = em.uid("em")
        em.w(f"{mask} = {' & '.join(terms)}")
        ectx.mask = mask
        self.body(s.body, ectx)

    def _bfs_nbr_loop(self, s: I.INbrLoop, ctx, bctx: BFSCtx):
        em = self.em
        if s.direction != "out":
            raise CodegenError("only neighbors() supported inside iterateInBFS")
        a = self._edge_arrays("out")
        ectx = EdgeCtx(it=s.it, source=s.source, direction="out",
                       vid=a["vid"], nid=a["nid"], w=a["w"], seg=a["seg"],
                       seg_sorted=False, mask=None, parent=ctx)
        terms = [a["valid"],
                 f"({bctx.level}[{ectx.vid}] == {bctx.cur})",
                 f"({bctx.level}[{ectx.nid}] == ({bctx.cur} + 1))"]
        mf = getattr(bctx, "mask_full", None)
        if mf:
            terms.append(f"{mf}[{ectx.vid}]")
        if s.filter is not None:
            terms.append(self.ex.expr(s.filter, ectx))
        mask = em.uid("em")
        em.w(f"{mask} = {' & '.join(terms)}")
        ectx.mask = mask
        self.body(s.body, ectx)

    # ------------------------------------------------------------------ writes
    def s_IMinMaxUpdate(self, s: I.IMinMaxUpdate, ctx):
        em = self.em
        ectx = self._edge_ctx(ctx)
        if ectx is None:
            raise CodegenError("Min/Max update outside a neighbor loop")
        p = self.wtarget(s.prop)
        dtype = self.f.node_props.get(s.prop, "int32")
        jdt = self.jdt(dtype)
        cand = self.ex.expr(s.cand, ctx)
        cv = em.uid("cand")
        ident = f"rt.inf_for({jdt})" if s.kind == "Min" else f"-rt.inf_for({jdt})"
        em.w(f"{cv} = jnp.where({ectx.mask}, {cand}, {ident})" if ectx.mask
             else f"{cv} = {cand}")
        new = em.uid("new")
        if s.target == ectx.it:
            # push: local scatter + one global combine = §4.2 aggregation
            fn = "rtd.combine_scatter_min" if s.kind == "Min" else "rtd.combine_scatter_max"
            comb = em.uid("comb")
            em.w(f"{comb} = {fn}(N_PAD, {ectx.nid}, {cv}, {jdt})")
            mm = "jnp.minimum" if s.kind == "Min" else "jnp.maximum"
            em.w(f"{new} = {mm}({s.prop}, {comb}[own_ids])")
        elif s.target == ectx.source:
            # pull: purely local segment reduction over owned in-edges
            fn = "rt.segment_min" if s.kind == "Min" else "rt.segment_max"
            mm = "jnp.minimum" if s.kind == "Min" else "jnp.maximum"
            em.w(f"{new} = {mm}({s.prop}, {fn}({cv}, {ectx.seg}, B, sorted_ids=False))")
        else:
            raise CodegenError(f"Min/Max target {s.target} not an endpoint")
        upd = em.uid("upd")
        cmp = "<" if s.kind == "Min" else ">"
        em.w(f"{upd} = {new} {cmp} {s.prop}")
        em.w(f"{p} = {new}" if p == s.prop else f"{p} = jnp.where({upd}, {new}, {p})")
        for eprop, _etgt, eval_ in s.extras:
            ep = self.wtarget(eprop)
            ev = self.ex.expr(eval_, HostCtx())
            em.w(f"{ep} = jnp.where({upd}, {ev}, {ep})")

    def s_IAssignProp(self, s: I.IAssignProp, ctx):
        em = self.em
        ectx = self._edge_ctx(ctx)
        vctx = self._vertex_ctx(ctx)
        p = self.wtarget(s.prop)
        e = self.ex.expr(s.expr, ctx)
        if ectx is not None:
            if s.reduce_op is None:
                raise CodegenError(f"unsynchronized per-edge write to {s.prop}")
            if s.reduce_op != "+":
                raise CodegenError(f"unsupported edge reduction {s.reduce_op}")
            masked = f"jnp.where({ectx.mask}, {e}, 0)" if ectx.mask else e
            dtype = self.jdt(self.f.node_props.get(s.prop, "float32"))
            if s.target == ectx.source:
                em.w(f"{p} = {p} + rt.segment_sum({masked}, {ectx.seg}, B, sorted_ids=False)")
            else:
                em.w(f"{p} = {p} + rtd.combine_scatter_add(N_PAD, {ectx.nid}, {masked}, {dtype})[own_ids]")
            return
        super().s_IAssignProp(s, ctx)   # vertex-level path works on blocks

    def s_IAssign(self, s: I.IAssign, ctx):
        # host-scalar reductions from parallel regions need a global combine
        if s.reduce_op is not None and not s.vertex_local and \
                (self._vertex_ctx(ctx) is not None or self._edge_ctx(ctx) is not None):
            em = self.em
            e = self.ex.expr(s.expr, ctx)
            dt = self.dtype_of(s.name)
            ectx = self._edge_ctx(ctx)
            vctx = self._vertex_ctx(ctx)
            mask = ectx.mask if ectx is not None else (vctx.mask if vctx else None)
            masked = f"jnp.where({mask}, {e}, 0)" if mask else e
            op = {"+": "+"}.get(s.reduce_op)
            if op is None:
                raise CodegenError(f"unsupported global reduction {s.reduce_op}")
            body = f"{s.name} {op} rtd.psum(jnp.sum({masked}))"
            em.w(f"{s.name} = jnp.asarray({body}, {self.jdt(dt)})" if dt else
                 f"{s.name} = {body}")
            return
        super().s_IAssign(s, ctx)

    # ------------------------------------------------------------------ BFS
    def s_IBFS(self, s: I.IBFS, ctx):
        em = self.em
        root = self.ex.expr(s.root, ctx)
        lvl = em.uid("level")
        dep = em.uid("depth")
        em.w(f"{lvl}, {dep} = rtd.bfs_levels_1d(esrc, edst, evalid, own_ids, {root}, N_PAD)")
        lvlf = f"{lvl}_full"
        em.w(f"{lvlf} = rtd.gather({lvl})")
        carry = self.carries(s.body)
        pack = ", ".join(carry)
        n = em.uid("bfsf")
        em.w(f"def {n}(_l, _carry):")
        with em.block():
            em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")
            self.emit_gathers(s.body)
            bctx = BFSCtx(it=s.it, level=lvlf, cur="_l", mask=None, parent=ctx)
            bctx.mask_full = None
            self.body(s.body, bctx)
            em.w(f"return ({pack},)" if len(carry) == 1 else f"return ({pack})")
        em.w(f"_carry = jax.lax.fori_loop(0, {dep} - 1, {n}, ({pack}{',' if len(carry) == 1 else ''}))")
        em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")
        if s.rev_body is None:
            return
        carry = self.carries(s.rev_body)
        pack = ", ".join(carry)
        n = em.uid("bfsr")
        em.w(f"def {n}(_k, _carry):")
        with em.block():
            em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")
            em.w(f"_l = {dep} - 2 - _k")
            self.emit_gathers(s.rev_body)
            vmf = em.uid("vmf")
            em.w(f"{vmf} = ({lvlf} == _l)")
            bctx = BFSCtx(it=s.it, level=lvlf, cur="_l", mask=None, parent=ctx)
            if s.rev_filter is not None:
                self.ex.full_mode = True
                em.w(f"{vmf} = {vmf} & ({self.ex.expr(s.rev_filter, bctx)})")
                self.ex.full_mode = False
            vm = em.uid("vm")
            em.w(f"{vm} = {vmf}[own_ids]")
            bctx.mask = vm
            bctx.mask_full = vmf
            self.body(s.rev_body, bctx)
            em.w(f"return ({pack},)" if len(carry) == 1 else f"return ({pack})")
        em.w(f"_carry = jax.lax.fori_loop(0, {dep} - 1, {n}, ({pack}{',' if len(carry) == 1 else ''}))")
        em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")

    # ------------------------------------------------------------------ wedge
    def _try_wedge(self, s: I.INbrLoop, ctx) -> bool:
        inner = s.body[0] if len(s.body) == 1 and isinstance(s.body[0], I.INbrLoop) else None
        if inner is None or inner.source != s.source or s.direction != "out" \
                or inner.direction != "out":
            return False
        iff = inner.body[0] if len(inner.body) == 1 and isinstance(inner.body[0], I.IIf) else None
        if iff is None or not isinstance(iff.cond, I.ICall) or iff.cond.fn != "is_an_edge":
            raise CodegenError("unsupported nested neighbor loop pattern")
        red = iff.then[0] if len(iff.then) == 1 and isinstance(iff.then[0], I.IAssign) else None
        if red is None or red.reduce_op != "+":
            raise CodegenError("wedge body must be a count reduction")
        self.needs_ell = True
        dt = self.dtype_of(red.name)
        acc = (f"{red.name} + rtd.wedge_count_1d(ell_cols, own_ids, "
               f"edge_key_rep, n_true) * ({self.ex.expr(red.expr, HostCtx())})")
        self.em.w(f"{red.name} = jnp.asarray({acc}, {self.jdt(dt)})" if dt else
                  f"{red.name} = {acc}")
        return True


def generate_distributed(irfn: I.IRFunction, schedule=None, **opts):
    # the schedule is accepted for API uniformity; the BSP lowering has no
    # frontier/batching knobs yet (properties are device-sharded [B]-blocks)
    cg = DistCodegen(irfn, schedule=schedule)
    body = cg.generate()
    from .. import runtime_dist as rtd
    meta = {
        "out_props": [v for v in cg.declared if v in irfn.node_props],
        "out_scalars": [v for v in cg.declared if v not in irfn.node_props],
        "needs_ell": cg.needs_ell,
    }
    return body, {"rtd": rtd, "__dist_meta__": meta}
