"""Distributed backend — the paper's MPI code generator, on shard_map.

Faithful to the paper's §3.2 BSP structure with 1-D block vertex
partitioning (§4.2 "quick index-based partitioning", last block padded):

  paper MPI                         generated JAX (per device, in shard_map)
  ---------                         ----------------------------------------
  local vertex block                property arrays of shape [B]
  scatter/gather send-recv          jax.lax.all_gather (tiled) of properties
  send-buffer + aggregation (§4.2)  local scatter-min into [N_pad] + lax.pmin
  MPI_Barrier / BSP step            the collective itself (BSP by construction)
  is_finished over all ranks        psum of the local OR (global OR)

The backend is schedule-driven like the local/pallas engines — every knob
is baked into the generated source as a literal (same `Schedule` =>
byte-identical source):

  * `dist_frontier` / `dist_gather_frac` pick the BSP property-exchange
    policy per superstep: the dense full all-gather (the paper's scheme),
    or frontier-compressed exchange of only the entries that changed since
    the last superstep (`rtd.exchange`), with a skip when the global
    frontier is empty ("auto"). The `{p}_full` gathered views ride in the
    BSP loop carry so each superstep applies deltas to them.
  * `direction` / `push_threshold_frac` pick the relax/BFS direction for
    the frontier-relax pattern: push (local scatter + one global min/add
    combine — §4.2 aggregation) vs pull (a purely local segment reduction
    over the shard's in-edge partition), switched per superstep by the
    replicated frontier's occupancy when "auto".
  * `priority="delta"` lowers the monotonic Min-relax fixedPoint to
    delta-stepping: the frontier becomes the current bucket window
    (`delta_bucket` wide; bucket advance = global any/min collectives over
    the blocks), and the value prop's changed-entry exchange is
    priority-SLICED — only in-window changes ship each superstep, cutting
    `_gather_elems` further. Out-of-window changes ship when their bucket
    is reached (values only decrease, so they keep registering as changed).
  * `batch_sources` batches `forall(src in sourceSet)` into S-lane chunks
    (pod-parallel-style lanes): per-source [B] blocks become [S, B], the
    gathered views [S, N_pad], and each superstep's exchange/combine moves
    all lanes at once. Bodies outside the batched subset fall back to the
    sequential per-source loop automatically, exactly like the local
    backend.

Every generated program additionally returns `_gather_elems`, the number
of property-exchange elements its collectives actually moved — the
communication-volume measurement `benchmarks/bench_dist.py` reports.

The generated function body runs per device; `repro.core.dist.run()` wraps
it in `jax.shard_map` over the mesh's 'data' axis.
"""
from __future__ import annotations

import contextlib

from .. import ir as I
from ..ir import read_props
from .base import (BFSCtx, CodegenError, EdgeCtx, ExprEmitter, HostCtx,
                   VertexCtx, pure_vertex_predicate, relax_candidate)
from .local_jax import LocalCodegen

# Ablation switch for the loop-invariant gather hoist: properties a BSP
# loop body reads but never writes are gathered once before the loop
# instead of once per superstep. `benchmarks/bench_analysis.py` flips this
# off (with a compile-cache clear) to measure the pre-hoist exchange plan
# on the same graph; it is not part of the Schedule because it is never
# the better plan — only a measurement baseline.
HOIST_INVARIANT = True

_PARTITIONED_KEYS = ["esrc", "edst", "ew", "evalid", "esrc_local",
                     "idst", "isrc", "iw", "ivalid", "idst_local", "own_ids"]
_REPLICATED_KEYS = ["out_degree_rep", "in_degree_rep", "edge_key_rep", "n_true_rep"]


class DistExprEmitter(ExprEmitter):
    """Property reads: block arrays in vertex context, gathered `_full`
    arrays when indexed by global edge-endpoint ids. Inside a batched
    source region, per-source arrays are [S, B] blocks / [S, N_pad] fulls
    and gathers move to the vertex axis (`arr_full[:, idx]`)."""

    full_mode = False   # filter emission over the full (gathered) arrays

    def expr(self, e, ctx):
        if isinstance(e, I.IProp):
            arr = self.prop_read(e.prop)
            if e.target is None:
                return arr
            idx = self.index_of(e.target, ctx)
            if idx == "_vids":
                return f"{arr}_full" if self.full_mode else arr
            b = self.batch
            if b is not None and e.prop in b.arrays:
                if idx == b.srcs2d:
                    raise CodegenError(
                        "reading a per-source property at the set iterator "
                        "is outside the batched distributed subset")
                return f"{arr}_full[:, {idx}]"
            return f"{arr}_full[{idx}]"
        if isinstance(e, (I.IIterId, I.INodeParam)):
            sidx = self.index_of(e.name, ctx)
            if sidx == "_vids" and self.full_mode:
                return "_vids_full"
            return sidx
        return super().expr(e, ctx)

    def call(self, e, ctx):
        if e.fn == "num_nodes":
            return "n_true"
        if e.fn in ("count_out_nbrs", "count_in_nbrs"):
            table = "out_degree_rep" if e.fn == "count_out_nbrs" else "in_degree_rep"
            idx = self.expr(e.args[0], ctx)
            if idx == "_vids":
                return f"{table}[own_ids]"
            if idx == "_vids_full":
                return table
            return f"{table}[{idx}]"
        return super().call(e, ctx)


class DistCodegen(LocalCodegen):
    backend_name = "distributed"
    VLEN = "B"
    # `forall(src in sourceSet)` batches into [S, B] lane blocks (the
    # pod-parallel lanes, fused into one program); bodies outside the
    # batched subset fall back to the sequential loop like the local backend
    supports_source_batching = True
    # delta-stepping here reshapes the EXCHANGE, not the relax: the bucketed
    # frontier flows through the partitioned push/pull supersteps unchanged,
    # so no `_dell` padded view is taken
    supports_delta_ell = False
    # per-source while/do-while loops (and their lane scalars) stay on the
    # sequential per-source fallback: fused lanes would need shard-uniform
    # per-lane trip counts threaded through every BSP superstep
    supports_batched_scalar_loops = False

    def __init__(self, irfn: I.IRFunction, schedule=None):
        super().__init__(irfn, schedule=schedule)
        self.ex = DistExprEmitter(irfn, graph_var=irfn.graph_param)
        self.needs_ell = False
        # stack of property groups whose `{p}_full` views are carried
        # through the enclosing BSP loop (compact/auto exchange policies)
        self._full_stack = []
        # stack of property groups the effect analysis proved loop-invariant
        # (read but never written inside the BSP loop): gathered once before
        # the loop under every policy, never re-exchanged per superstep
        self._invariant_stack = []
        # (value_prop, window_mask_var) of the active delta-stepping
        # fixedPoint: emit_gathers priority-slices that prop's exchange
        self._delta_within = None

    # ------------------------------------------------------------------ entry
    def generate(self) -> str:
        f, em = self.f, self.em
        args = [p.name for p in f.params]
        sig = ", ".join([args[0]] + [f"{a}=None" for a in args[1:]])
        em.w(f"def {f.name}({sig}):")
        with em.block():
            gd = f.graph_param
            for k in _PARTITIONED_KEYS:
                em.w(f"{k} = {gd}['{k}'][0]")
            em.w(f"if 'ell_cols' in {gd}: ell_cols = {gd}['ell_cols'][0]")
            for k in _REPLICATED_KEYS:
                em.w(f"{k} = {gd}['{k}']")
            em.w("n_true = n_true_rep")
            em.w("B = own_ids.shape[0]")
            em.w("P = rtd.axis_size('data')")
            em.w("N_PAD = B * P")
            em.w("_vids = own_ids")
            em.w("_vids_full = jnp.arange(N_PAD, dtype=jnp.int32)")
            # property-exchange volume accounting (elements moved by the
            # gather/exchange collectives; returned alongside the results).
            # Accumulated in f32: per-step counts are int32 <= N_PAD, but a
            # long BSP run can total past 2^31 and int64 is unavailable
            # under jax's default x64-disabled config — f32 stays exact to
            # 2^24 elements and degrades gracefully instead of wrapping.
            self.declare("_gather_elems", "float32")
            em.w("_gather_elems = jnp.float32(0)")
            for p in f.params:
                if p.kind == "prop_node":
                    self.declare(p.name, p.dtype)
                    em.w(f"if {p.name} is None:")
                    with em.block():
                        em.w(f"{p.name} = rt.init_prop(B, {self.jdt(p.dtype)})")
                elif p.kind == "scalar":
                    self.dtypes[p.name] = p.dtype
            for s in f.body:
                self.stmt(s, HostCtx())
            rets = ", ".join(f"'{v}': {v}" for v in self.declared)
            em.w(f"return {{{rets}}}")
        return em.source()

    # ------------------------------------------------------------------ helpers
    def fidx(self, arr: str, idx: str) -> str:
        """Index a replicated full array by an id array, batch-aware."""
        if self.batch is not None and arr in self.batch.arrays:
            return f"{arr}[:, {idx}]"
        return f"{arr}[{idx}]"

    def _full_vmask(self, expr: str) -> str:
        """Materialize a full-width ([N_PAD] / [S, N_PAD]) vertex mask;
        inside a batched region it is broadcast so downstream edge gathers
        see one uniform [S, *] shape."""
        m = self.em.uid("vmf")
        if self.batch is not None:
            self.em.w(f"{m} = jnp.broadcast_to(jnp.asarray({expr}), "
                      f"({self.batch.size}, N_PAD))")
            self.batch.arrays.add(m)
        else:
            self.em.w(f"{m} = {expr}")
        return m

    def _full_filter_expr(self, flt, it, ctx) -> str:
        """Emit a loop filter over the gathered full arrays."""
        self.ex.full_mode = True
        try:
            return self.ex.expr(flt, VertexCtx(it=it, mask=None, parent=ctx))
        finally:
            self.ex.full_mode = False

    def _carried_fulls(self) -> set:
        return {p for grp in self._full_stack for p in grp}

    def _invariant_fulls(self) -> set:
        return {p for grp in self._invariant_stack for p in grp}

    @contextlib.contextmanager
    def _bsp_loop_fulls(self, stmts):
        """Set up the `{p}_full` gathered views for one BSP loop.

        Effect split (the compile-time effect analysis made precise at the
        IR level): properties the loop reads but never writes are
        *loop-invariant* — gathered once here, before the loop, under every
        frontier policy, and never re-shipped per superstep (the view is a
        closure constant of the loop body). Read-AND-written properties are
        the actual BSP exchange set: under compact/auto their full views
        are carried through the loop and each superstep's `emit_gathers`
        applies only the changed entries (rtd.exchange); under dense they
        are re-gathered from scratch every superstep."""
        carried = self._carried_fulls()
        hoisted = self._invariant_fulls()
        written = I.written_vars(stmts)
        reads = [p for p in sorted(read_props(stmts))
                 if p in self.dtypes and p not in carried
                 and p not in hoisted]
        invariant = ([p for p in reads if p not in written]
                     if HOIST_INVARIANT else [])
        for p in invariant:
            self._emit_full_gather(p)
        self._invariant_stack.append(invariant)
        try:
            if self.schedule.dist_frontier == "dense":
                yield
                return
            props = [p for p in reads if p in written]
            for p in props:
                self._emit_full_gather(p)
            self._full_stack.append(props)
            try:
                yield
            finally:
                self._full_stack.pop()
        finally:
            self._invariant_stack.pop()

    def _emit_full_gather(self, p: str):
        batched = self.batch is not None and p in self.batch.arrays
        gfn = "rtd.gather_rows" if batched else "rtd.gather"
        self.em.w(f"{p}_full = {gfn}({p})")
        self.em.w(f"_gather_elems = _gather_elems + {p}_full.size")

    def emit_gathers(self, stmts):
        """BSP property exchange: make the `{p}_full` views every property
        the step reads consistent with the current blocks. This is the
        paper's scatter/gather communication phase; emitting it at loop
        entry gives exactly one exchange per BSP superstep. Properties with
        a carried full view exchange only their changed entries under the
        compiled `dist_frontier` policy; everything else takes the dense
        all-gather."""
        carried = self._carried_fulls()
        hoisted = self._invariant_fulls()
        sched = self.schedule
        for p in sorted(read_props(stmts)):
            if p not in self.dtypes:   # unknown name (not a property)
                continue
            if p in hoisted:   # loop-invariant: gathered once before the loop
                continue
            if p in carried:
                batched = self.batch is not None and p in self.batch.arrays
                xfn = "rtd.exchange_rows" if batched else "rtd.exchange"
                win = ""
                if not batched and self._delta_within is not None \
                        and p == self._delta_within[0]:
                    # priority slice: only changed entries inside the current
                    # bucket window ship this superstep; out-of-window changes
                    # stay local until their bucket is reached (they keep
                    # differing from the full view — values only decrease —
                    # so `chg` re-selects them then). The bucketed frontier is
                    # exchanged unsliced, so every in-window read is fresh.
                    win = f", within={self._delta_within[1]}"
                ge = self.em.uid("ge")
                self.em.w(f"{p}_full, {ge} = {xfn}({p}_full, {p}, own_ids, "
                          f"{sched.dist_gather_frac!r}, "
                          f"skip_empty={sched.dist_frontier == 'auto'}{win})")
                self.em.w(f"_gather_elems = _gather_elems + {ge}")
            else:
                self._emit_full_gather(p)

    def carries(self, body):
        out = super().carries(body)
        for p in (x for grp in self._full_stack for x in grp):
            full = f"{p}_full"
            if full not in out:
                out.append(full)
        if "_gather_elems" not in out:
            out.append("_gather_elems")
        return out

    def emit_finished(self, var: str, conv: str):
        self.em.w(f"{var} = ~rtd.any_global({conv})")

    # ---- delta-stepping hooks -------------------------------------------
    # the bucket advance runs on [B] blocks, so its any/min reductions must
    # be global collectives — every shard then agrees on the same bucket
    def _delta_any(self, expr: str) -> str:
        return f"rtd.any_global({expr})"

    def _delta_min(self, expr: str) -> str:
        return f"rtd.min_global({expr})"

    def _emit_delta_preamble(self, n: str, vprop: str, conv: str):
        """Bucketed-frontier preamble over the [B] blocks (emitted before
        this superstep's `emit_gathers`, so the window mask is available to
        priority-slice the value prop's exchange). The rebinding of `conv`
        to the windowed frontier happens on the block, BEFORE its exchange
        — the frontier's full view is therefore exact, and every read of
        the (possibly stale out-of-window) value full view is masked by
        it."""
        super()._emit_delta_preamble(n, vprop, conv)
        d = self.schedule.delta_bucket
        self.em.w(f"{n}_win = {vprop} < ({n}_bk + 1) * {d}")
        self._delta_within = (vprop, f"{n}_win")

    # ------------------------------------------------------------------ attach
    def s_IAttach(self, s: I.IAttach, ctx):
        if s.kind != "node":
            raise CodegenError("edge properties not supported")
        for prop, dtype, init in s.props:
            self.declare(prop, dtype)
            jdt = self.jdt(dtype)
            if self.batch is not None:
                # per-source property inside a batched set loop -> [S, B]
                self.batch.arrays.add(prop)
                sz = f"{self.batch.size}, B"
                if init is None:
                    self.em.w(f"{prop} = rt.init_prop_batch({sz}, {jdt})")
                elif isinstance(init, I.IConst) and init.kind == "inf":
                    self.em.w(f"{prop} = rt.init_prop_batch({sz}, {jdt}, rt.inf_for({jdt}))")
                else:
                    self.em.w(f"{prop} = rt.init_prop_batch({sz}, {jdt}, {self.ex.expr(init, ctx)})")
                continue
            if init is None:
                self.em.w(f"{prop} = rt.init_prop(B, {jdt})")
            elif isinstance(init, I.IConst) and init.kind == "inf":
                self.em.w(f"{prop} = rt.init_prop(B, {jdt}, rt.inf_for({jdt}))")
            else:
                self.em.w(f"{prop} = rt.init_prop(B, {jdt}, {self.ex.expr(init, ctx)})")

    def s_IWriteProp(self, s: I.IWriteProp, ctx):
        # single-node write: only the owning device's block slot changes
        # (in a batched region the [S, 1] iterator broadcasts lane-wise:
        # row s updates its own source vertex if owned)
        node = self.ex.expr(s.node, ctx)
        val = self.ex.expr(s.expr, ctx)
        p = self.wtarget(s.prop)
        if self.batch is not None:
            b = self.batch
            if s.prop not in b.arrays or node != b.srcs2d:
                raise CodegenError(
                    "batched single-node write must target the set iterator "
                    "on a per-source property")
        self.em.w(f"{p} = jnp.where(own_ids == {node}, {val}, {p})")

    def s_ICopyProp(self, s: I.ICopyProp, ctx):
        if self.batch is not None:
            ba = self.batch.arrays
            if (s.dst in ba) != (s.src in ba):
                raise CodegenError("copy between batched and shared property")
        self.em.w(f"{self.wtarget(s.dst)} = {s.src}")

    # ------------------------------------------------------------------ loops
    def s_IVertexLoop(self, s: I.IVertexLoop, ctx):
        em = self.em
        self.emit_gathers([s])
        mask = mask_full = None
        if s.filter is not None:
            mask_full = self._full_vmask(
                self._full_filter_expr(s.filter, s.it, ctx))
            if self.batch is not None:
                mask = self._vmask(f"{mask_full}[:, own_ids]")
            else:
                mask = em.uid("vm")
                em.w(f"{mask} = {mask_full}[own_ids]")
        vctx = VertexCtx(it=s.it, mask=mask, parent=ctx)
        vctx.mask_full = mask_full
        self.body(s.body, vctx)

    def _edge_arrays(self, direction: str):
        if direction == "out":
            return dict(vid="esrc", nid="edst", w="ew", seg="esrc_local",
                        valid="evalid")
        return dict(vid="idst", nid="isrc", w="iw", seg="idst_local",
                    valid="ivalid")

    def s_INbrLoop(self, s: I.INbrLoop, ctx):
        em = self.em
        vctx = self._vertex_ctx(ctx)
        if vctx is None:
            raise CodegenError("neighbor loop outside a vertex context")
        if self._try_wedge(s, ctx):
            return
        if isinstance(vctx, BFSCtx):
            return self._bfs_nbr_loop(s, ctx, vctx)
        a = self._edge_arrays(s.direction)
        ectx = EdgeCtx(it=s.it, source=s.source, direction=s.direction,
                       vid=a["vid"], nid=a["nid"], w=a["w"], seg=a["seg"],
                       seg_sorted=False, mask=None, parent=ctx)
        terms = [a["valid"]]
        pure = True
        mf = getattr(vctx, "mask_full", None)
        if mf:
            terms.append(self.fidx(mf, ectx.vid))
            ectx.src_vmask = mf
        if s.filter is not None:
            if pure_vertex_predicate(s.filter, s.it):
                # neighbor-side filter that only reads nbr-props: hoist it
                # to one full vertex mask (the frontier the engine and the
                # direction switch consume)
                nm = self._full_vmask(
                    self._full_filter_expr(s.filter, s.it, ctx))
                terms.append(self.fidx(nm, ectx.nid))
                ectx.it_vmask = nm
            else:
                terms.append(self.ex.expr(s.filter, ectx))
                pure = False
        ectx.pure_frontier = pure
        mask = em.uid("em")
        em.w(f"{mask} = {' & '.join(terms)}")
        ectx.mask = mask
        self.body(s.body, ectx)

    def _bfs_nbr_loop(self, s: I.INbrLoop, ctx, bctx: BFSCtx):
        em = self.em
        if s.direction != "out":
            raise CodegenError("only neighbors() supported inside iterateInBFS")
        a = self._edge_arrays("out")
        ectx = EdgeCtx(it=s.it, source=s.source, direction="out",
                       vid=a["vid"], nid=a["nid"], w=a["w"], seg=a["seg"],
                       seg_sorted=False, mask=None, parent=ctx)
        terms = [a["valid"],
                 f"({self.fidx(bctx.level, ectx.vid)} == {bctx.cur})",
                 f"({self.fidx(bctx.level, ectx.nid)} == ({bctx.cur} + 1))"]
        mf = getattr(bctx, "mask_full", None)
        if mf:
            terms.append(self.fidx(mf, ectx.vid))
        if s.filter is not None:
            terms.append(self.ex.expr(s.filter, ectx))
        mask = em.uid("em")
        em.w(f"{mask} = {' & '.join(terms)}")
        ectx.mask = mask
        self.body(s.body, ectx)

    # ------------------------------------------------------------------ writes
    def _dist_hybrid(self, s: I.IMinMaxUpdate, ectx):
        """Detect the frontier-relax pattern `Min(t.p, other.p [+ e.weight])`
        with nothing but a hoisted vertex frontier masking the contributing
        side — the pattern whose direction the Schedule may pin or switch.
        Returns (full frontier-mask name, weighted) or None; `weighted` is
        False for the bare-prop candidate (CC's unweighted component min),
        which takes the same push/pull supersteps minus the weight term."""
        if self.batch is not None or s.kind != "Min" \
                or not getattr(ectx, "pure_frontier", False):
            return None
        if self.f.node_props.get(s.prop) != "int32":
            return None
        if s.target == ectx.it and ectx.direction == "out":
            # push DSL form: the outer (frontier) vertex relaxes out-edges
            other, fr = ectx.source, ectx.src_vmask
            if ectx.it_vmask is not None:
                return None
        elif s.target == ectx.source and ectx.direction == "in":
            # pull DSL form: in-neighbors on the frontier contribute
            other, fr = ectx.it, ectx.it_vmask
            if ectx.src_vmask is not None:
                return None
        else:
            return None
        cand = relax_candidate(s.cand, other)
        if fr is None or cand is None or cand[0] != s.prop:
            return None
        return fr, cand[1]

    def _emit_relax_hybrid_dist(self, s: I.IMinMaxUpdate, fr: str,
                                weighted: bool = True) -> str:
        """Direction-optimized distributed relax superstep.

          push — local scatter-min over out-edges of frontier sources + one
                 global min-combine (the paper's §4.2 aggregation);
          pull — a purely local segment-min over the shard's in-edge
                 partition (no combine collective at all).

        Both compute min(dist[v], min over frontier in-neighbors u of
        dist[u] + w) exactly, so the per-superstep switch (on the
        replicated frontier's occupancy, shard-uniform by construction)
        never changes results. `Schedule.direction` pins one branch."""
        em = self.em
        sched = self.schedule
        jdt = self.jdt(self.f.node_props.get(s.prop, "int32"))
        full = f"{s.prop}_full"
        new = em.uid("new")
        wexp = (lambda w: f" + {w}" if weighted else "")
        push, pull = em.uid("push"), em.uid("pull")
        if sched.direction != "pull":
            em.w(f"{push} = lambda _fr: jnp.minimum({s.prop}, "
                 f"rtd.combine_scatter_min(N_PAD, edst, "
                 f"jnp.where(evalid & _fr[esrc], {full}[esrc]{wexp('ew')}, "
                 f"rt.inf_for({jdt})), {jdt})[own_ids])")
        if sched.direction != "push":
            em.w(f"{pull} = lambda _fr: jnp.minimum({s.prop}, "
                 f"rt.segment_min(jnp.where(ivalid & _fr[isrc], "
                 f"{full}[isrc]{wexp('iw')}, rt.inf_for({jdt})), "
                 f"idst_local, B, sorted_ids=False))")
        if sched.direction == "push":
            em.w(f"{new} = {push}({fr})")
        elif sched.direction == "pull":
            em.w(f"{new} = {pull}({fr})")
        else:
            em.w(f"{new} = jax.lax.cond(rtd.dist_should_push({fr}, "
                 f"{sched.push_threshold_frac!r}), {push}, {pull}, {fr})")
        return new

    def s_IMinMaxUpdate(self, s: I.IMinMaxUpdate, ctx):
        em = self.em
        if self.batch is not None:
            raise CodegenError("Min/Max construct inside a batched source "
                               "loop (falls back to the sequential lowering)")
        ectx = self._edge_ctx(ctx)
        if ectx is None:
            raise CodegenError("Min/Max update outside a neighbor loop")
        p = self.wtarget(s.prop)
        dtype = self.f.node_props.get(s.prop, "int32")
        jdt = self.jdt(dtype)
        hyb = self._dist_hybrid(s, ectx)
        if hyb is not None:
            fr, weighted = hyb
            new = self._emit_relax_hybrid_dist(s, fr, weighted)
            upd = em.uid("upd")
            em.w(f"{upd} = {new} < {s.prop}")
            em.w(f"{p} = {new}" if p == s.prop
                 else f"{p} = jnp.where({upd}, {new}, {p})")
            for eprop, _etgt, eval_ in s.extras:
                ep = self.wtarget(eprop)
                ev = self.ex.expr(eval_, HostCtx())
                em.w(f"{ep} = jnp.where({upd}, {ev}, {ep})")
            return
        cand = self.ex.expr(s.cand, ctx)
        cv = em.uid("cand")
        ident = f"rt.inf_for({jdt})" if s.kind == "Min" else f"-rt.inf_for({jdt})"
        em.w(f"{cv} = jnp.where({ectx.mask}, {cand}, {ident})" if ectx.mask
             else f"{cv} = {cand}")
        new = em.uid("new")
        if s.target == ectx.it:
            # push: local scatter + one global combine = §4.2 aggregation
            fn = "rtd.combine_scatter_min" if s.kind == "Min" else "rtd.combine_scatter_max"
            comb = em.uid("comb")
            em.w(f"{comb} = {fn}(N_PAD, {ectx.nid}, {cv}, {jdt})")
            mm = "jnp.minimum" if s.kind == "Min" else "jnp.maximum"
            em.w(f"{new} = {mm}({s.prop}, {comb}[own_ids])")
        elif s.target == ectx.source:
            # pull: purely local segment reduction over owned in-edges
            fn = "rt.segment_min" if s.kind == "Min" else "rt.segment_max"
            mm = "jnp.minimum" if s.kind == "Min" else "jnp.maximum"
            em.w(f"{new} = {mm}({s.prop}, {fn}({cv}, {ectx.seg}, B, sorted_ids=False))")
        else:
            raise CodegenError(f"Min/Max target {s.target} not an endpoint")
        upd = em.uid("upd")
        cmp = "<" if s.kind == "Min" else ">"
        em.w(f"{upd} = {new} {cmp} {s.prop}")
        em.w(f"{p} = {new}" if p == s.prop else f"{p} = jnp.where({upd}, {new}, {p})")
        for eprop, _etgt, eval_ in s.extras:
            ep = self.wtarget(eprop)
            ev = self.ex.expr(eval_, HostCtx())
            em.w(f"{ep} = jnp.where({upd}, {ev}, {ep})")

    def _batched_assign_prop(self, s: I.IAssignProp, ectx, vctx, p: str, e: str):
        """Property write inside a batched distributed source region. Edge
        contexts need the distributed combines ([S, E] candidates scattered
        by global ids and psum'd across shards); everything vertex-level
        reuses the local batched lowering (pure block ops)."""
        em = self.em
        b = self.batch
        if ectx is not None:
            if s.reduce_op is None:
                raise CodegenError(
                    f"unsynchronized per-edge write to {s.prop}")
            if s.reduce_op != "+":
                raise CodegenError(f"unsupported edge reduction {s.reduce_op}")
            if s.prop not in b.arrays:
                raise CodegenError(
                    "write to a shared property from an edge context in a "
                    "batched distributed source loop")
            masked = f"jnp.where({ectx.mask}, {e}, 0)" if ectx.mask else e
            if s.target == ectx.source:
                # pull: local batched segment reduction over owned edges
                em.w(f"{p} = {p} + rt.segment_sum_batch("
                     f"jnp.broadcast_to(jnp.asarray({masked}), ({b.size},) + {ectx.seg}.shape), "
                     f"{ectx.seg}, B, sorted_ids=False)")
            else:
                # push: one [S, N_PAD] scatter-add + psum serves all lanes
                dtype = self.jdt(self.f.node_props.get(s.prop, "float32"))
                em.w(f"{p} = {p} + rtd.combine_scatter_add_rows(N_PAD, {ectx.nid}, "
                     f"jnp.broadcast_to(jnp.asarray({masked}), ({b.size},) + {ectx.nid}.shape), "
                     f"{dtype})[:, own_ids]")
            return
        super()._batched_assign_prop(s, ectx, vctx, p, e)

    def s_IAssignProp(self, s: I.IAssignProp, ctx):
        em = self.em
        ectx = self._edge_ctx(ctx)
        vctx = self._vertex_ctx(ctx)
        p = self.wtarget(s.prop)
        e = self.ex.expr(s.expr, ctx)
        if self.batch is not None:
            return self._batched_assign_prop(s, ectx, vctx, p, e)
        if ectx is not None:
            if s.reduce_op is None:
                raise CodegenError(f"unsynchronized per-edge write to {s.prop}")
            if s.reduce_op != "+":
                raise CodegenError(f"unsupported edge reduction {s.reduce_op}")
            masked = f"jnp.where({ectx.mask}, {e}, 0)" if ectx.mask else e
            dtype = self.jdt(self.f.node_props.get(s.prop, "float32"))
            if s.target == ectx.source:
                em.w(f"{p} = {p} + rt.segment_sum({masked}, {ectx.seg}, B, sorted_ids=False)")
            else:
                em.w(f"{p} = {p} + rtd.combine_scatter_add(N_PAD, {ectx.nid}, {masked}, {dtype})[own_ids]")
            return
        super().s_IAssignProp(s, ctx)   # vertex-level path works on blocks

    def s_IAssign(self, s: I.IAssign, ctx):
        # host-scalar reductions from parallel regions need a global combine;
        # per-source lane scalars (sequential set-loop fallback) too — each
        # shard only sums its own block, and the enclosing while trip count
        # must stay shard-uniform
        if s.reduce_op is not None and \
                (not s.vertex_local or s.name in self.lane_scalars) and \
                (self._vertex_ctx(ctx) is not None or self._edge_ctx(ctx) is not None):
            if self.batch is not None:
                raise CodegenError("host-scalar reduction inside a batched "
                                   "distributed source loop")
            em = self.em
            e = self.ex.expr(s.expr, ctx)
            dt = self.dtype_of(s.name)
            ectx = self._edge_ctx(ctx)
            vctx = self._vertex_ctx(ctx)
            mask = ectx.mask if ectx is not None else (vctx.mask if vctx else None)
            masked = f"jnp.where({mask}, {e}, 0)" if mask else e
            op = {"+": "+"}.get(s.reduce_op)
            if op is None:
                raise CodegenError(f"unsupported global reduction {s.reduce_op}")
            body = f"{s.name} {op} rtd.psum(jnp.sum({masked}))"
            em.w(f"{s.name} = jnp.asarray({body}, {self.jdt(dt)})" if dt else
                 f"{s.name} = {body}")
            return
        super().s_IAssign(s, ctx)

    # ------------------------------------------------------------------ BSP loops
    def s_IFixedPoint(self, s: I.IFixedPoint, ctx):
        prev_within = self._delta_within
        try:
            with self._bsp_loop_fulls(s.body):
                super().s_IFixedPoint(s, ctx)
        finally:
            self._delta_within = prev_within

    def s_IDoWhile(self, s: I.IDoWhile, ctx):
        with self._bsp_loop_fulls(s.body):
            super().s_IDoWhile(s, ctx)

    def s_IWhile(self, s: I.IWhile, ctx):
        with self._bsp_loop_fulls(s.body):
            super().s_IWhile(s, ctx)

    # ------------------------------------------------------------------ BFS
    def s_IBFS(self, s: I.IBFS, ctx):
        em = self.em
        sched = self.schedule
        root = self.ex.expr(s.root, ctx)
        lvl = em.uid("level")
        dep = em.uid("depth")
        ge = em.uid("ge")
        kw = (f"frontier={sched.dist_frontier!r}, "
              f"gather_frac={sched.dist_gather_frac!r}, "
              f"direction={sched.direction!r}, "
              f"threshold_frac={sched.push_threshold_frac!r}")
        if self.batch is not None:
            if root != self.batch.srcs2d:
                raise CodegenError("batched iterateInBFS root must be the "
                                   "set iterator")
            em.w(f"{lvl}, {dep}, {ge} = rtd.bfs_levels_1d_batch(esrc, edst, "
                 f"evalid, isrc, idst_local, ivalid, own_ids, "
                 f"{self.batch.srcs}, N_PAD, {kw})")
            self.batch.arrays.add(lvl)
        else:
            em.w(f"{lvl}, {dep}, {ge} = rtd.bfs_levels_1d(esrc, edst, evalid, "
                 f"isrc, idst_local, ivalid, own_ids, {root}, N_PAD, {kw})")
        em.w(f"_gather_elems = _gather_elems + {ge}")
        lvlf = f"{lvl}_full"
        em.w(f"{lvlf} = {'rtd.gather_rows' if self.batch is not None else 'rtd.gather'}({lvl})")
        em.w(f"_gather_elems = _gather_elems + {lvlf}.size")
        if self.batch is not None:
            self.batch.arrays.add(lvlf)
        # forward pass: level-synchronous over the BFS DAG
        with self._bsp_loop_fulls(s.body):
            carry = self.carries(s.body)
            pack = ", ".join(carry)
            n = em.uid("bfsf")
            em.w(f"def {n}(_l, _carry):")
            with em.block():
                em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")
                self.emit_gathers(s.body)
                bctx = BFSCtx(it=s.it, level=lvlf, cur="_l", mask=None, parent=ctx)
                bctx.mask_full = None
                self.body(s.body, bctx)
                em.w(f"return ({pack},)" if len(carry) == 1 else f"return ({pack})")
            em.w(f"_carry = jax.lax.fori_loop(0, {dep} - 1, {n}, ({pack}{',' if len(carry) == 1 else ''}))")
            em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")
        if s.rev_body is None:
            return
        # reverse pass: levels from deepest-1 down to 0
        with self._bsp_loop_fulls(s.rev_body):
            carry = self.carries(s.rev_body)
            pack = ", ".join(carry)
            n = em.uid("bfsr")
            em.w(f"def {n}(_k, _carry):")
            with em.block():
                em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")
                em.w(f"_l = {dep} - 2 - _k")
                self.emit_gathers(s.rev_body)
                vmf = em.uid("vmf")
                em.w(f"{vmf} = ({lvlf} == _l)")
                bctx = BFSCtx(it=s.it, level=lvlf, cur="_l", mask=None, parent=ctx)
                if s.rev_filter is not None:
                    self.ex.full_mode = True
                    try:
                        em.w(f"{vmf} = {vmf} & ({self.ex.expr(s.rev_filter, bctx)})")
                    finally:
                        self.ex.full_mode = False
                vm = em.uid("vm")
                if self.batch is not None:
                    self.batch.arrays.add(vmf)
                    em.w(f"{vm} = {vmf}[:, own_ids]")
                    self.batch.arrays.add(vm)
                else:
                    em.w(f"{vm} = {vmf}[own_ids]")
                bctx.mask = vm
                bctx.mask_full = vmf
                self.body(s.rev_body, bctx)
                em.w(f"return ({pack},)" if len(carry) == 1 else f"return ({pack})")
            em.w(f"_carry = jax.lax.fori_loop(0, {dep} - 1, {n}, ({pack}{',' if len(carry) == 1 else ''}))")
            em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")

    # ------------------------------------------------------------------ wedge
    def _try_wedge(self, s: I.INbrLoop, ctx) -> bool:
        inner = s.body[0] if len(s.body) == 1 and isinstance(s.body[0], I.INbrLoop) else None
        if inner is None or inner.source != s.source or s.direction != "out" \
                or inner.direction != "out":
            return False
        iff = inner.body[0] if len(inner.body) == 1 and isinstance(inner.body[0], I.IIf) else None
        if iff is None or not isinstance(iff.cond, I.ICall) or iff.cond.fn != "is_an_edge":
            raise CodegenError("unsupported nested neighbor loop pattern")
        red = iff.then[0] if len(iff.then) == 1 and isinstance(iff.then[0], I.IAssign) else None
        if red is None or red.reduce_op != "+":
            raise CodegenError("wedge body must be a count reduction")
        if self.batch is not None:
            raise CodegenError("wedge pattern inside a batched source loop")
        self.needs_ell = True
        dt = self.dtype_of(red.name)
        acc = (f"{red.name} + rtd.wedge_count_1d(ell_cols, own_ids, "
               f"edge_key_rep, n_true) * ({self.ex.expr(red.expr, HostCtx())})")
        self.em.w(f"{red.name} = jnp.asarray({acc}, {self.jdt(dt)})" if dt else
                  f"{red.name} = {acc}")
        return True


def generate_distributed(irfn: I.IRFunction, schedule=None, **opts):
    """Emit the distributed-backend source under `schedule`. The BSP
    lowering consumes `dist_frontier`/`dist_gather_frac` (exchange policy),
    `direction`/`push_threshold_frac` (relax/BFS direction), and
    `batch_sources` (source-set lanes) — all baked in as literals, so the
    same schedule yields byte-identical source."""
    cg = DistCodegen(irfn, schedule=schedule)
    body = cg.generate()
    from .. import runtime_dist as rtd
    meta = {
        "out_props": [v for v in cg.declared if v in irfn.node_props],
        "out_scalars": [v for v in cg.declared if v not in irfn.node_props],
        "needs_ell": cg.needs_ell,
    }
    return body, {"rtd": rtd, "__dist_meta__": meta}
