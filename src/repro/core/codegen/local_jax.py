"""Local (single-device) backend — the paper's OpenMP code generator, on XLA.

`forall` over vertices → whole-array ops with boolean-mask predication;
neighbor loops → CSR edge-array ops; reductions → segment/scatter combines;
`fixedPoint` → `jax.lax.while_loop` with an on-device OR-reduction flag (the
paper's "memory optimization in OR-reduction", §4.3, without any transfer);
the Min/Max construct → deterministic scatter-min (the paper's CAS atomics,
§3.6, resolved structurally).
"""
from __future__ import annotations

from typing import List, Optional

from .. import ir as I
from ...graph.csr import resolve_schedule
from ...schedule import Schedule
from ..ir import written_vars
from .base import (BatchInfo, BFSCtx, CodegenError, EdgeCtx, Emitter,
                   ExprEmitter, HostCtx, VertexCtx, ctx_chain,
                   pure_vertex_predicate, relax_candidate)

_JNP_DTYPE = {"int32": "jnp.int32", "bool": "jnp.bool_",
              "float32": "jnp.float32", "float64": "jnp.float32"}
# float64 → float32: x64 is disabled on TPU; sigma counts fit f32 for our sizes.

_RED = {"+": "+", "-": "-", "*": "*", "/": "/", "&&": "&", "||": "|"}


class LocalCodegen:
    backend_name = "local"
    VLEN = "N"
    # batched `forall(src in sourceSet)` lowering (Schedule.batch_sources)
    supports_source_batching = True
    # takes a `_dell` padded forward-ELL param for the delta-stepping compact
    # relax (rt.relax_minplus_delta); pallas relaxes through its own sliced
    # kernels instead and the distributed backend relaxes partitioned arrays
    supports_delta_ell = True
    # per-source `while` / `do-while` loops inside a batched source-set
    # region lower to one fused lane-masked while_loop (all B lanes advance
    # together, converged lanes frozen); the distributed backend keeps the
    # sequential per-source fallback instead (its BSP supersteps would need
    # shard-uniform trip counts per lane)
    supports_batched_scalar_loops = True

    def __init__(self, irfn: I.IRFunction, schedule: Optional[Schedule] = None,
                 batch_sources: Optional[int] = None):
        self.f = irfn
        self.em = Emitter()
        self.ex = ExprEmitter(irfn, graph_var=irfn.graph_param)
        self.declared: List[str] = []      # ordered mutable host-scope vars
        self.dtypes = {}
        self.write_alias = {}              # fixedPoint redirects
        self.batch = None                  # active BatchInfo (batched set loop)
        self.lane_scalars = set()          # per-source scalars of the active
        #                                    set loop (host-scalar semantics
        #                                    per source; [B] when batched)
        self._delta_prop = None            # Min-relax prop of the active
        #                                    delta-stepping fixedPoint
        # every engine knob is baked into the emitted source as a literal:
        # same Schedule -> byte-identical source, and nothing generated ever
        # reads the deprecated ENGINE singleton at run time
        self.schedule = resolve_schedule(schedule, batch_sources=batch_sources)

    def _engine_kwargs(self) -> str:
        """`, threshold_frac=..., direction=...` literals for runtime calls.

        These are the Schedule knobs the local backend consumes directly;
        the layout knobs shape the sliced-ELL views and `block_rows` is a
        pallas-kernel grid cap (PallasCodegen appends it via
        `_kernel_kwargs`). Knob reference: docs/schedule.md."""
        s = self.schedule
        return (f", threshold_frac={s.push_threshold_frac!r}"
                f", direction={s.direction!r}")

    # ------------------------------------------------------------------ utils
    def dtype_of(self, name: str) -> Optional[str]:
        return self.dtypes.get(name)

    def bg(self, arr: str, idx: str) -> str:
        """Gather `arr[idx]`, batch-aware: arrays registered as [B, N] in the
        active batched region gather along the vertex axis (`arr[:, idx]`)."""
        if self.batch is not None and arr in self.batch.arrays:
            return f"{arr}[:, {idx}]"
        return f"{arr}[{idx}]"

    def _vmask(self, expr: str) -> str:
        """Materialize a vertex mask; inside a batched region every vertex
        mask is broadcast to [B, N] so downstream gathers/reductions see one
        uniform shape regardless of what the predicate read."""
        m = self.em.uid("vm")
        if self.batch is not None:
            self.em.w(f"{m} = jnp.broadcast_to(jnp.asarray({expr}), "
                      f"({self.batch.size}, {self.VLEN}))")
            self.batch.arrays.add(m)
        else:
            self.em.w(f"{m} = {expr}")
        return m

    def _snapshot(self):
        return (len(self.em.lines), self.em._uid, list(self.declared),
                dict(self.dtypes), dict(self.write_alias),
                set(self.lane_scalars))

    def _restore(self, state):
        nlines, uid, decl, dts, wa, ls = state
        del self.em.lines[nlines:]
        self.em._uid = uid
        self.declared[:] = decl
        self.dtypes = dts
        self.write_alias = wa
        self.lane_scalars = ls
        self.batch = None
        self.ex.batch = None

    def jdt(self, dtype: str) -> str:
        return _JNP_DTYPE[dtype]

    def declare(self, name: str, dtype: str):
        if name not in self.declared:
            self.declared.append(name)
        self.dtypes[name] = dtype

    def wtarget(self, prop: str) -> str:
        return self.write_alias.get(prop, prop)

    def carries(self, body) -> List[str]:
        wr = written_vars(body)
        return [v for v in self.declared if v in wr]

    # ---- delta-stepping detection (Schedule.priority == "delta") ------------
    def _delta_target(self, body) -> Optional[str]:
        """The value prop a delta-stepping lowering of this fixedPoint body
        would bucket on: the unique int32 Min-relax target (SSSP's dist,
        CC's comp). None when the knob is off or the body has no (or an
        ambiguous) monotonic Min relax — PR/TC loops pass through unchanged."""
        if self.schedule.priority != "delta" or self.batch is not None:
            return None
        props = []

        def scan(stmts):
            for st in stmts:
                if isinstance(st, I.IMinMaxUpdate) and st.kind == "Min" and \
                        self.f.node_props.get(st.prop) == "int32":
                    if st.prop not in props:
                        props.append(st.prop)
                for attr in ("body", "then", "els", "rev_body"):
                    sub = getattr(st, attr, None)
                    if sub:
                        scan(sub)

        scan(body)
        return props[0] if len(props) == 1 else None

    def _wants_dell(self) -> bool:
        """True when the generated function should take the `_dell` padded
        forward-ELL param: some fixedPoint in the program lowers to
        delta-stepping and this backend relaxes through it."""
        if not self.supports_delta_ell:
            return False
        fps = []

        def scan(stmts):
            for st in stmts:
                if isinstance(st, I.IFixedPoint):
                    fps.append(st)
                for attr in ("body", "then", "els", "rev_body"):
                    sub = getattr(st, attr, None)
                    if sub:
                        scan(sub)

        scan(self.f.body)
        return any(self._delta_target(fp.body) is not None for fp in fps)

    # ------------------------------------------------------------------ entry
    # when True, `generate()` emits the `<name>__refresh` incremental
    # variant: same body, extra `_warm/_reset/_seed` params, and a
    # warm-override block right before the first top-level iterative
    # construct (see `_emit_warm_start`). Set on a FRESH codegen instance
    # by the `generate_*` factories — never flipped mid-generation.
    refresh_variant = False

    def _sig_head(self, args):
        # non-graph prop params may be passed as None (re-initialized inside);
        # delta-stepping programs additionally take the padded ELL view the
        # compact relax gathers frontier out-rows from (None = dense fallback)
        return [args[0]] + (["_dell=None"] if self._wants_dell() else [])

    def generate(self) -> str:
        f, em = self.f, self.em
        g = f.graph_param
        args = [p.name for p in f.params]
        name = f"{f.name}__refresh" if self.refresh_variant else f.name
        tail = ["_warm=None", "_reset=None", "_seed=None"] \
            if self.refresh_variant else []
        sig = ", ".join(self._sig_head(args)
                        + [f"{a}=None" for a in args[1:]] + tail)
        em.w(f"def {name}({sig}):")
        with em.block():
            em.w(f"N = {g}.num_nodes")
            em.w("_vids = jnp.arange(N, dtype=jnp.int32)")
            for p in f.params:
                if p.kind == "prop_node":
                    self.declare(p.name, p.dtype)
                    em.w(f"if {p.name} is None:")
                    with em.block():
                        em.w(f"{p.name} = rt.init_prop(N, {self.jdt(p.dtype)!s})")
                elif p.kind == "scalar":
                    self.dtypes[p.name] = p.dtype
            warm_pending = self.refresh_variant
            for s in f.body:
                if warm_pending and isinstance(
                        s, (I.IFixedPoint, I.IDoWhile, I.IWhile)):
                    self._emit_warm_start(s)
                    warm_pending = False
                self.stmt(s, HostCtx())
            rets = ", ".join(f"'{v}': {v}" for v in self.declared)
            em.w(f"return {{{rets}}}")
        return em.source()

    def _emit_warm_start(self, s: I.IRStmt):
        """Warm-override block of a `__refresh` variant.

        Emitted AFTER the program's own init statements and immediately
        before the first top-level iterative construct, so source-level
        init writes (`src.dist = 0`) still stand for reset vertices:
        every node property falls back to its previous converged value
        except where `_reset` (the deletion cone) marks it stale, and for
        a fixedPoint with a boolean convergence prop the `_seed` frontier
        is OR-ed in so the first warm sweep relaxes exactly from the
        update-incident vertices."""
        em = self.em
        em.w("if _warm is not None:")
        with em.block():
            for p in self.declared:
                if p in self.f.node_props:
                    em.w(f"{p} = rt.warm_start({p}, _warm.get('{p}'), _reset)")
            if isinstance(s, I.IFixedPoint) and \
                    self.f.node_props.get(s.conv_prop) == "bool":
                em.w("if _seed is not None:")
                with em.block():
                    em.w(f"{s.conv_prop} = {s.conv_prop} "
                         f"| jnp.asarray(_seed)")

    # ------------------------------------------------------------------ stmts
    def stmt(self, s: I.IRStmt, ctx):
        m = getattr(self, f"s_{type(s).__name__}", None)
        if m is None:
            raise CodegenError(f"{self.backend_name}: unhandled {type(s).__name__}")
        m(s, ctx)

    def body(self, stmts, ctx):
        for s in stmts:
            self.stmt(s, ctx)

    # ---- host-level -----------------------------------------------------------
    def s_IAttach(self, s: I.IAttach, ctx):
        if s.kind != "node":
            raise CodegenError("edge properties not yet supported in codegen")
        for prop, dtype, init in s.props:
            self.declare(prop, dtype)
            if self.batch is not None:
                # per-source property inside a batched set loop → [B, N]
                self.batch.arrays.add(prop)
                b = self.batch.size
                if init is None:
                    self.em.w(f"{prop} = rt.init_prop_batch({b}, N, {self.jdt(dtype)})")
                elif isinstance(init, I.IConst) and init.kind == "inf":
                    self.em.w(f"{prop} = rt.init_prop_batch({b}, N, {self.jdt(dtype)}, rt.inf_for({self.jdt(dtype)}))")
                else:
                    self.em.w(f"{prop} = rt.init_prop_batch({b}, N, {self.jdt(dtype)}, {self.ex.expr(init, ctx)})")
                continue
            if init is None:
                self.em.w(f"{prop} = rt.init_prop(N, {self.jdt(dtype)})")
            elif isinstance(init, I.IConst) and init.kind == "inf":
                self.em.w(f"{prop} = rt.init_prop(N, {self.jdt(dtype)}, rt.inf_for({self.jdt(dtype)}))")
            else:
                self.em.w(f"{prop} = rt.init_prop(N, {self.jdt(dtype)}, {self.ex.expr(init, ctx)})")

    def s_IDeclScalar(self, s: I.IDeclScalar, ctx):
        em = self.em
        if s.vertex_local and self._vertex_ctx(ctx) is None \
                and self._edge_ctx(ctx) is None:
            # declared at set-loop body depth (outside any vertex/edge
            # region): a per-source "lane" scalar with host-scalar semantics
            # per source — a plain scalar in the sequential lowering, one
            # [B] slot per lane in a batched region
            if self.batch is not None and not self.supports_batched_scalar_loops:
                raise CodegenError("per-source scalar inside a batched source "
                                   "loop (falls back to the sequential loop)")
            self.lane_scalars.add(s.name)
            init = self.ex.expr(s.init, ctx) if s.init is not None else "0"
            if self.batch is not None:
                self.batch.lane_scalars.add(s.name)
                em.w(f"{s.name} = jnp.broadcast_to(jnp.asarray({init}, "
                     f"{self.jdt(s.dtype)}), ({self.batch.size},))")
            else:
                em.w(f"{s.name} = jnp.asarray({init}, {self.jdt(s.dtype)})")
            self.declare(s.name, s.dtype)
            return
        if s.vertex_local:
            shape = (f"({self.batch.size}, {self.VLEN})" if self.batch is not None
                     else f"({self.VLEN},)")
            if s.init is None or isinstance(s.init, I.IConst):
                init = "0" if s.init is None else self.ex.expr(s.init, ctx)
                em.w(f"{s.name} = jnp.full({shape}, {init}, {self.jdt(s.dtype)})")
            else:
                em.w(f"{s.name} = ({self.ex.expr(s.init, ctx)}) * jnp.ones({shape}, {self.jdt(s.dtype)})")
            if self.batch is not None:
                self.batch.arrays.add(s.name)
            self.dtypes[s.name] = s.dtype
            return
        if self.batch is not None:
            raise CodegenError("host-scalar declaration inside a batched "
                               "source loop (per-source scalars unsupported)")
        init = self.ex.expr(s.init, ctx) if s.init is not None else "0"
        em.w(f"{s.name} = jnp.asarray({init}, {self.jdt(s.dtype)})")
        self.declare(s.name, s.dtype)

    def s_ICopyProp(self, s: I.ICopyProp, ctx):
        if self.batch is not None:
            ba = self.batch.arrays
            if (s.dst in ba) != (s.src in ba):
                raise CodegenError("copy between batched and shared property")
        self.em.w(f"{self.wtarget(s.dst)} = {s.src}")

    def s_IWriteProp(self, s: I.IWriteProp, ctx):
        node = self.ex.expr(s.node, ctx)
        val = self.ex.expr(s.expr, ctx)
        p = self.wtarget(s.prop)
        if self.batch is not None:
            b = self.batch
            if s.prop not in b.arrays:
                raise CodegenError("single-node write to a shared property "
                                   "inside a batched source loop")
            if node != b.srcs2d:
                raise CodegenError("batched single-node write must target the "
                                   "set iterator")
            # lane-diagonal write: row b updates its own source vertex
            self.em.w(f"{p} = {p}.at[{b.lane}, {b.srcs}].set({val})")
            return
        self.em.w(f"{p} = {p}.at[{node}].set({val})")

    def s_IAssign(self, s: I.IAssign, ctx):
        em = self.em
        e = self.ex.expr(s.expr, ctx)
        dt = self.dtype_of(s.name)
        cast = (lambda x: f"jnp.asarray({x}, {self.jdt(dt)})") if dt else (lambda x: x)
        vctx = self._vertex_ctx(ctx)
        ectx = self._edge_ctx(ctx)
        if s.name in self.lane_scalars:
            return self._lane_scalar_assign(s, e, vctx, ectx)
        if s.reduce_op is None:
            if s.vertex_local:
                if vctx is not None and vctx.mask:
                    em.w(f"{s.name} = jnp.where({vctx.mask}, {e}, {s.name})")
                else:
                    em.w(f"{s.name} = {e}")
            else:
                if self.batch is not None:
                    raise CodegenError("host-scalar assignment inside a "
                                       "batched source loop")
                em.w(f"{s.name} = {cast(e)}")
            return
        op = _RED[s.reduce_op]
        if s.vertex_local:
            if ectx is not None:
                # per-vertex accumulation over the neighborhood → segment op
                masked = f"jnp.where({ectx.mask}, {e}, 0)" if ectx.mask else e
                if self.batch is not None:
                    b = self.batch
                    em.w(f"{s.name} = {s.name} {op} rt.segment_sum_batch("
                         f"jnp.broadcast_to(jnp.asarray({masked}), ({b.size},) + {ectx.seg}.shape), "
                         f"{ectx.seg}, {self.VLEN}, sorted_ids={ectx.seg_sorted})")
                else:
                    em.w(f"{s.name} = {s.name} {op} rt.segment_sum({masked}, {ectx.seg}, {self.VLEN}, sorted_ids={ectx.seg_sorted})")
            elif vctx is not None and vctx.mask:
                em.w(f"{s.name} = jnp.where({vctx.mask}, {s.name} {op} ({e}), {s.name})")
            else:
                em.w(f"{s.name} = {s.name} {op} ({e})")
            return
        # host scalar reduction (paper Table 1) from a parallel region
        if self.batch is not None:
            if s.reduce_op != "+":
                raise CodegenError(f"host-scalar {s.reduce_op} reduction "
                                   "inside a batched source loop")
            valid = f"{self.batch.valid}[:, None]"
            if ectx is not None or vctx is not None:
                mask = (ectx or vctx).mask
                m = f"({mask}) & {valid}" if mask else valid
                em.w(f"{s.name} = {cast(f'{s.name} + jnp.sum(jnp.where({m}, {e}, 0))')}")
            else:
                raise CodegenError("host-scalar update outside any loop in a "
                                   "batched source loop")
            return
        if ectx is not None:
            masked = f"jnp.where({ectx.mask}, {e}, 0)" if ectx.mask else e
            em.w(f"{s.name} = {cast(f'{s.name} {op} jnp.sum({masked})')}")
        elif vctx is not None:
            masked = f"jnp.where({vctx.mask}, {e}, 0)" if vctx.mask else e
            em.w(f"{s.name} = {cast(f'{s.name} {op} jnp.sum({masked})')}")
        else:
            em.w(f"{s.name} = {cast(f'{s.name} {op} ({e})')}")

    def _lane_scalar_assign(self, s: I.IAssign, e: str, vctx, ectx):
        """Assignment to a per-source lane scalar (declared at set-loop body
        depth): host-scalar reduction semantics per source. The sequential
        lowering is exactly the host-scalar paths; a batched region keeps a
        [B] lane axis — reductions from vertex/edge regions collapse the
        vertex/edge axis only, so each lane accumulates its own total."""
        em = self.em
        dt = self.dtype_of(s.name)
        cast = (lambda x: f"jnp.asarray({x}, {self.jdt(dt)})") if dt else (lambda x: x)
        b = self.batch
        if s.reduce_op is None:
            if vctx is not None or ectx is not None:
                raise CodegenError(f"unsynchronized write to per-source "
                                   f"scalar {s.name} from a parallel region")
            if b is not None:
                em.w(f"{s.name} = jnp.broadcast_to({cast(e)}, ({b.size},))")
            else:
                em.w(f"{s.name} = {cast(e)}")
            return
        op = _RED[s.reduce_op]
        if b is None:
            if ectx is not None:
                masked = f"jnp.where({ectx.mask}, {e}, 0)" if ectx.mask else e
                em.w(f"{s.name} = {cast(f'{s.name} {op} jnp.sum({masked})')}")
            elif vctx is not None:
                masked = f"jnp.where({vctx.mask}, {e}, 0)" if vctx.mask else e
                em.w(f"{s.name} = {cast(f'{s.name} {op} jnp.sum({masked})')}")
            else:
                em.w(f"{s.name} = {cast(f'{s.name} {op} ({e})')}")
            return
        if ectx is None and vctx is None:
            # set-body level: every lane applies the same scalar update
            em.w(f"{s.name} = {cast(f'{s.name} {op} ({e})')}")
            return
        if s.reduce_op != "+":
            raise CodegenError(
                f"per-source scalar {s.reduce_op} reduction from a parallel "
                "region inside a batched source loop")
        if ectx is not None:
            masked = f"jnp.where({ectx.mask}, {e}, 0)" if ectx.mask else e
            body = (f"jnp.broadcast_to(jnp.asarray({masked}), "
                    f"({b.size},) + {ectx.seg}.shape)")
        else:
            masked = f"jnp.where({vctx.mask}, {e}, 0)" if vctx.mask else e
            body = (f"jnp.broadcast_to(jnp.asarray({masked}), "
                    f"({b.size}, {self.VLEN}))")
        em.w(f"{s.name} = {cast(f'{s.name} + jnp.sum({body}, axis=1)')}")

    # ---- loops ------------------------------------------------------------------
    def _vertex_ctx(self, ctx):
        for c in ctx_chain(ctx):
            if isinstance(c, (VertexCtx, BFSCtx)):
                return c
        return None

    def _edge_ctx(self, ctx):
        for c in ctx_chain(ctx):
            if isinstance(c, EdgeCtx):
                return c
        return None

    def s_IVertexLoop(self, s: I.IVertexLoop, ctx):
        mask = None
        if s.filter is not None:
            mask = self._vmask(
                self.ex.expr(s.filter, VertexCtx(it=s.it, mask=None, parent=ctx)))
        vctx = VertexCtx(it=s.it, mask=mask, parent=ctx)
        self.body(s.body, vctx)

    def s_INbrLoop(self, s: I.INbrLoop, ctx):
        em = self.em
        g = self.f.graph_param
        vctx = self._vertex_ctx(ctx)
        if vctx is None:
            raise CodegenError("neighbor loop outside a vertex context")
        # wedge pattern (TC): nested neighbor loop over the same source
        if self._try_wedge(s, ctx):
            return
        if isinstance(vctx, BFSCtx):
            return self._bfs_nbr_loop(s, ctx, vctx)
        if s.direction == "out":
            ectx = EdgeCtx(it=s.it, source=s.source, direction="out",
                           vid=f"{g}.edge_src", nid=f"{g}.indices",
                           w=f"{g}.weights", seg=f"{g}.edge_src",
                           seg_sorted=True, mask=None, parent=ctx)
        else:
            ectx = EdgeCtx(it=s.it, source=s.source, direction="in",
                           vid=f"{g}.rev_edge_dst", nid=f"{g}.rev_indices",
                           w=f"{g}.rev_weights", seg=f"{g}.rev_edge_dst",
                           seg_sorted=True, mask=None, parent=ctx)
        terms = []
        pure = True
        if vctx.mask:
            terms.append(self.bg(vctx.mask, ectx.vid))
            ectx.src_vmask = vctx.mask
        if s.filter is not None:
            if pure_vertex_predicate(s.filter, s.it):
                # neighbor-side filter that only reads nbr-props: hoist it to
                # one [N] vertex mask (the frontier the engine switches on)
                nm = self._vmask(
                    self.ex.expr(s.filter, VertexCtx(it=s.it, mask=None, parent=ctx)))
                terms.append(self.bg(nm, ectx.nid))
                ectx.it_vmask = nm
            else:
                terms.append(self.ex.expr(s.filter, ectx))
                pure = False
        ectx.pure_frontier = pure
        if terms:
            mask = em.uid("em")
            em.w(f"{mask} = {' & '.join(terms)}")
            ectx.mask = mask
        self.body(s.body, ectx)

    def _bfs_nbr_loop(self, s: I.INbrLoop, ctx, bctx: BFSCtx):
        """neighbors() inside iterateInBFS = BFS-DAG successors (paper §2.3.2)."""
        em = self.em
        g = self.f.graph_param
        if s.direction != "out":
            raise CodegenError("only neighbors() supported inside iterateInBFS")
        ectx = EdgeCtx(it=s.it, source=s.source, direction="out",
                       vid=f"{g}.edge_src", nid=f"{g}.indices",
                       w=f"{g}.weights", seg=f"{g}.edge_src",
                       seg_sorted=True, mask=None, parent=ctx)
        terms = [f"({self.bg(bctx.level, ectx.vid)} == {bctx.cur})",
                 f"({self.bg(bctx.level, ectx.nid)} == ({bctx.cur} + 1))"]
        if bctx.mask:
            terms.append(self.bg(bctx.mask, ectx.vid))
        if s.filter is not None:
            terms.append(self.ex.expr(s.filter, ectx))
        mask = em.uid("em")
        em.w(f"{mask} = {' & '.join(terms)}")
        ectx.mask = mask
        self.body(s.body, ectx)

    # ---- in-loop writes -------------------------------------------------------
    def s_IAssignProp(self, s: I.IAssignProp, ctx):
        em = self.em
        ectx = self._edge_ctx(ctx)
        vctx = self._vertex_ctx(ctx)
        p = self.wtarget(s.prop)
        e = self.ex.expr(s.expr, ctx)
        if self.batch is not None:
            return self._batched_assign_prop(s, ectx, vctx, p, e)
        if ectx is not None:
            if s.reduce_op is None:
                raise CodegenError(
                    f"unsynchronized per-edge write to {s.prop}; use a "
                    "reduction or the Min/Max construct")
            if s.reduce_op not in ("+", "||", "&&"):
                raise CodegenError(f"unsupported edge reduction {s.reduce_op}")
            masked = f"jnp.where({ectx.mask}, {e}, 0)" if ectx.mask else e
            if s.target == s_target_source(s, ectx):
                # pull: reduce over the neighborhood into the source vertex
                em.w(f"{p} = {p} + rt.segment_sum({masked}, {ectx.seg}, {self.VLEN}, sorted_ids={ectx.seg_sorted})")
            else:
                # push: combine into the neighbor (paper: atomics; here scatter)
                em.w(f"{p} = {p} + rt.segment_sum({masked}, {ectx.nid}, N, sorted_ids=False)")
            return
        if vctx is None:
            raise CodegenError("property assignment outside any loop")
        if s.reduce_op is None:
            if vctx.mask:
                em.w(f"{p} = jnp.where({vctx.mask}, {e}, {p})")
            else:
                # broadcast keeps scalar rhs (v.modified = True) array-shaped
                em.w(f"{p} = jnp.broadcast_to(jnp.asarray({e}, {p}.dtype), {p}.shape)")
        else:
            op = _RED[s.reduce_op]
            if vctx.mask:
                em.w(f"{p} = jnp.where({vctx.mask}, {p} {op} ({e}), {p})")
            else:
                em.w(f"{p} = {p} {op} ({e})")

    def _batched_assign_prop(self, s: I.IAssignProp, ectx, vctx, p: str, e: str):
        """Property write inside a batched source-set region.

        Batched ([B, N]) targets take the sequential lowering with the batch
        axis along for the ride (masks are [B, *], segment ops use the
        `_batch` variants). SHARED ([N]) targets collapse the lane axis with
        a `+` reduction masked to the chunk's valid lanes — the per-source
        contributions of the parallel `forall(src in sourceSet)`."""
        em = self.em
        b = self.batch
        batched_target = s.prop in b.arrays
        if ectx is not None:
            if s.reduce_op is None:
                raise CodegenError(
                    f"unsynchronized per-edge write to {s.prop}; use a "
                    "reduction or the Min/Max construct")
            if s.reduce_op != "+":
                raise CodegenError(f"unsupported batched edge reduction {s.reduce_op}")
            seg = ectx.seg if s.target == ectx.source else ectx.nid
            sorted_ = ectx.seg_sorted if s.target == ectx.source else False
            if batched_target:
                masked = f"jnp.where({ectx.mask}, {e}, 0)" if ectx.mask else e
                em.w(f"{p} = {p} + rt.segment_sum_batch("
                     f"jnp.broadcast_to(jnp.asarray({masked}), ({b.size},) + {seg}.shape), "
                     f"{seg}, {self.VLEN}, sorted_ids={sorted_})")
            else:
                m = (f"({ectx.mask}) & {b.valid}[:, None]" if ectx.mask
                     else f"{b.valid}[:, None]")
                em.w(f"{p} = {p} + rt.segment_sum(jnp.sum("
                     f"jnp.broadcast_to(jnp.asarray(jnp.where({m}, {e}, 0)), ({b.size},) + {seg}.shape), "
                     f"axis=0), {seg}, {self.VLEN}, sorted_ids={sorted_})")
            return
        if vctx is None:
            raise CodegenError("property assignment outside any loop")
        if batched_target:
            if s.reduce_op is None:
                if vctx.mask:
                    em.w(f"{p} = jnp.where({vctx.mask}, {e}, {p})")
                else:
                    em.w(f"{p} = jnp.broadcast_to(jnp.asarray({e}, {p}.dtype), {p}.shape)")
            else:
                op = _RED[s.reduce_op]
                if vctx.mask:
                    em.w(f"{p} = jnp.where({vctx.mask}, {p} {op} ({e}), {p})")
                else:
                    em.w(f"{p} = {p} {op} ({e})")
            return
        # shared [N] target: collapse the lane axis (valid lanes only)
        if s.reduce_op != "+":
            raise CodegenError(
                f"write to shared property {s.prop} inside a batched source "
                f"loop needs a '+' reduction (got {s.reduce_op!r})")
        m = (f"({vctx.mask}) & {b.valid}[:, None]" if vctx.mask
             else f"{b.valid}[:, None]")
        em.w(f"{p} = {p} + jnp.sum(jnp.where({m}, {e}, 0), axis=0)")

    def _hybrid_frontier(self, s: I.IMinMaxUpdate, ectx):
        """Detect the frontier-relax pattern `Min(t.p, other.p [+ e.weight])`
        where the contributing side is masked by nothing but a per-vertex
        frontier. Returns (applicable, frontier_var_or_None, weighted) —
        `weighted` is False for the bare-prop candidate (CC's unweighted
        component min), which takes the same push/pull machinery minus the
        `+ w` term."""
        if s.kind != "Min" or not ectx.pure_frontier:
            return False, None, True
        if self.f.node_props.get(s.prop) != "int32":
            return False, None, True
        if s.target == ectx.it and ectx.direction == "out":
            # push form: the outer vertex contributes along its out-edges
            other, frontier = ectx.source, ectx.src_vmask
            if ectx.it_vmask is not None:
                return False, None, True    # extra mask on the landing side
        elif s.target == ectx.source and ectx.direction == "in":
            # pull form: in-neighbors contribute into the outer vertex
            other, frontier = ectx.it, ectx.it_vmask
            if ectx.src_vmask is not None:
                return False, None, True
        else:
            return False, None, True
        cand = relax_candidate(s.cand, other)
        if cand is None or cand[0] != s.prop:
            return False, None, True
        return True, frontier, cand[1]

    def emit_relax_hybrid(self, s: I.IMinMaxUpdate, frontier,
                          weighted: bool = True):
        """Direction-optimized relax step: push (scatter-min from frontier
        sources) vs pull (segment-min over in-edges), switched on-device by
        frontier occupancy — or pinned by `Schedule.direction`; both
        branches compute the identical relaxation, so pinning never changes
        results. The occupancy threshold is emitted as a literal from the
        compiled schedule. Emitted inline (not as a call to
        rt.relax_minplus_hybrid, which is the same computation — keep in
        sync) so the generated source shows the full lowering, per the
        paper's source-to-source design.

        Inside a delta-stepping fixedPoint (`frontier` is the bucketed
        window) the relax goes through `rt.relax_minplus_delta` instead:
        same relaxation, but a frontier that fits the compact cap relaxes
        only its gathered ELL out-rows — O(cap * max_deg), not O(E)."""
        em = self.em
        g = self.f.graph_param
        sched = self.schedule
        new = em.uid("new")
        if frontier is None:
            em.w(f"{new} = rt.relax_minplus_hybrid({g}, {s.prop}, None"
                 f"{'' if weighted else ', weighted=False'})")
            return new
        if self._delta_prop == s.prop and self.supports_delta_ell:
            em.w(f"{new} = rt.relax_minplus_delta({g}, {s.prop}, {frontier}, "
                 f"_dell, max(min(N // 8, 4096), 32){self._engine_kwargs()}"
                 f"{'' if weighted else ', weighted=False'})")
            return new
        wexp = lambda w: f" + {w}" if weighted else ""  # noqa: E731
        push, pull = em.uid("push"), em.uid("pull")
        if sched.direction != "pull":
            em.w(f"{push} = lambda _d: rt.scatter_min(_d, {g}.indices, "
                 f"jnp.where({frontier}[{g}.edge_src], "
                 f"_d[{g}.edge_src]{wexp(f'{g}.weights')}, rt.INF))")
        if sched.direction != "push":
            em.w(f"{pull} = lambda _d: jnp.minimum(_d, rt.segment_min("
                 f"jnp.where({frontier}[{g}.rev_indices], "
                 f"_d[{g}.rev_indices]{wexp(f'{g}.rev_weights')}, rt.INF), "
                 f"{g}.rev_edge_dst, {self.VLEN}))")
        if sched.direction == "push":
            em.w(f"{new} = {push}({s.prop})")
        elif sched.direction == "pull":
            em.w(f"{new} = {pull}({s.prop})")
        else:
            em.w(f"{new} = jax.lax.cond(rt.frontier_should_push({frontier}, "
                 f"{self.VLEN}, {sched.push_threshold_frac!r}), "
                 f"{push}, {pull}, {s.prop})")
        return new

    def s_IMinMaxUpdate(self, s: I.IMinMaxUpdate, ctx):
        em = self.em
        if self.batch is not None:
            raise CodegenError("Min/Max construct inside a batched source "
                               "loop (falls back to the sequential lowering)")
        ectx = self._edge_ctx(ctx)
        if ectx is None:
            raise CodegenError("Min/Max update outside a neighbor loop")
        p = self.wtarget(s.prop)
        dtype = self.f.node_props.get(s.prop, "int32")
        ok, frontier, weighted = self._hybrid_frontier(s, ectx)
        if ok:
            new = self.emit_relax_hybrid(s, frontier, weighted)
            upd = em.uid("upd")
            em.w(f"{upd} = {new} < {s.prop}")
            em.w(f"{p} = {new}" if p == s.prop else
                 f"{p} = jnp.where({upd}, {new}, {p})")
            for eprop, _etgt, eval_ in s.extras:
                ep = self.wtarget(eprop)
                ev = self.ex.expr(eval_, HostCtx())
                em.w(f"{ep} = jnp.where({upd}, {ev}, {ep})")
            return
        cand = self.ex.expr(s.cand, ctx)
        cv = em.uid("cand")
        ident = f"rt.inf_for({self.jdt(dtype)})" if s.kind == "Min" else f"-rt.inf_for({self.jdt(dtype)})"
        if ectx.mask:
            em.w(f"{cv} = jnp.where({ectx.mask}, {cand}, {ident})")
        else:
            em.w(f"{cv} = {cand}")
        new = em.uid("new")
        if s.target == ectx.it:        # push: update lands on the neighbor
            fn = "rt.scatter_min" if s.kind == "Min" else "rt.scatter_max"
            em.w(f"{new} = {fn}({s.prop}, {ectx.nid}, {cv})")
        elif s.target == ectx.source:  # pull: reduce into the source vertex
            fn = "rt.segment_min" if s.kind == "Min" else "rt.segment_max"
            mm = "jnp.minimum" if s.kind == "Min" else "jnp.maximum"
            em.w(f"{new} = {mm}({s.prop}, {fn}({cv}, {ectx.seg}, {self.VLEN}, sorted_ids={ectx.seg_sorted}))")
        else:
            raise CodegenError(f"Min/Max target {s.target} not an endpoint of the loop")
        upd = em.uid("upd")
        cmp = "<" if s.kind == "Min" else ">"
        em.w(f"{upd} = {new} {cmp} {s.prop}")
        em.w(f"{p} = {new}" if p == s.prop else
             f"{p} = jnp.where({upd}, {new}, {p})")
        for eprop, _etgt, eval_ in s.extras:
            ep = self.wtarget(eprop)
            ev = self.ex.expr(eval_, HostCtx())  # vertex-uniform (True/False/const)
            em.w(f"{ep} = jnp.where({upd}, {ev}, {ep})")

    # ---- control flow ------------------------------------------------------------
    def s_IIf(self, s: I.IIf, ctx):
        ectx = self._edge_ctx(ctx)
        vctx = self._vertex_ctx(ctx)
        em = self.em
        if ectx is not None:
            mask = em.uid("em")
            cond = self.ex.expr(s.cond, ctx)
            em.w(f"{mask} = {f'{ectx.mask} & ' if ectx.mask else ''}{cond}")
            import dataclasses as _dc
            sub = _dc.replace(ectx, mask=mask, pure_frontier=False)
            self.body(s.then, sub)
            if s.els:
                raise CodegenError("else in edge context unsupported")
            return
        if vctx is not None:
            cond = self.ex.expr(s.cond, ctx)
            mask = self._vmask(f"{f'{vctx.mask} & ' if vctx.mask else ''}{cond}")
            import dataclasses as _dc
            sub = _dc.replace(vctx, mask=mask)
            self.body(s.then, sub)
            if s.els:
                raise CodegenError("else in vertex context unsupported")
            return
        raise CodegenError("host-level if unsupported (use fixedPoint/do-while)")

    def s_IFixedPoint(self, s: I.IFixedPoint, ctx):
        em = self.em
        if self.batch is not None:
            raise CodegenError("fixedPoint inside a batched source loop")
        conv = s.conv_prop
        delta = self._delta_target(s.body)
        if delta is not None and (delta == conv or
                                  self.f.node_props.get(conv) != "bool"):
            delta = None    # bucketing needs a bool pending-mask conv prop
        self.declare(s.var, "bool")
        em.w(f"{s.var} = jnp.asarray(False)")
        carry = self.carries(s.body)
        if s.var not in carry:
            carry.append(s.var)
        n = em.uid("fp")
        if delta is not None:
            em.w(f"{n}_bk = jnp.int32(0)")
            carry.append(f"{n}_bk")
        pack = ", ".join(carry)
        em.w(f"def {n}_cond(_state):")
        with em.block():
            em.w(f"({pack},) = _state" if len(carry) == 1 else f"({pack}) = _state")
            em.w(f"return ~{s.var}")
        em.w(f"def {n}_body(_state):")
        with em.block():
            em.w(f"({pack},) = _state" if len(carry) == 1 else f"({pack}) = _state")
            if delta is None:
                em.w(f"{conv}_nxt = jnp.zeros_like({conv})")
            else:
                # delta-stepping: the sweep's frontier is the pending set
                # restricted to the current bucket window; out-of-window
                # pending vertices seed the next sweep's pending set
                self._emit_delta_preamble(n, delta, conv)
                em.w(f"{conv}_nxt = {n}_keep")
            saved = dict(self.write_alias)
            self.write_alias[conv] = f"{conv}_nxt"
            prev_dprop = self._delta_prop
            self._delta_prop = delta
            try:
                self.body(s.body, ctx)
            finally:
                self._delta_prop = prev_dprop
                self.write_alias = saved
            em.w(f"{conv} = {conv}_nxt")
            self.emit_finished(s.var, conv)
            em.w(f"return ({pack},)" if len(carry) == 1 else f"return ({pack})")
        em.w(f"_state = jax.lax.while_loop({n}_cond, {n}_body, ({pack},))"
             if len(carry) == 1 else
             f"_state = jax.lax.while_loop({n}_cond, {n}_body, ({pack}))")
        em.w(f"({pack},) = _state" if len(carry) == 1 else f"({pack}) = _state")

    def _emit_delta_preamble(self, n: str, vprop: str, conv: str):
        """Bucketed-frontier preamble of a delta-stepping fixedPoint body.

        The window is upper-bound-only — `value < (bk + 1) * Δ` — so values
        that move backwards into earlier buckets (CC's component min) stay
        in the window; the fused advance jumps `bk` straight to the bucket
        of the smallest pending value, so no sweep relaxes an empty
        frontier. Rebinding `conv` to the windowed frontier makes every
        downstream filter/relax emission see the bucketed frontier without
        touching the rest of the lowering."""
        em = self.em
        d = self.schedule.delta_bucket
        em.w(f"{n}_bk = jnp.where("
             f"{self._delta_any(f'{conv} & ({vprop} < ({n}_bk + 1) * {d})')}, "
             f"{n}_bk, "
             f"{self._delta_min(f'jnp.where({conv}, {vprop}, rt.INF)')} // {d})")
        em.w(f"{n}_fr = {conv} & ({vprop} < ({n}_bk + 1) * {d})")
        em.w(f"{n}_keep = {conv} & ~{n}_fr")
        em.w(f"{conv} = {n}_fr")

    def _delta_any(self, expr: str) -> str:
        return f"jnp.any({expr})"

    def _delta_min(self, expr: str) -> str:
        return f"jnp.min({expr})"

    def emit_finished(self, var: str, conv: str):
        self.em.w(f"{var} = ~jnp.any({conv})")

    def s_IDoWhile(self, s: I.IDoWhile, ctx):
        em = self.em
        if self.batch is not None:
            if not self.supports_batched_scalar_loops:
                raise CodegenError("do-while inside a batched source loop")
            return self._batched_scalar_loop(s, ctx, do_while=True)
        carry = self.carries(s.body)
        pack = ", ".join(carry)
        n = em.uid("dw")
        first = f"{n}_first"
        em.w(f"def {n}_cond(_state):")
        with em.block():
            em.w(f"({first}, {pack}) = _state")
            em.w(f"return {first} | ({self.ex.expr(s.cond, ctx)})")
        em.w(f"def {n}_body(_state):")
        with em.block():
            em.w(f"({first}, {pack}) = _state")
            self.body(s.body, ctx)
            em.w(f"return (jnp.asarray(False), {pack})")
        em.w(f"_state = jax.lax.while_loop({n}_cond, {n}_body, (jnp.asarray(True), {pack}))")
        em.w(f"({first}, {pack}) = _state")

    def s_IWhile(self, s: I.IWhile, ctx):
        em = self.em
        if self.batch is not None:
            if not self.supports_batched_scalar_loops:
                raise CodegenError("while inside a batched source loop")
            return self._batched_scalar_loop(s, ctx, do_while=False)
        carry = self.carries(s.body)
        pack = ", ".join(carry)
        n = em.uid("wl")
        em.w(f"def {n}_cond(_state):")
        with em.block():
            em.w(f"({pack},) = _state" if len(carry) == 1 else f"({pack}) = _state")
            em.w(f"return {self.ex.expr(s.cond, ctx)}")
        em.w(f"def {n}_body(_state):")
        with em.block():
            em.w(f"({pack},) = _state" if len(carry) == 1 else f"({pack}) = _state")
            self.body(s.body, ctx)
            em.w(f"return ({pack},)" if len(carry) == 1 else f"return ({pack})")
        em.w(f"_state = jax.lax.while_loop({n}_cond, {n}_body, ({pack}{',' if len(carry) == 1 else ''}))")
        em.w(f"({pack},) = _state" if len(carry) == 1 else f"({pack}) = _state")

    def _batched_scalar_loop(self, s, ctx, do_while: bool):
        """Per-source `while` / `do-while` inside a BATCHED source-set
        region: all B lanes run one fused `jax.lax.while_loop`. The loop
        condition evaluates per lane (lane scalars read as [B] at host
        level); the fused loop runs while ANY lane is still active, and
        lanes that already converged are FROZEN — every carried per-source
        value ([B, N] property or [B] lane scalar) rolls back to its
        previous value on inactive lanes after each sweep, so an
        early-converging lane keeps exactly the state it converged to."""
        em = self.em
        b = self.batch
        carry = self.carries(s.body)
        if not carry:
            raise CodegenError("batched per-source loop carries no state")
        for v in carry:
            if v not in b.arrays and v not in b.lane_scalars:
                raise CodegenError(
                    f"batched per-source loop writes shared state {v} "
                    "(falls back to the sequential lowering)")
        cond = self.ex.expr(s.cond, ctx)
        pack = ", ".join(carry)
        one = len(carry) == 1
        n = em.uid("bdw" if do_while else "bwl")
        first = f"{n}_first"
        state = f"({first}, {pack})" if do_while else \
            (f"({pack},)" if one else f"({pack})")
        em.w(f"def {n}_cond(_state):")
        with em.block():
            em.w(f"{state} = _state")
            any_ = f"jnp.any({cond})"
            em.w(f"return {first} | {any_}" if do_while else f"return {any_}")
        em.w(f"def {n}_body(_state):")
        with em.block():
            em.w(f"{state} = _state")
            act = f"{first} | ({cond})" if do_while else cond
            em.w(f"{n}_act = jnp.broadcast_to(jnp.asarray({act}), ({b.size},))")
            for v in carry:
                em.w(f"{n}_p_{v} = {v}")
            self.body(s.body, ctx)
            for v in carry:
                sel = f"{n}_act" if v in b.lane_scalars else f"{n}_act[:, None]"
                em.w(f"{v} = jnp.where({sel}, {v}, {n}_p_{v})")
            if do_while:
                em.w(f"return (jnp.asarray(False), {pack})")
            else:
                em.w(f"return ({pack},)" if one else f"return ({pack})")
        init = f"(jnp.asarray(True), {pack})" if do_while else \
            (f"({pack},)" if one else f"({pack})")
        em.w(f"_state = jax.lax.while_loop({n}_cond, {n}_body, {init})")
        em.w(f"{state} = _state")

    def s_ISetLoop(self, s: I.ISetLoop, ctx):
        bs = self.schedule.batch_sources
        if self.supports_source_batching and self.batch is None and bs and bs > 1:
            state = self._snapshot()
            try:
                return self._batched_set_loop(s, ctx, int(bs))
            except CodegenError:
                # pattern outside the batched subset (fixedPoint, Min/Max,
                # per-source scalars, ...): fall back to the sequential loop
                self._restore(state)
        self._sequential_set_loop(s, ctx)

    def _sequential_set_loop(self, s: I.ISetLoop, ctx):
        em = self.em
        carry = self.carries(s.body)
        pack = ", ".join(carry)
        n = em.uid("set")
        mark = len(self.declared)
        saved_ls = set(self.lane_scalars)
        em.w(f"def {n}_body(_i, _carry):")
        with em.block():
            em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")
            em.w(f"{s.it} = {s.set_name}[_i]")
            hctx = HostCtx()
            hctx.node_bindings[s.it] = s.it
            try:
                self.body(s.body, hctx)
            finally:
                self.lane_scalars = saved_ls
            em.w(f"return ({pack},)" if len(carry) == 1 else f"return ({pack})")
        del self.declared[mark:]   # loop-local props don't escape
        # static shape guard: fori_loop traces its body even for a zero trip
        # count, and indexing an empty sourceSet would fail at trace time
        em.w(f"if {s.set_name}.shape[0]:")
        with em.block():
            em.w(f"_carry = jax.lax.fori_loop(0, {s.set_name}.shape[0], {n}_body, ({pack}{',' if len(carry) == 1 else ''}))")
            em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")

    def _batched_set_loop(self, s: I.ISetLoop, ctx, bs: int):
        """`forall(src in sourceSet)` as ceil(S/B) chunked BATCHED passes:
        each chunk traverses B sources at once (per-source [N] properties
        become [B, N] matrices, every SpMV a B-lane SpMM) and reduces its
        contribution into the shared properties at chunk end. The final
        partial chunk is padded with repeats of the last source and masked
        out of every shared-property reduction, so S need not divide B."""
        em = self.em
        ss = s.set_name
        carry = self.carries(s.body)
        pack = ", ".join(carry)
        n = em.uid("bset")
        B, lane, srcs, ok = f"{n}_B", f"{n}_lane", f"{n}_src", f"{n}_ok"
        mark = len(self.declared)
        em.w(f"{B} = max(min({bs}, {ss}.shape[0]), 1)")
        em.w(f"def {n}_body(_c, _carry):")
        with em.block():
            em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")
            em.w(f"{n}_idx = _c * {B} + jnp.arange({B}, dtype=jnp.int32)")
            em.w(f"{ok} = {n}_idx < {ss}.shape[0]")
            em.w(f"{srcs} = {ss}[jnp.clip({n}_idx, 0, {ss}.shape[0] - 1)]")
            em.w(f"{lane} = jnp.arange({B}, dtype=jnp.int32)")
            info = BatchInfo(size=B, lane=lane, srcs=srcs,
                             srcs2d=f"{srcs}[:, None]", valid=ok, it=s.it)
            self.batch = info
            self.ex.batch = info
            saved_ls = set(self.lane_scalars)
            hctx = HostCtx()
            hctx.node_bindings[s.it] = info.srcs2d
            try:
                self.body(s.body, hctx)
            finally:
                self.batch = None
                self.ex.batch = None
                self.lane_scalars = saved_ls
            em.w(f"return ({pack},)" if len(carry) == 1 else f"return ({pack})")
        del self.declared[mark:]   # loop-local props don't escape
        # static shape guard: fori_loop traces its body even for a zero trip
        # count, and indexing an empty sourceSet would fail at trace time
        em.w(f"if {ss}.shape[0]:")
        with em.block():
            em.w(f"_carry = jax.lax.fori_loop(0, -(-{ss}.shape[0] // {B}), {n}_body, ({pack}{',' if len(carry) == 1 else ''}))")
            em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")

    def s_IBFS(self, s: I.IBFS, ctx):
        em = self.em
        g = self.f.graph_param
        root = self.ex.expr(s.root, ctx)
        lvl = em.uid("level")
        dep = em.uid("depth")
        if self.batch is not None:
            if root != self.batch.srcs2d:
                raise CodegenError("batched iterateInBFS root must be the "
                                   "set iterator")
            # one batched BFS: level[b] == bfs_levels(g, srcs[b]); depth is
            # the deepest lane's count — shallower lanes see empty frontiers
            em.w(f"{lvl}, {dep} = rt.bfs_levels_batch({g}, {self.batch.srcs}"
                 f"{self._engine_kwargs()})")
            self.batch.arrays.add(lvl)
        else:
            em.w(f"{lvl}, {dep} = rt.bfs_levels({g}, {root}"
                 f"{self._engine_kwargs()})")
        # forward pass: level-synchronous over the BFS DAG
        carry = self.carries(s.body)
        pack = ", ".join(carry)
        n = em.uid("bfsf")
        em.w(f"def {n}(_l, _carry):")
        with em.block():
            em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")
            bctx = BFSCtx(it=s.it, level=lvl, cur="_l", mask=None, parent=ctx)
            self.body(s.body, bctx)
            em.w(f"return ({pack},)" if len(carry) == 1 else f"return ({pack})")
        em.w(f"_carry = jax.lax.fori_loop(0, {dep} - 1, {n}, ({pack}{',' if len(carry) == 1 else ''}))")
        em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")
        if s.rev_body is None:
            return
        # reverse pass: levels from deepest-1 down to 0
        carry = self.carries(s.rev_body)
        pack = ", ".join(carry)
        n = em.uid("bfsr")
        em.w(f"def {n}(_k, _carry):")
        with em.block():
            em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")
            em.w(f"_l = {dep} - 2 - _k")
            vm = self._vmask(f"({lvl} == _l)")
            bctx = BFSCtx(it=s.it, level=lvl, cur="_l", mask=vm, parent=ctx)
            if s.rev_filter is not None:
                em.w(f"{vm} = {vm} & ({self.ex.expr(s.rev_filter, bctx)})")
            self.body(s.rev_body, bctx)
            em.w(f"return ({pack},)" if len(carry) == 1 else f"return ({pack})")
        em.w(f"_carry = jax.lax.fori_loop(0, {dep} - 1, {n}, ({pack}{',' if len(carry) == 1 else ''}))")
        em.w(f"({pack},) = _carry" if len(carry) == 1 else f"({pack}) = _carry")

    def s_IReturn(self, s: I.IReturn, ctx):
        pass  # outputs are returned as the property/scalar dict

    # ---- wedge (TC) pattern ------------------------------------------------------
    def _try_wedge(self, s: I.INbrLoop, ctx) -> bool:
        inner = s.body[0] if len(s.body) == 1 and isinstance(s.body[0], I.INbrLoop) else None
        if inner is None or inner.source != s.source or s.direction != "out" \
                or inner.direction != "out":
            return False
        iff = inner.body[0] if len(inner.body) == 1 and isinstance(inner.body[0], I.IIf) else None
        if iff is None or not isinstance(iff.cond, I.ICall) or iff.cond.fn != "is_an_edge":
            raise CodegenError("nested same-source neighbor loops support only "
                               "the is_an_edge counting pattern (paper Fig. 20)")
        red = iff.then[0] if len(iff.then) == 1 and isinstance(iff.then[0], I.IAssign) else None
        if red is None or red.reduce_op != "+":
            raise CodegenError("wedge body must be a count reduction")
        if self.batch is not None:
            raise CodegenError("wedge pattern inside a batched source loop")
        g = self.f.graph_param
        dt = self.dtype_of(red.name)
        acc = f"{red.name} + rt.wedge_count({g}) * ({self.ex.expr(red.expr, HostCtx())})"
        self.em.w(f"{red.name} = jnp.asarray({acc}, {self.jdt(dt)})" if dt else
                  f"{red.name} = {acc}")
        return True


def s_target_source(s: I.IAssignProp, ectx) -> str:
    return ectx.source


def has_refresh_variant(irfn: I.IRFunction) -> bool:
    """True when a `<name>__refresh` incremental variant is emitted next to
    the program: the body has a TOP-LEVEL iterative construct to
    warm-start. Programs whose loops live inside a set loop (BC's
    per-source BFS) or that have no loop at all (TC) get no variant —
    there is no converged per-node state a delta could reuse."""
    return any(isinstance(s, (I.IFixedPoint, I.IDoWhile, I.IWhile))
               for s in irfn.body)


def generate_local(irfn: I.IRFunction, schedule: Optional[Schedule] = None,
                   batch_sources: Optional[int] = None) -> str:
    """Emit the local-backend source under `schedule` (default: the ENGINE
    shim's snapshot). Every knob is baked in as a literal — the same
    schedule yields byte-identical source. `batch_sources` is the legacy
    per-program override (0/1 = sequential set loops). Programs with a
    top-level iterative construct additionally carry a `<name>__refresh`
    incremental variant (fresh codegen instance — emitter/declared state
    is per-function)."""
    src = LocalCodegen(irfn, schedule=schedule,
                       batch_sources=batch_sources).generate()
    if has_refresh_variant(irfn):
        cg = LocalCodegen(irfn, schedule=schedule, batch_sources=batch_sources)
        cg.refresh_variant = True
        src = src + "\n\n" + cg.generate()
    return src
