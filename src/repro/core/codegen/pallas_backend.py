"""Pallas backend — the paper's CUDA code generator, rethought for TPU.

The CUDA backend turns each outermost `forall` into a kernel launch with
thread-per-vertex + atomics (paper §3.2). TPU has no SIMT threads and no
atomics, so this backend restructures the two hot patterns into blocked
dense Pallas kernels (see kernels/ell_spmv), now over the degree-bucketed
sliced-ELL view with frontier-aware direction optimization:

  * Min/Max edge relaxation  → per-bucket min-plus SpMV over the REVERSE
    (in-edge) sliced-ELL view, masked to the current frontier, with an
    on-device switch to scatter-push over the CSR out-edges when the
    frontier is sparse (Beamer-style direction optimization). The frontier
    is the fixedPoint convergence property, threaded through the generated
    while_loop carry; each relax recomputes it from the update mask. Pull
    from non-frontier sources cannot change the result (relaxation is
    monotone-idempotent), so push and pull branches agree exactly.
  * neighborhood sum reductions (PR) → per-bucket (+,×) SpMV of a per-node
    contribution vector (plus the COO hub fallback inside the op).

Everything else (BFS, scalar reductions, fixed point) inherits the local
backend's vectorized lowering — those are memory-bound scatter/gathers XLA
already fuses well; the kernels own the compute-dense inner loops.
"""
from __future__ import annotations

from .. import ir as I
from .base import HostCtx, VertexCtx
from .local_jax import LocalCodegen, has_refresh_variant


def _only_reads_side(expr, side: str) -> bool:
    """True if expr reads only <side>.prop / degree(<side>) / constants."""
    ok = True

    def visit(e):
        nonlocal ok
        if isinstance(e, I.IProp):
            if e.target != side:
                ok = False
        elif isinstance(e, I.IEdgeWeight):
            ok = False
        elif isinstance(e, I.IIterId) and e.name != side:
            ok = False
        elif isinstance(e, I.IBin):
            visit(e.left); visit(e.right)
        elif isinstance(e, I.IUn):
            visit(e.operand)
        elif isinstance(e, I.ICall):
            for a in e.args:
                visit(a)

    visit(expr)
    return ok


class PallasCodegen(LocalCodegen):
    backend_name = "pallas"
    # the kernel op already takes an arbitrary frontier mask, so a delta-
    # stepping fixedPoint relaxes its bucketed window through the same
    # sliced-ELL kernels — no separate `_dell` padded view needed
    supports_delta_ell = False

    def _block_rows_literal(self) -> str:
        """`Schedule.block_rows` as a source literal for the kernel ops.

        A uniform int cap stays an int; per-bucket caps are emitted as a
        {bucket_width: cap} mapping (width-keyed, because empty buckets are
        dropped from a graph's sliced view, so positional caps would drift
        per graph)."""
        s = self.schedule
        if isinstance(s.block_rows, int):
            return repr(s.block_rows)
        return repr(dict(zip(s.bucket_widths(), s.bucket_block_rows())))

    def _kernel_kwargs(self) -> str:
        """Literal kwargs for kops calls: engine knobs + kernel block caps."""
        return f"{self._engine_kwargs()}, block_rows={self._block_rows_literal()}"

    def _sig_head(self, args):
        # the bound sliced-ELL view is a required positional (the bind/api
        # layer resolves it from the GraphContext per call)
        return [args[0], "_ell"]

    # ---- hot pattern 1: frontier relax → sliced-ELL hybrid kernel ------------
    def emit_relax_hybrid(self, s: I.IMinMaxUpdate, frontier,
                          weighted: bool = True):
        """Same pattern the local backend detects, lowered to the kernel op:
        per-bucket pull kernels over the reverse sliced-ELL view, or
        scatter-push over the CSR edge arrays when the frontier is sparse
        (the op owns the on-device occupancy switch). The compiled
        schedule's threshold/direction are baked in as literals. Under
        delta-stepping the frontier arriving here is already the bucketed
        window, so the same kernel call applies unchanged. The unweighted
        relax (CC) keeps the inherited inline jnp lowering — the min-plus
        kernels are weighted."""
        if not weighted:
            return super().emit_relax_hybrid(s, frontier, weighted)
        em = self.em
        g = self.f.graph_param
        new = em.uid("new")
        fr = frontier or "None"
        em.w(f"{new} = kops.relax_minplus(_ell, {s.prop}, frontier={fr}, "
             f"csr={g}{self._kernel_kwargs()})")
        return new

    # ---- hot pattern 2: neighborhood sum → sliced-ELL (+,×) kernel -----------
    def s_IAssign(self, s: I.IAssign, ctx):
        ectx = self._edge_ctx(ctx)
        # the gather kernel produces one [N] vector: batched ([B, N]) regions
        # and per-source lane scalars keep the inherited segment lowering
        if (s.reduce_op == "+" and s.vertex_local and ectx is not None
                and ectx.direction == "in" and ectx.mask is None
                and self.batch is None and s.name not in self.lane_scalars
                and _only_reads_side(s.expr, ectx.it)):
            em = self.em
            contrib = em.uid("contrib")
            # evaluate the per-edge term as a per-NODE vector (nbr ↦ node)
            vctx = VertexCtx(it=ectx.it, mask=None, parent=HostCtx())
            em.w(f"{contrib} = {self.ex.expr(s.expr, vctx)}")
            em.w(f"{contrib} = jnp.asarray({contrib}, jnp.float32) * jnp.ones((N,), jnp.float32)")
            em.w(f"{s.name} = {s.name} + kops.gather_plustimes(_ell, "
                 f"{contrib}, block_rows={self._block_rows_literal()})")
            return
        super().s_IAssign(s, ctx)


def generate_pallas(irfn: I.IRFunction, schedule=None, batch_sources=None,
                    **opts):
    cg = PallasCodegen(irfn, schedule=schedule, batch_sources=batch_sources)
    body = cg.generate()
    if has_refresh_variant(irfn):
        rcg = PallasCodegen(irfn, schedule=schedule,
                            batch_sources=batch_sources)
        rcg.refresh_variant = True
        body = body + "\n\n" + rcg.generate()
    from ...kernels.ell_spmv import ops as kops
    return body, {"kops": kops}
