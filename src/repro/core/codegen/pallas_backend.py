"""Pallas backend — the paper's CUDA code generator, rethought for TPU.

The CUDA backend turns each outermost `forall` into a kernel launch with
thread-per-vertex + atomics (paper §3.2). TPU has no SIMT threads and no
atomics, so this backend restructures the two hot patterns into blocked
dense Pallas kernels (see kernels/ell_spmv):

  * Min/Max edge relaxation  → block-ELL min-plus SpMV over the REVERSE
    (in-edge) ELL view. Push becomes pull: instead of scattering
    atomicMin(&dist[nbr], ...) we gather min over in-neighbors — same
    fixed point, zero write contention. The frontier filter is dropped:
    relaxation is monotone-idempotent, so relaxing from non-modified
    sources cannot change the result, and the dense sweep keeps the MXU/VPU
    pipelines regular (the TPU version of "enough parallelism to keep the
    resources busy").
  * neighborhood sum reductions (PR) → block-ELL (+,×) SpMV of a per-node
    contribution vector.

Everything else (BFS, scalar reductions, fixed point) inherits the local
backend's vectorized lowering — those are memory-bound scatter/gathers XLA
already fuses well; the kernels own the compute-dense inner loops.
"""
from __future__ import annotations

from .. import ir as I
from .base import CodegenError, EdgeCtx, HostCtx, VertexCtx
from .local_jax import LocalCodegen, _RED


def _prop_plus_weight(cand, other_side: str):
    """Match `<other>.prop + e.weight` (either order) → prop name, or None."""
    if not isinstance(cand, I.IBin) or cand.op != "+":
        return None
    a, b = cand.left, cand.right
    for x, y in ((a, b), (b, a)):
        if isinstance(x, I.IProp) and x.target == other_side and \
                isinstance(y, I.IEdgeWeight):
            return x.prop
    return None


def _only_reads_side(expr, side: str) -> bool:
    """True if expr reads only <side>.prop / degree(<side>) / constants."""
    ok = True

    def visit(e):
        nonlocal ok
        if isinstance(e, I.IProp):
            if e.target != side:
                ok = False
        elif isinstance(e, I.IEdgeWeight):
            ok = False
        elif isinstance(e, I.IIterId) and e.name != side:
            ok = False
        elif isinstance(e, I.IBin):
            visit(e.left); visit(e.right)
        elif isinstance(e, I.IUn):
            visit(e.operand)
        elif isinstance(e, I.ICall):
            for a in e.args:
                visit(a)

    visit(expr)
    return ok


class PallasCodegen(LocalCodegen):
    backend_name = "pallas"

    def generate(self) -> str:
        f, em = self.f, self.em
        g = f.graph_param
        args = [p.name for p in f.params]
        sig = ", ".join([args[0], "_ell_cols", "_ell_wts"]
                        + [f"{a}=None" for a in args[1:]])
        em.w(f"def {f.name}({sig}):")
        with em.block():
            em.w(f"N = {g}.num_nodes")
            em.w("_vids = jnp.arange(N, dtype=jnp.int32)")
            for p in f.params:
                if p.kind == "prop_node":
                    self.declare(p.name, p.dtype)
                    em.w(f"if {p.name} is None:")
                    with em.block():
                        em.w(f"{p.name} = rt.init_prop(N, {self.jdt(p.dtype)})")
                elif p.kind == "scalar":
                    self.dtypes[p.name] = p.dtype
            for s in f.body:
                self.stmt(s, HostCtx())
            rets = ", ".join(f"'{v}': {v}" for v in self.declared)
            em.w(f"return {{{rets}}}")
        return em.source()

    # ---- hot pattern 1: Min/Max relax → ELL min-plus kernel ------------------
    def s_IMinMaxUpdate(self, s: I.IMinMaxUpdate, ctx):
        ectx = self._edge_ctx(ctx)
        if ectx is None:
            raise CodegenError("Min/Max outside a neighbor loop")
        if s.kind != "Min":
            return super().s_IMinMaxUpdate(s, ctx)
        # which side feeds the candidate? push: source side; pull: nbr side
        other = ectx.source if s.target == ectx.it else ectx.it
        prop = _prop_plus_weight(s.cand, other)
        if prop != s.prop:
            return super().s_IMinMaxUpdate(s, ctx)
        em = self.em
        p = self.wtarget(s.prop)
        new = em.uid("new")
        # reverse-ELL pull sweep — the kernel includes min with the current
        # value, so this is exactly one Bellman-Ford relaxation step.
        em.w(f"{new} = kops.relax_minplus(_ell_cols, _ell_wts, {s.prop})")
        upd = em.uid("upd")
        em.w(f"{upd} = {new} < {s.prop}")
        em.w(f"{p} = {new}" if p == s.prop else f"{p} = jnp.where({upd}, {new}, {p})")
        for eprop, _t, eval_ in s.extras:
            ep = self.wtarget(eprop)
            ev = self.ex.expr(eval_, HostCtx())
            em.w(f"{ep} = jnp.where({upd}, {ev}, {ep})")

    # ---- hot pattern 2: neighborhood sum → ELL (+,×) kernel -------------------
    def s_IAssign(self, s: I.IAssign, ctx):
        ectx = self._edge_ctx(ctx)
        if (s.reduce_op == "+" and s.vertex_local and ectx is not None
                and ectx.direction == "in" and ectx.mask is None
                and _only_reads_side(s.expr, ectx.it)):
            em = self.em
            contrib = em.uid("contrib")
            # evaluate the per-edge term as a per-NODE vector (nbr ↦ node)
            vctx = VertexCtx(it=ectx.it, mask=None, parent=HostCtx())
            em.w(f"{contrib} = {self.ex.expr(s.expr, vctx)}")
            em.w(f"{contrib} = jnp.asarray({contrib}, jnp.float32) * jnp.ones((N,), jnp.float32)")
            em.w(f"{s.name} = {s.name} + kops.gather_plustimes(_ell_cols, {contrib})[:N]")
            return
        super().s_IAssign(s, ctx)


def generate_pallas(irfn: I.IRFunction, **opts):
    cg = PallasCodegen(irfn)
    body = cg.generate()
    from ...kernels.ell_spmv import ops as kops
    return body, {"kops": kops}
