"""Shared code-generation machinery.

Backends emit real Python/JAX *source text* (the paper's compiler is
source-to-source; so is this one — the generated module is inspectable via
`CompiledProgram.source`). Every engine knob a backend consults comes from
the compiled `Schedule` and is emitted as a literal into that text — the
generated program never reads mutable global state, so one schedule means
one byte-identical source. The vectorization model:

  host ctx    : scalars are 0-d jnp values, properties are [N] arrays
  vertex ctx  : `forall(v in g.nodes())` — statements become whole-array ops;
                a filter is a boolean mask (predication, the TPU analogue of
                the paper's `if (!modified[v]) continue;`)
  edge ctx    : `forall(nbr in g.neighbors(v)/g.nodes_to(v))` — statements
                become per-edge ops on the CSR edge arrays; reads of v.prop /
                nbr.prop gather through the edge endpoint ids; reductions
                lower to segment ops (pull) or scatter combines (push)
  BFS ctx     : `iterateInBFS` — per-level masks over the BFS DAG
                (level[src]==l && level[dst]==l+1), per the paper's semantics
                that `neighbors()` means DAG neighbors inside the construct
  wedge ctx   : doubly-nested neighbor loops over the same vertex (TC)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from .. import ir as I


class CodegenError(Exception):
    pass


_BINOP = {"+": "+", "-": "-", "*": "*", "/": "/", "%": "%",
          "<": "<", ">": ">", "<=": "<=", ">=": ">=", "==": "==", "!=": "!=",
          "&&": "&", "||": "|"}
_UNOP = {"!": "~", "-": "-"}


class Emitter:
    def __init__(self):
        self.lines: List[str] = []
        self.indent = 0
        self._uid = 0

    def uid(self, prefix: str) -> str:
        self._uid += 1
        return f"_{prefix}{self._uid}"

    def w(self, line: str = ""):
        self.lines.append("    " * self.indent + line if line else "")

    def block(self):
        return _IndentCtx(self)

    def source(self) -> str:
        return "\n".join(self.lines) + "\n"


class _IndentCtx:
    def __init__(self, em):
        self.em = em

    def __enter__(self):
        self.em.indent += 1

    def __exit__(self, *a):
        self.em.indent -= 1


# --------------------------------------------------------------------------
# Emission contexts
# --------------------------------------------------------------------------

@dataclass
class HostCtx:
    kind: str = "host"
    node_bindings: dict = field(default_factory=dict)  # node-param/set-iter name -> py expr


@dataclass
class VertexCtx:
    it: str
    mask: Optional[str]          # name of [N] bool mask var, or None
    parent: object = None
    kind: str = "vertex"


@dataclass
class EdgeCtx:
    it: str                      # neighbor iterator name
    source: str                  # outer vertex iterator
    direction: str               # 'out' | 'in'
    vid: str                     # py expr: edge-array ids of the source side
    nid: str                     # py expr: edge-array ids of the neighbor side
    w: str                       # py expr: per-edge weights
    seg: str = ""                # py expr: segment ids for reductions to the source
    seg_sorted: bool = True      # seg array sorted (CSR row order)?
    mask: Optional[str] = None   # [E] bool mask var, or None
    # frontier-engine bookkeeping: the [N] vertex masks the edge mask was
    # derived from, when it was derived from nothing else (`pure_frontier`).
    src_vmask: Optional[str] = None  # [N] mask of the source side (vertex filter)
    it_vmask: Optional[str] = None   # [N] mask of the neighbor side (nbr filter)
    pure_frontier: bool = False      # mask == exactly those vmask gathers
    parent: object = None
    kind: str = "edge"


@dataclass
class BFSCtx:
    it: str                      # BFS vertex iterator
    level: str                   # py expr for the level array var
    cur: str                     # py expr for current level scalar
    mask: Optional[str]          # [N] vertex mask (level==cur [& rev filter])
    parent: object = None
    kind: str = "bfs"


@dataclass
class BatchInfo:
    """Active batched source-set region (`forall(src in sourceSet)` with
    `Schedule.batch_sources > 1`): per-source vertex state is [B, N] — row b
    is source b's view — and the fields below are the generated-code names
    the emitters use to index into the batch."""

    size: str                    # py expr: static chunk width (python int)
    lane: str                    # py expr: int32[B] = arange(B)
    srcs: str                    # py expr: int32[B] source ids of this chunk
    srcs2d: str                  # py expr: [B, 1] view (broadcasts over [.., N])
    valid: str                   # py expr: bool[B] padding mask (last chunk)
    it: str                      # the set-iterator name bound to srcs2d
    arrays: set = field(default_factory=set)  # names shaped [B, N] (vs shared [N])
    # per-source scalars declared at set-loop body depth: one value per
    # lane, shaped [B] (vs the [B, N] property arrays above)
    lane_scalars: set = field(default_factory=set)


def ctx_chain(ctx):
    while ctx is not None:
        yield ctx
        ctx = getattr(ctx, "parent", None)


# --------------------------------------------------------------------------
# Pattern helpers (frontier-engine hot-path detection)
# --------------------------------------------------------------------------

def prop_plus_weight(cand, other_side: str):
    """Match `<other>.prop + e.weight` (either order) → prop name, or None."""
    if not isinstance(cand, I.IBin) or cand.op != "+":
        return None
    a, b = cand.left, cand.right
    for x, y in ((a, b), (b, a)):
        if isinstance(x, I.IProp) and x.target == other_side and \
                isinstance(y, I.IEdgeWeight):
            return x.prop
    return None


def relax_candidate(cand, other_side: str):
    """Match a Min-relax candidate contributed by `other_side`: either
    `<other>.prop + e.weight` (the weighted SSSP relax) or a bare
    `<other>.prop` (the unweighted relax — CC's component min). Returns
    (prop, weighted) or None; both shapes route through the same push/pull
    frontier machinery, the unweighted one simply drops the `+ w` term."""
    p = prop_plus_weight(cand, other_side)
    if p is not None:
        return p, True
    if isinstance(cand, I.IProp) and cand.target == other_side:
        return cand.prop, False
    return None


def pure_vertex_predicate(expr, side: str) -> bool:
    """True if `expr` reads only <side>.prop, constants, and host scalars —
    i.e. it can be evaluated once as an [N] vertex mask instead of per edge.
    Rejects edge weights, foreign iterators, and vertex-local scalars (which
    are aligned to the *outer* vertex, not `side`)."""
    ok = True

    def visit(e):
        nonlocal ok
        if isinstance(e, I.IProp):
            if e.target != side:
                ok = False
        elif isinstance(e, (I.IEdgeWeight, I.IVertexLocal)):
            ok = False
        elif isinstance(e, I.IIterId) and e.name != side:
            ok = False
        elif isinstance(e, I.IBin):
            visit(e.left); visit(e.right)
        elif isinstance(e, I.IUn):
            visit(e.operand)
        elif isinstance(e, I.ICall):
            for a in e.args:
                visit(a)

    visit(expr)
    return ok


class ExprEmitter:
    """IR expression → python source, given a context."""

    def __init__(self, irfn: I.IRFunction, graph_var: str = "g"):
        self.irfn = irfn
        self.g = graph_var
        # fixedPoint write-redirect: prop -> replacement var (read side stays)
        self.prop_read_alias: dict = {}
        # active batched source-set region (set by the codegen), or None
        self.batch: Optional[BatchInfo] = None

    # -- helpers --------------------------------------------------------------
    def index_of(self, name: str, ctx) -> str:
        """Array (or scalar) of ids for iterator/param `name` in `ctx`."""
        for c in ctx_chain(ctx):
            if isinstance(c, EdgeCtx):
                if name == c.source:
                    return c.vid
                if name == c.it:
                    return c.nid
            elif isinstance(c, VertexCtx) and name == c.it:
                return "_vids"
            elif isinstance(c, BFSCtx) and name == c.it:
                return "_vids"
            elif isinstance(c, HostCtx) and name in c.node_bindings:
                return c.node_bindings[name]
        return name  # node param / set iterator bound as a local python var

    def prop_read(self, prop: str) -> str:
        return self.prop_read_alias.get(prop, prop)

    def expr(self, e: I.IRExpr, ctx) -> str:
        if isinstance(e, I.IConst):
            if e.kind == "inf":
                return "rt.INF"
            if e.kind == "bool":
                return "True" if e.value else "False"
            return repr(e.value)
        if isinstance(e, I.IScalar):
            return e.name
        if isinstance(e, I.IVertexLocal):
            b = self.batch
            if b is not None and e.name in b.lane_scalars:
                # per-source [B] scalar read inside a vertex/edge/BFS region:
                # add a trailing axis so it broadcasts against the [B, N] /
                # [B, E] arrays of the batched region; at host level the
                # bare [B] value is the per-lane scalar itself
                for c in ctx_chain(ctx):
                    if isinstance(c, (VertexCtx, EdgeCtx, BFSCtx)):
                        return f"{e.name}[:, None]"
            return e.name
        if isinstance(e, I.INodeParam):
            return self.index_of(e.name, ctx)
        if isinstance(e, I.IIterId):
            return self.index_of(e.name, ctx)
        if isinstance(e, I.IProp):
            arr = self.prop_read(e.prop)
            if e.target is None:
                return arr
            idx = self.index_of(e.target, ctx)
            if idx == "_vids":
                return arr            # vertex ctx: aligned whole array
            b = self.batch
            if b is not None and e.prop in b.arrays:
                if idx == b.srcs2d:   # src.prop on a batched prop: lane-diagonal
                    return f"{arr}[{b.lane}, {b.srcs}][:, None]"
                return f"{arr}[:, {idx}]"   # batched gather: [B, E] / [B, ...]
            return f"{arr}[{idx}]"
        if isinstance(e, I.IEdgeWeight):
            for c in ctx_chain(ctx):
                if isinstance(c, EdgeCtx):
                    return c.w
            raise CodegenError("e.weight outside a neighbor loop")
        if isinstance(e, I.IBin):
            return f"({self.expr(e.left, ctx)} {_BINOP[e.op]} {self.expr(e.right, ctx)})"
        if isinstance(e, I.IUn):
            return f"({_UNOP[e.op]}{self.expr(e.operand, ctx)})"
        if isinstance(e, I.ICall):
            return self.call(e, ctx)
        raise CodegenError(f"unhandled expr {type(e).__name__}")

    def call(self, e: I.ICall, ctx) -> str:
        g = self.g
        if e.fn == "num_nodes":
            return f"{g}.num_nodes"
        if e.fn == "num_edges":
            return f"{g}.num_edges"
        if e.fn == "count_out_nbrs":
            idx = self.expr(e.args[0], ctx)
            return f"{g}.out_degree" if idx == "_vids" else f"{g}.out_degree[{idx}]"
        if e.fn == "count_in_nbrs":
            idx = self.expr(e.args[0], ctx)
            return f"{g}.in_degree" if idx == "_vids" else f"{g}.in_degree[{idx}]"
        if e.fn == "is_an_edge":
            u = self.expr(e.args[0], ctx)
            w = self.expr(e.args[1], ctx)
            return f"rt.is_an_edge({g}, {u}, {w})"
        if e.fn == "abs":
            return f"jnp.abs({self.expr(e.args[0], ctx)})"
        if e.fn == "min_wt":
            return f"jnp.min({g}.weights)"
        if e.fn == "max_wt":
            return f"jnp.max({g}.weights)"
        raise CodegenError(f"unknown builtin {e.fn}")
