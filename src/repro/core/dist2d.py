"""Beyond-paper distributed path: 2-D adjacency partitioning.

The paper's MPI backend is 1-D: every BSP step moves O(N) property bytes
per process (all-gather of the frontier + combine of candidates). That is
fine at 96 ranks and fatal at 512+. The classic fix (CombBLAS / 2-D SpMV)
blocks the adjacency over an R×C device grid so each step moves only

    all_gather along 'data'  : N/C   bytes per device (source block)
    reduce-scatter 'model'   : N/C   bytes per device (dest partials)

i.e. O(N/√P) for a square grid — a 16× collective-byte reduction on the
16×16 production mesh. State lives as N/(R·C) pieces per device; the edge
tiles carry pre-remapped local indices (graph/partition.py:partition_2d).

These steps are validated against the NumPy oracles across mesh shapes in
tests/test_dist2d.py (plus the single-shape checks in
tests/test_distributed.py); benchmarks/bench_table5_mpi.py times them
against the 1-D backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..graph.csr import CSRGraph, INF_I32
from ..graph.partition import partition_2d
from . import runtime as rt
from .runtime_dist import shard_map as _shard_map

DATA, MODEL = "data", "model"


def prepare_graph_2d(g: CSRGraph, rows: int, cols: int) -> dict:
    """Edge tiles + metadata, stacked [R, C, ...] for shard_map."""
    part = partition_2d(g, rows, cols)
    return {
        "src_local": part.src_local,
        "dst_local": part.dst_local,
        "weight": part.weight,
        "valid": part.valid,
        "piece": part.piece,            # static
        "rows": rows, "cols": cols,     # static
        "n_true": g.num_nodes,
        "out_degree": np.asarray(g.out_degree),
    }


def specs_2d(mesh):
    return {
        "src_local": P(DATA, MODEL, None), "dst_local": P(DATA, MODEL, None),
        "weight": P(DATA, MODEL, None), "valid": P(DATA, MODEL, None),
    }


def _own_global_ids(piece, c):
    i = jax.lax.axis_index(DATA)
    j = jax.lax.axis_index(MODEL)
    b = i * c + j
    return b * piece + jnp.arange(piece, dtype=jnp.int32)


def _reduce_scatter_min(part, c, piece):
    """Min-reduce-scatter along 'model' via all_to_all + local min.
    part: [C * piece] destination-block candidates."""
    chunks = part.reshape(c, piece)
    swapped = jax.lax.all_to_all(chunks, MODEL, split_axis=0, concat_axis=0)
    return jnp.min(swapped, axis=0)


def _reduce_scatter_sum(part, c, piece):
    return jax.lax.psum_scatter(part.reshape(c, piece), MODEL,
                                scatter_dimension=0, tiled=False).reshape(piece)


# --------------------------------------------------------------------------
# SSSP (2-D relax until fixed point)
# --------------------------------------------------------------------------

def sssp_2d(g: CSRGraph, mesh, src: int = 0):
    r, c = mesh.shape[DATA], mesh.shape[MODEL]
    gd = prepare_graph_2d(g, r, c)
    piece = gd["piece"]

    def body(src_local, dst_local, weight, valid, src_id):
        src_local, dst_local = src_local[0, 0], dst_local[0, 0]
        weight, valid = weight[0, 0], valid[0, 0]
        own = _own_global_ids(piece, c)
        dist = jnp.where(own == src_id, 0, INF_I32).astype(jnp.int32)
        block_rows = piece * c     # destination block size N/R

        def cond(state):
            return ~state[1]

        def step(state):
            dist, _ = state
            xj = jax.lax.all_gather(dist, DATA, tiled=True)       # [piece*R]
            cand = jnp.where(valid, xj[src_local] + weight, INF_I32)
            part = rt.segment_min(cand, dst_local, block_rows, sorted_ids=False)
            new = jnp.minimum(dist, _reduce_scatter_min(part, c, piece))
            changed = jnp.any(new < dist)
            changed = jax.lax.psum(changed.astype(jnp.int32), DATA)
            changed = jax.lax.psum(changed, MODEL) > 0
            return new, ~changed

        dist, _ = jax.lax.while_loop(cond, step, (dist, jnp.bool_(False)))
        return dist

    fn = jax.jit(_shard_map(
        body, mesh=mesh,
        in_specs=(P(DATA, MODEL, None),) * 4 + (P(),),
        out_specs=P((DATA, MODEL))))
    out = fn(gd["src_local"], gd["dst_local"], gd["weight"], gd["valid"],
             jnp.int32(src))
    return out[: g.num_nodes]


# --------------------------------------------------------------------------
# PageRank (2-D gather until convergence)
# --------------------------------------------------------------------------

def pagerank_2d(g: CSRGraph, mesh, delta: float = 0.85, beta: float = 1e-4,
                max_iter: int = 100):
    # PR pulls over in-edges of v, i.e. exactly the original edge set u→v:
    # tile (i,j) holds edges with v=dst ∈ block_i (accumulator side, 'data')
    # and u=src ∈ colset_j (contributor side, 'model').
    r, c = mesh.shape[DATA], mesh.shape[MODEL]
    gd = partition_2d(g, r, c)
    piece = gd.piece
    n = g.num_nodes
    deg_pad = np.zeros(piece * r * c, np.float32)
    deg_pad[:n] = np.maximum(np.asarray(g.out_degree), 1)
    # out-degree of the gathered source block, in x_j (i-interleaved) order
    deg_blocks = deg_pad.reshape(r * c, piece)   # piece b
    # piece b = i*c + j → column j gathers pieces [j, c+j, 2c+j, ...] in i order
    deg_xj = np.stack([deg_blocks[np.arange(r) * c + j].reshape(-1)
                       for j in range(c)])       # [C, piece*R]

    def body(src_local, dst_local, valid, deg_j):
        src_local, dst_local, valid = src_local[0, 0], dst_local[0, 0], valid[0, 0]
        deg_j = deg_j[0]
        own = _own_global_ids(piece, c)
        pr = jnp.full((piece,), 1.0 / n, jnp.float32)
        block_rows = piece * c

        def cond(state):
            _, diff, it, first = state
            return first | ((diff > beta) & (it < max_iter))

        def step(state):
            pr, _, it, _ = state
            xj = jax.lax.all_gather(pr, DATA, tiled=True)         # [piece*R]
            contrib = xj / deg_j
            term = jnp.where(valid, contrib[src_local], 0.0)
            part = rt.segment_sum(term, dst_local, block_rows, sorted_ids=False)
            summ = _reduce_scatter_sum(part, c, piece)
            val = (1 - delta) / n + delta * summ
            val = jnp.where(own < n, val, 0.0)
            diff = jnp.sum(jnp.abs(val - pr))
            diff = jax.lax.psum(jax.lax.psum(diff, DATA), MODEL)
            return val, diff, it + 1, jnp.bool_(False)

        pr, diff, it, _ = jax.lax.while_loop(
            cond, step, (pr, jnp.float32(0), jnp.int32(0), jnp.bool_(True)))
        return pr

    fn = jax.jit(_shard_map(
        body, mesh=mesh,
        in_specs=(P(DATA, MODEL, None),) * 3 + (P(MODEL, None),),
        out_specs=P((DATA, MODEL))))
    out = fn(gd.src_local, gd.dst_local, gd.valid, jnp.asarray(deg_xj))
    return out[: g.num_nodes]
