"""AST → IR lowering.

Normalizations performed here (so every backend sees the same canonical IR):
  * identifier roles resolved via the semantic symbol table;
  * `x = x + t` folded into a reduce-assign (`x += t`) — the paper lets the
    user write either form (Fig. 5 line 5 vs line 7);
  * the Min/Max multiple assignment becomes one `IMinMaxUpdate` node;
  * filter sugar (`filter(modified == True)`) resolved to iterator props;
  * `fixedPoint until (v : !prop)` validated to the paper's canonical shape.
"""
from __future__ import annotations

from typing import List, Optional

from . import ast_nodes as A
from . import ir as I
from .semantic import FunctionInfo, analyze


class LowerError(Exception):
    pass


class Lowerer:
    def __init__(self, fn: A.Function, info: FunctionInfo):
        self.fn = fn
        self.info = info
        self.edge_bindings = {}   # edge var -> (src_iter, nbr_iter)
        self.loop_depth = 0

    def run(self) -> I.IRFunction:
        params = []
        for p in self.info.params:
            params.append(I.IRParam(name=p.name, kind=p.kind, dtype=p.dtype))
        body = self.stmts(self.fn.body.stmts)
        scalars = {s.name: s.dtype for s in self.info.symbols.values()
                   if s.kind == "scalar" and not s.param and s.decl_depth == 0}
        return I.IRFunction(
            name=self.fn.name, params=params, body=body,
            node_props=dict(self.info.node_props),
            edge_props=dict(self.info.edge_props),
            scalars=scalars, graph_param=self.info.graph)

    # ------------------------------------------------------------------ stmts
    def stmts(self, lst: List[A.Statement]) -> List[I.IRStmt]:
        out = []
        for s in lst:
            r = self.stmt(s)
            if r is not None:
                out.extend(r if isinstance(r, list) else [r])
        return out

    def stmt(self, s: A.Statement):
        if isinstance(s, A.DeclarationStmt):
            return self.decl(s)
        if isinstance(s, A.AssignmentStmt):
            return self.assign(s)
        if isinstance(s, A.MultiAssignmentStmt):
            return self.multi_assign(s)
        if isinstance(s, A.ForallStmt):
            return self.forall(s)
        if isinstance(s, A.FixedPointStmt):
            return self.fixed_point(s)
        if isinstance(s, A.DoWhileStmt):
            return I.IDoWhile(cond=self.expr(s.cond), body=self.in_loop(s.body))
        if isinstance(s, A.WhileStmt):
            return I.IWhile(cond=self.expr(s.cond), body=self.in_loop(s.body))
        if isinstance(s, A.IfStmt):
            return I.IIf(cond=self.expr(s.cond),
                         then=self.stmts(s.then_body.stmts),
                         els=self.stmts(s.else_body.stmts) if s.else_body else [])
        if isinstance(s, A.IterateInBFSStmt):
            return self.bfs(s)
        if isinstance(s, A.ProcCallStmt):
            return self.proc_call_stmt(s.call)
        if isinstance(s, A.ReturnStmt):
            return I.IReturn(expr=self.expr(s.value) if s.value else None)
        if isinstance(s, A.BlockStmt):
            return self.stmts(s.stmts)
        raise LowerError(f"unhandled statement {type(s).__name__}")

    def in_loop(self, body: A.BlockStmt) -> List[I.IRStmt]:
        self.loop_depth += 1
        try:
            return self.stmts(body.stmts)
        finally:
            self.loop_depth -= 1

    def decl(self, s: A.DeclarationStmt):
        sym = self.info.symbols[s.name]
        if sym.kind in ("prop_node", "prop_edge"):
            # allocation happens at attachNodeProperty; a bare declaration
            # attaches a zero-initialized array so reads are always defined.
            return I.IAttach(props=[(s.name, sym.dtype, None)],
                             kind="node" if sym.kind == "prop_node" else "edge")
        if sym.kind == "edge_var":
            if sym.edge_between is None:
                raise LowerError(f"edge {s.name} must bind via g.getEdge(u, v)")
            self.edge_bindings[s.name] = sym.edge_between
            return None
        if sym.kind == "scalar":
            return I.IDeclScalar(
                name=s.name, dtype=sym.dtype,
                init=self.expr(s.init) if s.init else None,
                vertex_local=sym.decl_depth > 0)
        raise LowerError(f"cannot lower declaration of {s.name}")

    def assign(self, s: A.AssignmentStmt):
        rhs = s.rhs
        reduce_op = s.reduce_op
        # fold `x = x + t` (paper Fig. 5 line 5) into a reduce-assign
        if reduce_op is None and isinstance(rhs, A.BinaryOp) and rhs.op in ("+", "*"):
            lhs_key = self._lhs_key(s.lhs)
            if lhs_key is not None and self._lhs_key(rhs.left) == lhs_key:
                reduce_op, rhs = rhs.op, rhs.right
        if isinstance(s.lhs, A.Identifier):
            sym = s.lhs.sym
            if sym.kind in ("prop_node", "prop_edge"):
                if reduce_op is None and isinstance(rhs, A.Identifier) and \
                        rhs.sym.kind in ("prop_node", "prop_edge"):
                    return I.ICopyProp(dst=sym.name, src=rhs.sym.name)
                raise LowerError(f"unsupported whole-property assignment to {sym.name}")
            if sym.kind == "scalar":
                return I.IAssign(name=sym.name, expr=self.expr(rhs),
                                 reduce_op=reduce_op,
                                 vertex_local=sym.decl_depth > 0)
            raise LowerError(f"cannot assign to {sym.kind} {sym.name}")
        if isinstance(s.lhs, A.MemberAccess):
            tgt = s.lhs.target
            if not isinstance(tgt, A.Identifier):
                raise LowerError("chained member assignment unsupported")
            tsym = tgt.sym
            prop = s.lhs.member
            if tsym.kind in ("node_param", "iter_set"):
                return I.IWriteProp(prop=prop, node=self.expr(tgt),
                                    expr=self.expr(rhs))
            if tsym.kind in ("iter_vertex", "iter_nbr", "iter_bfs"):
                return I.IAssignProp(prop=prop, target=tsym.name,
                                     expr=self.expr(rhs), reduce_op=reduce_op)
            raise LowerError(f"cannot assign property via {tsym.kind}")
        raise LowerError("bad assignment lhs")

    def _lhs_key(self, e) -> Optional[str]:
        if isinstance(e, A.Identifier):
            return f"id:{e.name}"
        if isinstance(e, A.MemberAccess) and isinstance(e.target, A.Identifier):
            return f"mem:{e.target.name}.{e.member}"
        return None

    def multi_assign(self, s: A.MultiAssignmentStmt):
        if not s.values or not isinstance(s.values[0], A.MinMaxExpr):
            raise LowerError("multiple assignment must lead with Min/Max")
        mm = s.values[0]
        main = s.targets[0]
        if not (isinstance(main, A.MemberAccess) and isinstance(main.target, A.Identifier)):
            raise LowerError("Min/Max main target must be iter.prop")
        target_iter = main.target.name
        prop = main.member
        # Min(t.prop, cand) — first arg must be the target itself
        cand = mm.args[1]
        extras = []
        for t, v in zip(s.targets[1:], s.values[1:]):
            if not (isinstance(t, A.MemberAccess) and isinstance(t.target, A.Identifier)):
                raise LowerError("Min/Max extra target must be iter.prop")
            extras.append((t.member, t.target.name, self.expr(v)))
        return I.IMinMaxUpdate(prop=prop, target=target_iter,
                               cand=self.expr(cand), kind=mm.kind, extras=extras)

    def forall(self, s: A.ForallStmt):
        sym = s.iter_sym
        filt = self.expr(s.filter_expr, filter_iter=sym.name) if s.filter_expr is not None else None
        if sym.kind == "iter_vertex":
            return I.IVertexLoop(it=sym.name, filter=filt,
                                 body=self.in_loop(s.body), parallel=s.parallel)
        if sym.kind == "iter_nbr":
            return I.INbrLoop(it=sym.name, source=sym.source_iter,
                              direction=sym.direction, filter=filt,
                              body=self.in_loop(s.body), parallel=s.parallel)
        if sym.kind == "iter_set":
            return I.ISetLoop(it=sym.name, set_name=sym.source_iter,
                              body=self.in_loop(s.body))
        raise LowerError(f"bad forall iterator kind {sym.kind}")

    def fixed_point(self, s: A.FixedPointStmt):
        conv = s.conv_expr
        prop = None
        if isinstance(conv, A.UnaryOp) and conv.op == "!" and isinstance(conv.operand, A.Identifier):
            prop = conv.operand.name
        elif isinstance(conv, A.BinaryOp) and conv.op == "==" and \
                isinstance(conv.left, A.Identifier) and \
                isinstance(conv.right, A.Literal) and conv.right.value is False:
            prop = conv.left.name
        if prop is None or prop not in self.info.node_props:
            raise LowerError(
                "fixedPoint convergence must be !<bool node property>")
        return I.IFixedPoint(var=s.var, conv_prop=prop, body=self.in_loop(s.body))

    def bfs(self, s: A.IterateInBFSStmt):
        rev_f = rev_b = None
        if s.reverse is not None:
            rev_f = (self.expr(s.reverse.filter_expr, filter_iter=s.iterator.name)
                     if s.reverse.filter_expr is not None else None)
            rev_b = self.in_loop(s.reverse.body)
        return I.IBFS(it=s.iterator.name, root=self.expr(s.root),
                      body=self.in_loop(s.body), rev_filter=rev_f, rev_body=rev_b)

    def proc_call_stmt(self, call: A.ProcCall):
        if call.name in ("attachNodeProperty", "attachEdgeProperty"):
            kind = "node" if call.name == "attachNodeProperty" else "edge"
            props = []
            table = self.info.node_props if kind == "node" else self.info.edge_props
            for key, val in call.kwargs:
                if key not in table:
                    raise LowerError(f"attach of undeclared property {key}")
                props.append((key, table[key], self.expr(val)))
            return I.IAttach(props=props, kind=kind)
        raise LowerError(f"unsupported procedure call {call.name}")

    # ------------------------------------------------------------------ exprs
    def expr(self, e: A.Expression, filter_iter: Optional[str] = None) -> I.IRExpr:
        if isinstance(e, A.Literal):
            return I.IConst(value=e.value, kind=e.kind)
        if isinstance(e, A.Identifier):
            sym = e.sym
            if sym.kind in ("prop_node", "prop_edge"):
                target = getattr(e, "filter_sugar_iter", None) or filter_iter
                return I.IProp(prop=sym.name, target=target, dtype=sym.dtype)
            if sym.kind == "scalar":
                if sym.decl_depth > 0:
                    return I.IVertexLocal(name=sym.name, dtype=sym.dtype)
                return I.IScalar(name=sym.name, dtype=sym.dtype)
            if sym.kind == "node_param":
                return I.INodeParam(name=sym.name)
            if sym.kind in ("iter_vertex", "iter_nbr", "iter_bfs", "iter_set"):
                return I.IIterId(name=sym.name)
            raise LowerError(f"cannot reference {sym.kind} {sym.name}")
        if isinstance(e, A.MemberAccess):
            tgt = e.target
            if isinstance(tgt, A.Identifier):
                tsym = tgt.sym
                if tsym.kind == "edge_var":
                    if e.member != "weight":
                        raise LowerError(f"edge member {e.member} unsupported")
                    return I.IEdgeWeight(edge_var=tsym.name)
                dtype = self.info.node_props.get(e.member) or \
                    self.info.edge_props.get(e.member)
                if dtype is None:
                    raise LowerError(f"unknown property {e.member}")
                return I.IProp(prop=e.member, target=tsym.name, dtype=dtype)
            raise LowerError("chained member access unsupported")
        if isinstance(e, A.BinaryOp):
            return I.IBin(op=e.op, left=self.expr(e.left, filter_iter),
                          right=self.expr(e.right, filter_iter))
        if isinstance(e, A.UnaryOp):
            return I.IUn(op=e.op, operand=self.expr(e.operand, filter_iter))
        if isinstance(e, A.ProcCall):
            return self.call(e, filter_iter)
        if isinstance(e, A.MinMaxExpr):
            raise LowerError("Min/Max only valid in multiple assignment")
        raise LowerError(f"unhandled expression {type(e).__name__}")

    _CALLS = {"num_nodes": "num_nodes", "num_edges": "num_edges",
              "count_outNbrs": "count_out_nbrs", "count_outNbrs_": "count_out_nbrs",
              "count_inNbrs": "count_in_nbrs", "is_an_edge": "is_an_edge",
              "minWt": "min_wt", "maxWt": "max_wt", "abs": "abs"}

    def call(self, e: A.ProcCall, filter_iter=None) -> I.IRExpr:
        if e.name in self._CALLS:
            return I.ICall(fn=self._CALLS[e.name],
                           args=[self.expr(a, filter_iter) for a in e.args])
        raise LowerError(f"unsupported call {e.name}()")


def lower(prog: A.Program) -> List[I.IRFunction]:
    infos = analyze(prog)
    return [Lowerer(fn, infos[fn.name]).run() for fn in prog.functions]
