"""StarPlat DSL compiler — the paper's primary contribution.

Frontend: lexer → parser → AST (§2.4) → semantic analysis → IR.
Backends:  local (OpenMP analogue), distributed (MPI analogue, shard_map),
           pallas (CUDA analogue, TPU kernels).
"""
from .api import CompiledProgram, compile_bundled, compile_program, load_program_source

__all__ = ["CompiledProgram", "compile_bundled", "compile_program",
           "load_program_source"]
