"""StarPlat DSL compiler — the paper's primary contribution.

Frontend: lexer → parser → AST (§2.4) → semantic analysis → IR.
Backends:  local (OpenMP analogue), distributed (MPI analogue, shard_map),
           pallas (CUDA analogue, TPU kernels).
"""
from ..schedule import DEFAULT_SCHEDULE, Schedule
from .api import (BoundProgram, CompiledProgram, bind_cache_clear,
                  bind_cache_size, bundled_programs, compile_bundled,
                  compile_cache_clear, compile_cache_size, compile_program,
                  load_program_source)
from .context import GraphContext, get_context, prepare

__all__ = ["BoundProgram", "CompiledProgram", "DEFAULT_SCHEDULE",
           "GraphContext", "Schedule", "bind_cache_clear", "bind_cache_size",
           "bundled_programs", "compile_bundled", "compile_cache_clear",
           "compile_cache_size", "compile_program", "get_context",
           "load_program_source", "prepare"]
