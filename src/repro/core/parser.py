"""Recursive-descent parser for StarPlat → AST (paper §2 frontend).

The grammar follows the paper's concrete syntax: the five published programs
(Figs. 3, 18, 19, 20, 21) parse verbatim (modulo whitespace/line wrapping in
the PDF listing).
"""
from __future__ import annotations

from typing import List, Optional

from .ast_nodes import (
    AssignmentStmt, BinaryOp, BlockStmt, DeclarationStmt, DoWhileStmt,
    Expression, FixedPointStmt, ForallStmt, FormalParam, Function, Identifier,
    IfStmt, IterateInBFSStmt, IterateInReverseStmt, Literal, MemberAccess,
    MinMaxExpr, MultiAssignmentStmt, ProcCall, ProcCallStmt, Program,
    ReturnStmt, Statement, TypeNode, UnaryOp, WhileStmt,
)
from .lexer import Token, tokenize

TYPE_KEYWORDS = {"int", "bool", "long", "float", "double", "Graph", "node",
                 "edge", "propNode", "propEdge", "SetN", "SetE"}

REDUCE_ASSIGN = {"+=": "+", "-=": "-", "*=": "*", "/=": "/", "&&=": "&&", "||=": "||"}

_PRECEDENCE = [
    ("||",),
    ("&&",),
    ("==", "!="),
    ("<", ">", "<=", ">="),
    ("+", "-"),
    ("*", "/", "%"),
]


class ParseError(Exception):
    pass


class Parser:
    def __init__(self, src: str):
        self.toks: List[Token] = tokenize(src)
        self.pos = 0

    # --- token helpers -----------------------------------------------------
    def peek(self, off: int = 0) -> Token:
        return self.toks[min(self.pos + off, len(self.toks) - 1)]

    def next(self) -> Token:
        t = self.toks[self.pos]
        self.pos += 1
        return t

    def at(self, kind: str, value: Optional[str] = None, off: int = 0) -> bool:
        t = self.peek(off)
        return t.kind == kind and (value is None or t.value == value)

    def expect(self, kind: str, value: Optional[str] = None) -> Token:
        t = self.next()
        if t.kind != kind or (value is not None and t.value != value):
            raise ParseError(
                f"line {t.line}: expected {value or kind}, got {t.value!r}")
        return t

    def accept(self, kind: str, value: Optional[str] = None) -> Optional[Token]:
        if self.at(kind, value):
            return self.next()
        return None

    # --- top level ----------------------------------------------------------
    def parse_program(self) -> Program:
        first = self.peek().line
        functions = []
        while not self.at("eof"):
            functions.append(self.parse_function())
        return Program(functions=functions, line=first)

    def parse_function(self) -> Function:
        t = self.expect("kw", "function")
        name = self.expect("id").value
        self.expect("sym", "(")
        params = []
        while not self.at("sym", ")"):
            ty = self.parse_type()
            pname = self.expect("id").value
            params.append(FormalParam(ty=ty, name=pname, line=t.line))
            if not self.accept("sym", ","):
                break
        self.expect("sym", ")")
        body = self.parse_block()
        return Function(name=name, params=params, body=body, line=t.line)

    def parse_type(self) -> TypeNode:
        t = self.next()
        if t.kind != "kw" or t.value not in TYPE_KEYWORDS:
            raise ParseError(f"line {t.line}: expected type, got {t.value!r}")
        elem = None
        if t.value in ("propNode", "propEdge", "SetN", "SetE") and self.accept("sym", "<"):
            inner = self.next()
            elem = inner.value
            self.expect("sym", ">")
        return TypeNode(name=t.value, elem=elem, line=t.line)

    # --- statements ----------------------------------------------------------
    def parse_block(self) -> BlockStmt:
        t = self.expect("sym", "{")
        stmts: List[Statement] = []
        while not self.at("sym", "}"):
            stmts.append(self.parse_statement())
        self.expect("sym", "}")
        # attach trailing iterateInReverse to preceding iterateInBFS
        merged: List[Statement] = []
        for s in stmts:
            if (isinstance(s, IterateInReverseStmt) and merged
                    and isinstance(merged[-1], IterateInBFSStmt)
                    and merged[-1].reverse is None):
                merged[-1].reverse = s
            else:
                merged.append(s)
        return BlockStmt(stmts=merged, line=t.line)

    def parse_statement(self) -> Statement:
        t = self.peek()
        if t.kind == "kw":
            if t.value in TYPE_KEYWORDS:
                return self.parse_declaration()
            if t.value in ("forall", "for"):
                return self.parse_forall(parallel=t.value == "forall")
            if t.value == "fixedPoint":
                return self.parse_fixed_point()
            if t.value == "iterateInBFS":
                return self.parse_iterate_bfs()
            if t.value == "iterateInReverse":
                return self.parse_iterate_reverse()
            if t.value == "do":
                return self.parse_do_while()
            if t.value == "while":
                return self.parse_while()
            if t.value == "if":
                return self.parse_if()
            if t.value == "return":
                self.next()
                val = None if self.at("sym", ";") else self.parse_expression()
                self.expect("sym", ";")
                return ReturnStmt(value=val, line=t.line)
        if t.kind == "sym" and t.value == "<":
            return self.parse_multi_assignment()
        if t.kind == "sym" and t.value == "{":
            return self.parse_block()
        return self.parse_expr_statement()

    def parse_declaration(self) -> DeclarationStmt:
        ty = self.parse_type()
        name = self.expect("id").value
        init = None
        if self.accept("sym", "="):
            init = self.parse_expression()
        self.expect("sym", ";")
        return DeclarationStmt(ty=ty, name=name, init=init, line=ty.line)

    def parse_forall(self, parallel: bool) -> ForallStmt:
        t = self.next()  # forall | for
        self.expect("sym", "(")
        it = Identifier(name=self.expect("id").value, line=t.line)
        self.expect("kw", "in")
        rng = self.parse_expression()
        self.expect("sym", ")")
        rng, filt = self._strip_filter(rng)
        body = self.parse_block() if self.at("sym", "{") else BlockStmt(
            stmts=[self.parse_statement()], line=t.line)
        return ForallStmt(iterator=it, range_call=rng, filter_expr=filt,
                          body=body, parallel=parallel, line=t.line)

    def _strip_filter(self, rng: Expression):
        """g.nodes().filter(cond) → (g.nodes(), cond)"""
        if isinstance(rng, ProcCall) and rng.name == "filter":
            return rng.target, (rng.args[0] if rng.args else None)
        return rng, None

    def parse_fixed_point(self) -> FixedPointStmt:
        t = self.expect("kw", "fixedPoint")
        self.expect("kw", "until")
        self.expect("sym", "(")
        var = self.expect("id").value
        self.expect("sym", ":")
        conv = self.parse_expression()
        self.expect("sym", ")")
        body = self.parse_block()
        return FixedPointStmt(var=var, conv_expr=conv, body=body, line=t.line)

    def parse_iterate_bfs(self) -> IterateInBFSStmt:
        t = self.expect("kw", "iterateInBFS")
        self.expect("sym", "(")
        it = Identifier(name=self.expect("id").value, line=t.line)
        self.expect("kw", "in")
        rng = self.parse_expression()
        self.expect("kw", "from")
        root = self.parse_expression()
        self.expect("sym", ")")
        rng, filt = self._strip_filter(rng)
        body = self.parse_block()
        return IterateInBFSStmt(iterator=it, root=root, filter_expr=filt,
                                body=body, line=t.line)

    def parse_iterate_reverse(self) -> IterateInReverseStmt:
        t = self.expect("kw", "iterateInReverse")
        filt = None
        if self.accept("sym", "("):
            if not self.at("sym", ")"):
                filt = self.parse_expression()
            self.expect("sym", ")")
        body = self.parse_block()
        return IterateInReverseStmt(filter_expr=filt, body=body, line=t.line)

    def parse_do_while(self) -> DoWhileStmt:
        t = self.expect("kw", "do")
        body = self.parse_block()
        self.expect("kw", "while")
        self.expect("sym", "(")
        cond = self.parse_expression()
        self.expect("sym", ")")
        self.expect("sym", ";")
        return DoWhileStmt(body=body, cond=cond, line=t.line)

    def parse_while(self) -> WhileStmt:
        t = self.expect("kw", "while")
        self.expect("sym", "(")
        cond = self.parse_expression()
        self.expect("sym", ")")
        body = self.parse_block()
        return WhileStmt(cond=cond, body=body, line=t.line)

    def parse_if(self) -> IfStmt:
        t = self.expect("kw", "if")
        self.expect("sym", "(")
        cond = self.parse_expression()
        self.expect("sym", ")")
        then = self.parse_block() if self.at("sym", "{") else BlockStmt(
            stmts=[self.parse_statement()], line=t.line)
        els = None
        if self.accept("kw", "else"):
            els = self.parse_block() if self.at("sym", "{") else BlockStmt(
                stmts=[self.parse_statement()], line=t.line)
        return IfStmt(cond=cond, then_body=then, else_body=els, line=t.line)

    def parse_multi_assignment(self) -> MultiAssignmentStmt:
        # Elements are parsed above the relational level so the closing '>'
        # of the angle-bracket list is not mistaken for a comparison.
        additive = len(_PRECEDENCE) - 2  # ('+', '-') level
        t = self.expect("sym", "<")
        targets = [self._parse_binary(additive)]
        while self.accept("sym", ","):
            targets.append(self._parse_binary(additive))
        self.expect("sym", ">")
        self.expect("sym", "=")
        self.expect("sym", "<")
        values = [self._parse_binary(additive)]
        while self.accept("sym", ","):
            values.append(self._parse_binary(additive))
        self.expect("sym", ">")
        self.expect("sym", ";")
        return MultiAssignmentStmt(targets=targets, values=values, line=t.line)

    def parse_expr_statement(self) -> Statement:
        t = self.peek()
        lhs = self.parse_expression()
        if self.at("sym") and self.peek().value in REDUCE_ASSIGN:
            op = self.next().value
            rhs = self.parse_expression()
            self.expect("sym", ";")
            return AssignmentStmt(lhs=lhs, rhs=rhs,
                                  reduce_op=REDUCE_ASSIGN[op], line=t.line)
        if self.accept("sym", "++"):
            self.expect("sym", ";")
            return AssignmentStmt(lhs=lhs,
                                  rhs=Literal(value=1, kind="int", line=t.line),
                                  reduce_op="+", line=t.line)
        if self.accept("sym", "="):
            rhs = self.parse_expression()
            self.expect("sym", ";")
            return AssignmentStmt(lhs=lhs, rhs=rhs, line=t.line)
        self.expect("sym", ";")
        if isinstance(lhs, ProcCall):
            return ProcCallStmt(call=lhs, line=t.line)
        raise ParseError(f"line {t.line}: expression is not a statement")

    # --- expressions ----------------------------------------------------------
    def parse_expression(self) -> Expression:
        return self._parse_binary(0)

    def _parse_binary(self, level: int) -> Expression:
        if level >= len(_PRECEDENCE):
            return self._parse_unary()
        left = self._parse_binary(level + 1)
        ops = _PRECEDENCE[level]
        while self.at("sym") and self.peek().value in ops:
            # do not treat '>' of a multi-assign target list as an operator:
            # handled by caller context (parse_multi_assignment consumes '>').
            op = self.next().value
            right = self._parse_binary(level + 1)
            left = BinaryOp(op=op, left=left, right=right, line=left.line)
        return left

    def _parse_unary(self) -> Expression:
        t = self.peek()
        if self.accept("sym", "!"):
            return UnaryOp(op="!", operand=self._parse_unary(), line=t.line)
        if self.accept("sym", "-"):
            return UnaryOp(op="-", operand=self._parse_unary(), line=t.line)
        return self._parse_postfix()

    def _parse_postfix(self) -> Expression:
        expr = self._parse_primary()
        while True:
            if self.accept("sym", "."):
                name = self.next().value
                if self.at("sym", "("):
                    args, kwargs = self._parse_args()
                    expr = ProcCall(target=expr, name=name, args=args,
                                    kwargs=kwargs, line=expr.line)
                else:
                    expr = MemberAccess(target=expr, member=name, line=expr.line)
            elif self.at("sym", "(") and isinstance(expr, Identifier):
                args, kwargs = self._parse_args()
                expr = ProcCall(target=None, name=expr.name, args=args,
                                kwargs=kwargs, line=expr.line)
            else:
                return expr

    def _parse_args(self):
        self.expect("sym", "(")
        args, kwargs = [], []
        while not self.at("sym", ")"):
            # keyword arg: id '=' expr  (attachNodeProperty(dist = INF))
            if self.at("id") and self.at("sym", "=", off=1):
                key = self.next().value
                self.next()  # '='
                kwargs.append((key, self.parse_expression()))
            else:
                args.append(self.parse_expression())
            if not self.accept("sym", ","):
                break
        self.expect("sym", ")")
        return args, kwargs

    def _parse_primary(self) -> Expression:
        t = self.next()
        if t.kind == "int":
            return Literal(value=int(t.value), kind="int", line=t.line)
        if t.kind == "float":
            return Literal(value=float(t.value), kind="float", line=t.line)
        if t.kind == "kw":
            if t.value in ("True", "False"):
                return Literal(value=t.value == "True", kind="bool", line=t.line)
            if t.value == "INF":
                return Literal(value=None, kind="inf", line=t.line)
            if t.value in ("Min", "Max"):
                args, _ = self._parse_args()
                return MinMaxExpr(kind=t.value, args=args, line=t.line)
        if t.kind == "id":
            return Identifier(name=t.value, line=t.line)
        if t.kind == "sym" and t.value == "(":
            e = self.parse_expression()
            self.expect("sym", ")")
            return e
        raise ParseError(f"line {t.line}: unexpected token {t.value!r}")


def parse(src: str) -> Program:
    prog = Parser(src).parse_program()
    # plain attribute (not a dataclass field): `walk` never visits it, and
    # downstream passes can quote offending source lines in diagnostics
    prog.src_text = src
    return prog

