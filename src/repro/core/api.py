"""Public compiler API: StarPlat source → executable JAX program.

The algorithm/schedule split (GraphIt-style):

    sched = Schedule(batch_sources=16)               # the schedule
    prog  = compile_program(source, backend="pallas", schedule=sched)
    bound = prog.bind(g)                             # per-graph entry point
    out   = bound(src=0)                             # serve queries
    print(prog.source)                               # generated Python/JAX

`compile_program` is memoized on `(source digest, backend, schedule,
fn_name, jit)`: repeated calls return the SAME `CompiledProgram` without
re-parsing or re-exec'ing generated code — compile once per (program,
schedule), prepare each graph once (`repro.core.context.prepare`), then
serve. Per-graph derived structures (sliced-ELL views, distributed
partitions) live in the shared `GraphContext` registry, not in
backend-private caches.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import weakref
from typing import Callable, Optional

import jax

from ..graph.csr import resolve_schedule
from ..schedule import Schedule
from . import runtime as rt
from .analysis import (DiagnosticError, check_schedule, entry_error,
                       program_analysis, split)
from .context import get_context
from .lowering import lower
from .parser import parse

_BACKENDS = ("local", "pallas", "distributed")

_PROGRAM_DIR = os.path.join(os.path.dirname(__file__), "programs")

_PRELUDE = (
    "import jax\n"
    "import jax.numpy as jnp\n"
    "from repro.core import runtime as rt\n\n"
)


@dataclasses.dataclass(eq=False)
class CompiledProgram:
    name: str
    backend: str
    source: str          # generated Python/JAX source text
    fn: Callable         # compiled callable (jit according to backend)
    raw_fn: Callable     # un-jitted generated function
    ir: object
    schedule: Schedule   # the schedule baked into `source`
    dist_meta: Optional[dict] = None   # distributed backend: output specs
    dsl_source: str = ""  # the StarPlat source this was compiled from
    jit: bool = True      # jit flag the program was compiled under
    diagnostics: tuple = ()  # analysis findings that survived the gate
    # jitted `<name>__refresh` wrapper (same calling convention as `fn`,
    # plus _warm/_reset/_seed), or None when the program has no top-level
    # iterative construct to warm-start. Call through
    # `BoundProgram.refresh`, which derives the seeding from a GraphDelta.
    refresh_fn: Optional[Callable] = None

    def recompile(self, schedule: Schedule) -> "CompiledProgram":
        """The same algorithm under a different schedule — a compile-cache
        probe, so repeated requests (e.g. autotuning trials) for an
        already-built (source, backend, schedule) are free."""
        return compile_program(self.dsl_source, backend=self.backend,
                               fn_name=self.name, jit=self.jit,
                               schedule=schedule)

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)

    def bind(self, g, *, mesh=None) -> "BoundProgram":
        """Graph-bound callable — the uniform calling convention.

        `prog.bind(g)(**params)` works identically on every backend: the
        local/pallas backends resolve the graph's derived views through its
        `GraphContext` (warming them at bind time), and the distributed
        backend folds in the mesh / partition / `dist_meta` plumbing that
        previously had to go through `repro.core.dist.run` by hand
        (`mesh=None` → one shard per local device).

        Memoized per (program, graph) with weakref keying (the GraphContext
        registry idiom): repeated binds on a serving query path return the
        SAME `BoundProgram` as long as someone holds it, instead of
        re-warming views and (distributed) re-building the jitted runner.
        An explicit `mesh=` bypasses the cache (the mesh is caller state)."""
        if mesh is not None:
            return BoundProgram(self, g, mesh=mesh)
        key = (id(self), id(g))
        entry = _BIND_CACHE.get(key)
        if entry is not None:
            wp, wg, wb = entry
            bound = wb()
            if bound is not None and wp() is self and wg() is g:
                return bound
        bound = BoundProgram(self, g)

        def _evict(_r, _k=key):
            # only remove the entry this weakref belongs to: the key may
            # have been re-filled after an id() reuse
            cur = _BIND_CACHE.get(_k)
            if cur is not None and (cur[2]() is None or cur[0]() is None
                                    or cur[1]() is None):
                _BIND_CACHE.pop(_k, None)

        _BIND_CACHE[key] = (weakref.ref(self, _evict), weakref.ref(g, _evict),
                            weakref.ref(bound, _evict))
        return bound


class BoundProgram:
    """A `CompiledProgram` bound to one graph (`prog.bind(g)`).

    Holds the graph strongly (a bound program keeps its graph alive) and
    warms the per-graph structures once at construction, so every
    subsequent call is pure execution. For the distributed backend the
    shard_map-wrapped jitted runner is also built once per parameter
    signature and cached here."""

    def __init__(self, program: CompiledProgram, graph, *, mesh=None):
        self.program = program
        self.graph = graph
        ctx = get_context(graph)
        if program.backend == "distributed":
            from . import dist, runtime_dist as rtd
            self.mesh = mesh if mesh is not None else dist.make_mesh_1d()
            meta = program.dist_meta or {}
            self._gd = ctx.dist_arrays(self.mesh.shape[rtd.AXIS],
                                       ell=meta.get("needs_ell", False))
        else:
            if mesh is not None:
                raise ValueError(
                    "mesh= applies to the distributed backend only (this "
                    f"program's backend is {program.backend!r})")
            self.mesh = None
            if program.backend == "pallas":
                ctx.sliced_ell(program.schedule, reverse=True)
            elif program.backend == "local" and ", _dell" in program.source:
                ctx.delta_ell()   # warm the delta-stepping compact-relax view

    def __call__(self, **params):
        prog = self.program
        if prog.backend != "distributed":
            return prog.fn(self.graph, **params)
        from . import dist
        return dist.run_prepared(prog, self._gd, self.mesh,
                                 num_nodes=self.graph.num_nodes, **params)

    def refresh(self, prev: dict, delta, /, **params):
        # prev/delta are positional-only: program params are free to reuse
        # the names (PR's damping factor is literally called `delta`)
        """Incremental recompute after `g.update()`: the previous result
        warm-starts the program's iterative construct instead of running it
        from the cold init.

        `prev` is a prior result dict of the SAME program (on the
        pre-update graph), `delta` the `GraphDelta` whose `.graph` this
        program is bound to. The delta's `plan()` supplies the seeding:
        previous per-node values are kept except in the deletion cone
        (reset to cold init), and the first sweep's frontier is the
        update-incident seed set. When the affected fraction of N exceeds
        `Schedule.refresh_threshold_frac`, the warm start would touch most
        of the graph anyway, so this falls back to a dense from-scratch
        run — either path returns the same converged result dict a plain
        call would."""
        prog = self.program
        if prog.backend == "distributed":
            raise ValueError(
                "refresh is a local/pallas entry point; recompute "
                "distributed programs from scratch after an update")
        if prog.refresh_fn is None:
            raise ValueError(
                f"{prog.name!r} has no incremental refresh: the program "
                "has no top-level iterative construct (fixedPoint / while "
                "/ do-while) to warm-start")
        fx = program_analysis(prog.dsl_source).functions.get(prog.name)
        if fx is not None and fx.refresh_unsafe:
            from .analysis import diag
            raise DiagnosticError(
                [diag("SP209", fx.refresh_unsafe_reason, fn=prog.name,
                      line=fx.refresh_unsafe_line, src=prog.dsl_source)],
                header=f"refresh rejected for {prog.name!r}")
        if delta.graph is not self.graph:
            raise ValueError(
                "refresh must run on the post-update graph: bind the "
                "program to delta.graph and pass the matching delta")
        plan = delta.plan()
        if plan.affected_frac > prog.schedule.refresh_threshold_frac:
            return self(**params)
        n = self.graph.num_nodes
        warm = {k: v for k, v in prev.items()
                if getattr(v, "shape", None) == (n,)}
        import jax.numpy as jnp
        return prog.refresh_fn(self.graph, _warm=warm,
                               _reset=jnp.asarray(plan.reset),
                               _seed=jnp.asarray(plan.seed), **params)

    def __repr__(self):
        g = self.graph
        return (f"BoundProgram({self.program.name!r}, "
                f"backend={self.program.backend!r}, N={g.num_nodes}, "
                f"E={g.num_edges})")


def _exec_generated(src: str, fn_name: str, extra_env: Optional[dict] = None):
    """Exec the generated module source; returns its namespace (the main
    function plus, when emitted, the `<name>__refresh` incremental
    variant)."""
    import jax.numpy as jnp
    env = {"jax": jax, "jnp": jnp, "rt": rt}
    if extra_env:
        env.update(extra_env)
    code = compile(src, f"<starplat:{fn_name}>", "exec")
    exec(code, env)
    return env


# compile cache: (source digest, backend, schedule, fn_name, jit) -> program
_COMPILE_CACHE: dict = {}

# bind cache: (id(program), id(graph)) -> (wr(program), wr(graph), wr(bound)).
# Everything is held WEAKLY: a BoundProgram keeps its graph alive, so the
# cache must not keep the bound program alive (that would pin every graph
# ever bound); when the caller drops the bound runner — or either key dies —
# the entry evicts itself and the next bind rebuilds.
_BIND_CACHE: dict = {}


def compile_cache_clear() -> None:
    _COMPILE_CACHE.clear()


def compile_cache_size() -> int:
    return len(_COMPILE_CACHE)


def bind_cache_clear() -> None:
    _BIND_CACHE.clear()


def bind_cache_size() -> int:
    return len(_BIND_CACHE)


def compile_program(source: str, backend: str = "local",
                    fn_name: Optional[str] = None, jit: bool = True,
                    schedule: Optional[Schedule] = None,
                    batch_sources: Optional[int] = None,
                    strict: bool = False,
                    **backend_opts) -> CompiledProgram:
    """Compile a StarPlat program under an explicit `Schedule`.

    `schedule=None` snapshots the deprecated `ENGINE` shim (the default
    `Schedule` unless someone mutated it); `batch_sources=` is the legacy
    per-compile override, folded into the schedule. Every engine knob is
    baked into the generated source as a literal, so the same schedule
    yields byte-identical source and mutating `ENGINE` afterwards never
    changes an already-compiled program. Results are memoized — repeated
    identical calls return the same `CompiledProgram` object (unknown
    `backend_opts` bypass the cache).

    Every compile — cache hits included — passes the static analysis gate
    (`repro.core.analysis`): effect-analysis errors (races, non-terminating
    fixed points) and illegal schedule combinations raise
    `DiagnosticError` with stable SPxxx codes; `strict=True` promotes
    warnings to errors.  Surviving warnings ride on the returned program's
    `.diagnostics`."""
    if backend not in _BACKENDS:
        raise entry_error(
            "SP301",
            f"unknown backend {backend!r}; backends: {', '.join(_BACKENDS)}")
    sched = resolve_schedule(schedule, batch_sources=batch_sources)

    # --- static analysis gate (runs before the cache: rejection must not
    # depend on whether an earlier permissive call already compiled) -------
    analysis = program_analysis(source)
    if fn_name is not None and fn_name not in analysis.functions:
        defined = ", ".join(analysis.functions) or "<none>"
        raise entry_error(
            "SP302",
            f"program defines no function named {fn_name!r}; it "
            f"defines: {defined}")
    gate_name = fn_name if fn_name is not None \
        else next(iter(analysis.functions))
    fx = analysis.functions[gate_name]
    diags = tuple(fx.diagnostics) + tuple(check_schedule(fx, sched, backend))
    errors, warnings = split(diags)
    if errors or (strict and warnings):
        raise DiagnosticError(
            diags, header=(f"analysis rejected {gate_name!r} "
                           f"(backend={backend!r})"))

    cache_key = None
    if not backend_opts:
        digest = hashlib.sha256(source.encode()).hexdigest()
        cache_key = (digest, backend, sched, fn_name, jit)
        cached = _COMPILE_CACHE.get(cache_key)
        if cached is not None:
            return cached

    prog_ast = parse(source)
    irfns = lower(prog_ast)
    if fn_name is None:
        irfn = irfns[0]
    else:
        irfn = [f for f in irfns if f.name == fn_name][0]

    if backend == "local":
        from .codegen.local_jax import generate_local
        body = generate_local(irfn, schedule=sched, **backend_opts)
        extra_env = None
    elif backend == "distributed":
        from .codegen.distributed import generate_distributed
        body, extra_env = generate_distributed(irfn, schedule=sched,
                                               **backend_opts)
    else:
        from .codegen.pallas_backend import generate_pallas
        body, extra_env = generate_pallas(irfn, schedule=sched,
                                          **backend_opts)

    src = _PRELUDE + body
    env = _exec_generated(src, irfn.name, extra_env)
    raw = env[irfn.name]
    raw_refresh = env.get(f"{irfn.name}__refresh")

    # CSRGraph is a registered pytree with static num_nodes/num_edges metadata,
    # so the graph argument is dynamic (arrays) + static (sizes) automatically.
    def _wrap(raw_fn):
        if backend == "pallas":
            jitted = jax.jit(raw_fn) if jit else raw_fn

            def fn(g, *, _jitted=jitted, _sched=sched, **kw):
                # degree-bucketed reverse (in-edge) view, owned by the
                # graph's shared GraphContext — built once per (graph,
                # layout), shared with every other program compiled under
                # the same layout.
                ell = get_context(g).sliced_ell(_sched, reverse=True)
                return _jitted(g, ell, **kw)
            return fn
        if backend == "local" and \
                f"def {irfn.name}({irfn.graph_param}, _dell" in body:
            # delta-stepping program: the generated functions take the
            # padded forward-ELL view the compact bucket relax gathers
            # frontier out-rows from (None on hub-heavy graphs → dense
            # fallback)
            jitted = jax.jit(raw_fn) if jit else raw_fn

            def fn(g, *, _jitted=jitted, **kw):
                return _jitted(g, get_context(g).delta_ell(), **kw)
            return fn
        return jax.jit(raw_fn) if jit and backend == "local" else raw_fn

    fn = _wrap(raw)
    refresh_fn = _wrap(raw_refresh) if raw_refresh is not None else None
    prog = CompiledProgram(
        name=irfn.name, backend=backend, source=src, fn=fn, raw_fn=raw,
        ir=irfn, schedule=sched,
        dist_meta=(extra_env or {}).get("__dist_meta__"),
        dsl_source=source, jit=jit, diagnostics=diags,
        refresh_fn=refresh_fn)
    if cache_key is not None:
        _COMPILE_CACHE[cache_key] = prog
        if fn_name is None:
            # also file under the resolved name, so an explicit request for
            # the same function (e.g. CompiledProgram.recompile) is a hit
            # on the same object rather than a duplicate compile
            _COMPILE_CACHE[(digest, backend, sched, irfn.name, jit)] = prog
    return prog


def bundled_programs() -> list:
    """Names of the bundled paper programs (`.sp` sources)."""
    return sorted(p[:-3] for p in os.listdir(_PROGRAM_DIR)
                  if p.endswith(".sp"))


def load_program_source(name: str) -> str:
    """Source text of a bundled paper program (sssp, sssp_pull, pr, tc, bc,
    cc); raises `ValueError` naming the bundled programs otherwise."""
    path = os.path.join(_PROGRAM_DIR, f"{name}.sp")
    if not os.path.exists(path):
        raise entry_error(
            "SP303",
            f"no bundled program named {name!r}; bundled programs: "
            f"{', '.join(bundled_programs())}")
    with open(path) as f:
        return f.read()


def compile_bundled(name: str, backend: str = "local", **kw) -> CompiledProgram:
    return compile_program(load_program_source(name), backend=backend, **kw)
