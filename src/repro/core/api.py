"""Public compiler API: StarPlat source → executable JAX program.

    prog = compile_program(source, backend="local")
    out  = prog(g, src=0)           # jitted
    print(prog.source)              # generated Python/JAX text
"""
from __future__ import annotations

import os
import weakref
from dataclasses import dataclass
from typing import Callable, Optional

import jax

from . import runtime as rt
from .lowering import lower
from .parser import parse

_PROGRAM_DIR = os.path.join(os.path.dirname(__file__), "programs")

_PRELUDE = (
    "import jax\n"
    "import jax.numpy as jnp\n"
    "from repro.core import runtime as rt\n\n"
)


@dataclass
class CompiledProgram:
    name: str
    backend: str
    source: str          # generated Python/JAX source text
    fn: Callable         # compiled callable (jit according to backend)
    raw_fn: Callable     # un-jitted generated function
    ir: object

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)


def _exec_generated(src: str, fn_name: str, extra_env: Optional[dict] = None):
    import jax.numpy as jnp
    env = {"jax": jax, "jnp": jnp, "rt": rt}
    if extra_env:
        env.update(extra_env)
    code = compile(src, f"<starplat:{fn_name}>", "exec")
    exec(code, env)
    return env[fn_name]


def compile_program(source: str, backend: str = "local", fn_name: Optional[str] = None,
                    jit: bool = True, **backend_opts) -> CompiledProgram:
    prog = parse(source)
    irfns = lower(prog)
    if fn_name is None:
        irfn = irfns[0]
    else:
        irfn = next(f for f in irfns if f.name == fn_name)

    if backend == "local":
        from .codegen.local_jax import generate_local
        body = generate_local(irfn, **backend_opts)
        extra_env = None
    elif backend == "distributed":
        from .codegen.distributed import generate_distributed
        body, extra_env = generate_distributed(irfn, **backend_opts)
    elif backend == "pallas":
        from .codegen.pallas_backend import generate_pallas
        body, extra_env = generate_pallas(irfn, **backend_opts)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    src = _PRELUDE + body
    raw = _exec_generated(src, irfn.name, extra_env)
    # CSRGraph is a registered pytree with static num_nodes/num_edges metadata,
    # so the graph argument is dynamic (arrays) + static (sizes) automatically.
    if backend == "pallas":
        from ..kernels.ell_spmv.ops import prepare_sliced_ell
        jitted = jax.jit(raw) if jit else raw
        # Per-graph ELL cache. Entries hold a WEAK reference to the graph:
        # `id(g)` alone is unsafe (ids are reused after GC, so a dead graph
        # could alias a new one's sliced view) and keeping `g` strongly would
        # leak every graph ever run. The weakref callback evicts the entry
        # the moment the graph is collected, so the dict cannot grow
        # unboundedly, and the `ref() is g` check guards against id reuse in
        # the window before the callback fires.
        _ell_cache = {}

        def fn(g, **kw):
            key = id(g)
            entry = _ell_cache.get(key)
            if entry is None or entry[0]() is not g:
                # degree-bucketed reverse (in-edge) view, built once per graph
                ref = weakref.ref(g, lambda _r, _k=key: _ell_cache.pop(_k, None))
                _ell_cache[key] = entry = (ref, prepare_sliced_ell(g, reverse=True))
            _, ell = entry
            return jitted(g, ell, **kw)

        fn._ell_cache = _ell_cache   # introspection hook (tests)
    else:
        fn = jax.jit(raw) if jit and backend == "local" else raw
    prog = CompiledProgram(name=irfn.name, backend=backend, source=src,
                           fn=fn, raw_fn=raw, ir=irfn)
    if extra_env and "__dist_meta__" in extra_env:
        prog.dist_meta = extra_env["__dist_meta__"]
    return prog


def load_program_source(name: str) -> str:
    """Bundled paper programs: sssp, sssp_pull, pr, tc, bc."""
    with open(os.path.join(_PROGRAM_DIR, f"{name}.sp")) as f:
        return f.read()


def compile_bundled(name: str, backend: str = "local", **kw) -> CompiledProgram:
    return compile_program(load_program_source(name), backend=backend, **kw)
