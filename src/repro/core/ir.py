"""Backend-independent intermediate representation.

"Central to our compiler is an intermediate representation which allows a
common representation of the high-level program, from which individual
backend code generations begin" (paper abstract). This IR normalizes the
AST: identifier roles are resolved, reductions are explicit (`x = x + t`
becomes a reduce-assign), the Min/Max multiple-assignment is a single
synchronized-update node, and every loop carries its iteration space
(vertices / out-neighbors / in-neighbors / source set / BFS levels).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple


# --------------------------------------------------------------------------
# Expressions
# --------------------------------------------------------------------------

@dataclass
class IRExpr:
    pass


@dataclass
class IConst(IRExpr):
    value: object
    kind: str = "int"        # int|float|bool|inf


@dataclass
class IScalar(IRExpr):
    """Function-scope scalar variable (loop-carried in generated code)."""
    name: str
    dtype: str = "float32"


@dataclass
class IVertexLocal(IRExpr):
    """Scalar declared inside a vertex loop — one value per vertex."""
    name: str
    dtype: str = "float32"


@dataclass
class IProp(IRExpr):
    """Property read. `target` is an iterator / node-param name, or None for
    the whole array (e.g. the fixedPoint convergence expression)."""
    prop: str
    target: Optional[str]
    dtype: str = "float32"


@dataclass
class IIterId(IRExpr):
    """The integer id of an iterator (for filters like `u < v`)."""
    name: str


@dataclass
class INodeParam(IRExpr):
    name: str


@dataclass
class IEdgeWeight(IRExpr):
    """e.weight where `edge e = g.getEdge(v, nbr)` binds e to the current edge."""
    edge_var: str


@dataclass
class IBin(IRExpr):
    op: str
    left: IRExpr = None
    right: IRExpr = None


@dataclass
class IUn(IRExpr):
    op: str
    operand: IRExpr = None


@dataclass
class ICall(IRExpr):
    fn: str                      # num_nodes | count_out_nbrs | count_in_nbrs | is_an_edge | min_wt | max_wt
    args: List[IRExpr] = field(default_factory=list)


# --------------------------------------------------------------------------
# Statements
# --------------------------------------------------------------------------

@dataclass
class IRStmt:
    pass


@dataclass
class IAttach(IRStmt):
    """attachNodeProperty / attachEdgeProperty: [(prop, dtype, init|None)]."""
    props: List[Tuple[str, str, Optional[IRExpr]]]
    kind: str = "node"


@dataclass
class IDeclScalar(IRStmt):
    name: str
    dtype: str
    init: Optional[IRExpr] = None
    vertex_local: bool = False


@dataclass
class IAssign(IRStmt):
    """Scalar assignment; reduce_op != None is a paper Table-1 reduction."""
    name: str
    expr: IRExpr
    reduce_op: Optional[str] = None
    vertex_local: bool = False


@dataclass
class IWriteProp(IRStmt):
    """Single-node property write at host level: src.dist = 0."""
    prop: str
    node: IRExpr            # INodeParam or IIterId (set iterator)
    expr: IRExpr = None


@dataclass
class IAssignProp(IRStmt):
    """In-loop property write: v.pageRank_nxt = val / w.sigma += v.sigma."""
    prop: str
    target: str             # iterator name
    expr: IRExpr = None
    reduce_op: Optional[str] = None


@dataclass
class IMinMaxUpdate(IRStmt):
    """<t.p, extras...> = <Min(t.p, cand), vals...> — synchronized update."""
    prop: str
    target: str             # iterator the update lands on
    cand: IRExpr = None
    kind: str = "Min"
    extras: List[Tuple[str, str, IRExpr]] = field(default_factory=list)


@dataclass
class IVertexLoop(IRStmt):
    it: str
    filter: Optional[IRExpr] = None
    body: List[IRStmt] = field(default_factory=list)
    parallel: bool = True


@dataclass
class INbrLoop(IRStmt):
    it: str
    source: str             # the vertex iterator this neighborhood belongs to
    direction: str = "out"  # out (neighbors/nodesFrom) | in (nodesTo)
    filter: Optional[IRExpr] = None
    body: List[IRStmt] = field(default_factory=list)
    parallel: bool = True


@dataclass
class IFixedPoint(IRStmt):
    var: str
    conv_prop: str          # fixedPoint until (var : !conv_prop)
    body: List[IRStmt] = field(default_factory=list)


@dataclass
class IDoWhile(IRStmt):
    cond: IRExpr = None
    body: List[IRStmt] = field(default_factory=list)


@dataclass
class IWhile(IRStmt):
    cond: IRExpr = None
    body: List[IRStmt] = field(default_factory=list)


@dataclass
class IIf(IRStmt):
    cond: IRExpr = None
    then: List[IRStmt] = field(default_factory=list)
    els: List[IRStmt] = field(default_factory=list)


@dataclass
class IBFS(IRStmt):
    it: str
    root: IRExpr = None
    body: List[IRStmt] = field(default_factory=list)
    rev_filter: Optional[IRExpr] = None
    rev_body: Optional[List[IRStmt]] = None


@dataclass
class ISetLoop(IRStmt):
    it: str
    set_name: str
    body: List[IRStmt] = field(default_factory=list)


@dataclass
class ICopyProp(IRStmt):
    dst: str
    src: str


@dataclass
class IReturn(IRStmt):
    expr: Optional[IRExpr] = None


# --------------------------------------------------------------------------
# Function container
# --------------------------------------------------------------------------

@dataclass
class IRParam:
    name: str
    kind: str               # graph|node|scalar|prop_node|prop_edge|set_n|set_e
    dtype: Optional[str] = None


@dataclass
class IRFunction:
    name: str
    params: List[IRParam]
    body: List[IRStmt]
    node_props: dict        # name -> dtype (all propNode declared/param)
    edge_props: dict
    scalars: dict           # function-scope scalar name -> dtype
    graph_param: str = "g"


def walk_stmts(stmts, fn):
    for s in stmts:
        fn(s)
        for attr in ("body", "then", "els", "rev_body"):
            sub = getattr(s, attr, None)
            if sub:
                walk_stmts(sub, fn)


def written_vars(stmts) -> set:
    """Names of scalars/properties mutated anywhere in `stmts` — used by the
    backends to build loop carries (and, in the distributed backend, to decide
    what must be communicated; in the Pallas backend, kernel outputs)."""
    out = set()

    def visit(s):
        if isinstance(s, IAssign):
            out.add(s.name)
        elif isinstance(s, (IAssignProp, IMinMaxUpdate)):
            out.add(s.prop)
            if isinstance(s, IMinMaxUpdate):
                out.update(p for p, _, _ in s.extras)
        elif isinstance(s, IWriteProp):
            out.add(s.prop)
        elif isinstance(s, ICopyProp):
            out.add(s.dst)
        elif isinstance(s, IFixedPoint):
            out.add(s.var)
        elif isinstance(s, IAttach):
            out.update(p for p, _, _ in s.props)

    walk_stmts(stmts, visit)
    return out


def read_props(stmts) -> set:
    """Property names read anywhere (the distributed backend all-gathers these;
    the paper's CUDA backend H2D-transfers them)."""
    out = set()

    def expr_visit(e):
        if isinstance(e, IProp):
            out.add(e.prop)
        for attr in ("left", "right", "operand", "cand", "expr", "cond", "root", "node", "filter", "rev_filter", "init"):
            sub = getattr(e, attr, None)
            if isinstance(sub, IRExpr):
                expr_visit(sub)
        for a in getattr(e, "args", []) or []:
            expr_visit(a)

    def visit(s):
        for attr in ("expr", "cand", "cond", "filter", "root", "node", "init", "rev_filter"):
            sub = getattr(s, attr, None)
            if isinstance(sub, IRExpr):
                expr_visit(sub)
        if isinstance(s, IMinMaxUpdate):
            for _, _, v in s.extras:
                expr_visit(v)
        if isinstance(s, IAttach):
            for _, _, init in s.props:
                if init is not None:
                    expr_visit(init)

    walk_stmts(stmts, visit)
    return out
