"""Driver for the distributed backend: wraps a generated per-device body in
`jax.shard_map` over the mesh 'data' axis and runs it on a partitioned graph.

    prog = compile_bundled("sssp", backend="distributed")
    out  = dist.run(prog, g, mesh, src=0)     # same result dict as local
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..graph.csr import CSRGraph
from . import runtime_dist as rtd


def make_mesh_1d(num_devices: int | None = None):
    devs = jax.devices()
    n = num_devices or len(devs)
    return jax.make_mesh((n,), (rtd.AXIS,), devices=devs[:n])


def prepare(g: CSRGraph, mesh, *, ell: bool = False) -> dict:
    """Partitioned device arrays for `g`, memoized in the graph's shared
    `GraphContext` — repeated runs against one graph partition it once."""
    from .context import get_context
    return get_context(g).dist_arrays(mesh.shape[rtd.AXIS], ell=ell)


def run(prog, g: CSRGraph, mesh, **params):
    """Partition `g`, shard_map the generated body, return global results
    (property arrays trimmed to the true vertex count).

    Equivalent to `prog.bind(g, mesh=mesh)(**params)` — prefer `bind` for
    repeated queries against one graph."""
    meta = getattr(prog, "dist_meta", None) or {}
    gd = prepare(g, mesh, ell=meta.get("needs_ell", False))
    return run_prepared(prog, gd, mesh, num_nodes=g.num_nodes, **params)


def run_pod_parallel(prog, g: CSRGraph, mesh, source_set, **params):
    """Source-parallel execution over the 'pod' axis (multi-pod BC/SSSP).

    mesh must have axes ('pod', 'data'). The graph is replicated across
    pods; the source set is sharded over 'pod'; each pod runs the 1-D
    distributed program over its 'data' axis for its source subset; the
    centrality contributions are psum'd across pods at the end. Inter-pod
    traffic = one psum of the output — the DCI-friendly schedule."""
    meta = getattr(prog, "dist_meta", None) or {}
    gd = prepare(g, mesh, ell=meta.get("needs_ell", False))
    in_specs = rtd.partition_specs(gd, mesh)          # 'data' only → pod-replicated
    npods = mesh.shape["pod"]
    srcs = np.asarray(source_set, np.int32)
    pad = (-len(srcs)) % npods
    if pad:   # pad with repeats of source 0 and subtract its extra runs
        raise ValueError("source set must divide the pod count for now")
    body = prog.raw_fn
    set_param = next(p.name for p in prog.ir.params if p.kind == "set_n")
    names = [n for n, v in params.items() if v is not None and n != set_param]
    other = tuple(params[n] for n in names)

    def pod_body(gd_, srcs_, *vs):
        kw = dict(zip(names, vs))
        kw[set_param] = srcs_
        out = body(gd_, **kw)
        # sum per-pod contributions of every output property; the
        # communication counter also diverges per pod (each pod ran its
        # own source subset), so the reported volume is the pod total
        summed = set(meta.get("out_props", ())) | {"_gather_elems"}
        return {k: (jax.lax.psum(v, "pod") if k in summed else v)
                for k, v in out.items()}

    out_specs = {v: P(rtd.AXIS) for v in meta.get("out_props", [])}
    out_specs.update({v: P() for v in meta.get("out_scalars", [])})
    fn = jax.jit(rtd.shard_map(
        pod_body, mesh=mesh,
        in_specs=(in_specs, P("pod")) + tuple(P() for _ in other),
        out_specs=out_specs))
    out = fn(gd, jnp.asarray(srcs), *other)
    return {k: (v[: g.num_nodes] if k in meta.get("out_props", ()) else v)
            for k, v in out.items()}


def run_prepared(prog, gd: dict, mesh, *, num_nodes: int | None = None, **params):
    meta = getattr(prog, "dist_meta", None) or {}
    names = tuple(n for n, v in params.items() if v is not None)
    vals = tuple(params[n] for n in names)
    fn = _runner(prog, gd, mesh, names, meta)
    out = fn(gd, *vals)
    if num_nodes is not None:
        out = {k: (v[:num_nodes] if k in meta.get("out_props", ()) else v)
               for k, v in out.items()}
    return out


def _runner(prog, gd: dict, mesh, names: tuple, meta: dict):
    """The jitted shard_map wrapper for one (program, mesh, param-signature).

    Built once and cached on the program: `jax.jit` keys its own cache on
    function identity, so constructing a fresh lambda per call (the old
    behavior) re-traced and re-compiled on EVERY query — fatal for a query
    server. `ell_cols` presence is in the key because it changes `gd`'s
    pytree structure."""
    cache = getattr(prog, "_dist_runner_cache", None)
    if cache is None:
        cache = {}
        try:
            prog._dist_runner_cache = cache
        except AttributeError:   # e.g. a frozen/slots stand-in program
            pass
    key = (mesh, names, "ell_cols" in gd)
    fn = cache.get(key)
    if fn is None:
        in_specs = rtd.partition_specs(gd, mesh)
        out_specs = {v: P(rtd.AXIS) for v in meta.get("out_props", [])}
        out_specs.update({v: P() for v in meta.get("out_scalars", [])})
        body = prog.raw_fn
        fn = cache[key] = jax.jit(rtd.shard_map(
            lambda gd_, *vs: body(gd_, **dict(zip(names, vs))),
            mesh=mesh,
            in_specs=(in_specs,) + tuple(P() for _ in names),
            out_specs=out_specs,
        ))
    return fn
