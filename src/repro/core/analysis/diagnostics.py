"""Diagnostics: stable ``SPxxx`` codes, severities, and a uniform error shape.

Every message the compile-time analysis layer can produce is registered here
with a stable code and a default severity.  A :class:`Diagnostic` is a frozen
value object carrying the code, the resolved severity, a human-readable
message, and (when known) the source position *plus the offending source
line itself* — tools should never have to re-open the ``.sp`` file to show
context.

``DiagnosticError`` is the one exception type the gate raises.  It subclasses
``ValueError`` on purpose: every pre-existing caller of ``compile_program`` /
``load_program_source`` that catches ``ValueError`` (the serving layer's
warm-schedule reload, the autotuner) keeps working, while new callers can
catch ``DiagnosticError`` and read ``.diagnostics`` for the structured list.

The registry below is lint-checked against ``docs/analysis.md`` by
``tests/test_docs.py`` — add a code here and the docs test fails until the
table documents it.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

ERROR = "error"
WARNING = "warning"
SEVERITIES = (ERROR, WARNING)

#: code -> (default severity, one-line description).  Codes are grouped:
#:   SP1xx  effect analysis (races, parallel-write legality)
#:   SP15x  fixed-point / monotonicity analysis
#:   SP2xx  schedule legality (knob × program-structure combinations)
#:   SP3xx  compile-entry errors (unknown backend / function / program)
REGISTRY: Dict[str, Tuple[str, str]] = {
    "SP101": (ERROR,
              "cross-vertex plain property write under a parallel forall "
              "(write-write race); use a Min/Max/reduction update"),
    "SP102": (WARNING,
              "plain scalar assignment inside a parallel loop "
              "(last-writer-wins; use a reduction form such as `x = x + t`)"),
    "SP151": (ERROR,
              "fixedPoint convergence property is never written inside the "
              "loop body (the loop cannot terminate)"),
    "SP153": (WARNING,
              "fixedPoint property is updated non-monotonically (mixed "
              "Min/Max kinds or plain overwrites of a Min/Max-updated "
              "property); convergence is not provable"),
    "SP201": (ERROR,
              "priority=\"delta\" requires a monotone int-valued Min-relax "
              "fixedPoint; this program has none"),
    "SP202": (WARNING,
              "priority=\"delta\" on an unweighted Min relax: every "
              "relaxation lands in the current bucket, so delta-stepping "
              "degenerates to plain sweeps"),
    "SP203": (WARNING,
              "dist_frontier=\"compact\"/\"auto\" needs an iterative "
              "construct (fixedPoint / BFS / while) to carry frontier "
              "views across; this program has none"),
    "SP204": (WARNING,
              "batch_sources set explicitly but the program has no "
              "source-set forall to batch over"),
    "SP205": (WARNING,
              "direction pinned to push/pull but the program has no "
              "direction-switchable neighbor relax or BFS"),
    "SP206": (WARNING,
              "dist_gather_frac >= 0.5 makes the compact exchange "
              "statically degrade to dense (cap never beats the full row)"),
    "SP207": (WARNING,
              "delta_bucket set to a non-default value while "
              "priority=\"none\"; the knob has no effect"),
    "SP208": (WARNING,
              "refresh_threshold_frac set to a non-default value but the "
              "program has no iterative construct to warm-start"),
    "SP209": (ERROR,
              "incremental refresh on a self-gated peeling loop (a while "
              "body plain-writes a property its own visitation filter "
              "reads); the converged state cannot be warm-started soundly "
              "— recompute from scratch"),
    "SP301": (ERROR, "unknown backend"),
    "SP302": (ERROR, "program defines no function with the requested name"),
    "SP303": (ERROR, "no bundled program with the requested name"),
}


def severity_of(code: str) -> str:
    return REGISTRY[code][0]


@dataclass(frozen=True)
class Diagnostic:
    """One analysis finding.  ``line`` is 1-based; 0 means "no position"."""
    code: str
    message: str
    severity: str = ""
    line: int = 0
    source_line: str = ""
    fn: str = ""

    def __post_init__(self):
        if not self.severity:
            object.__setattr__(self, "severity", severity_of(self.code))
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}")
        if self.code not in REGISTRY:
            raise ValueError(f"unregistered diagnostic code {self.code!r}")

    def format(self) -> str:
        where = f"line {self.line}: " if self.line else ""
        fn = f"[{self.fn}] " if self.fn else ""
        out = f"{self.code} {self.severity}: {fn}{where}{self.message}"
        if self.source_line:
            out += f"\n    | {self.source_line.strip()}"
        return out

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Diagnostic":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def diag(code: str, message: str, *, line: int = 0, fn: str = "",
         src: Optional[str] = None, severity: str = "") -> Diagnostic:
    """Build a Diagnostic, quoting the offending source line from ``src``."""
    return Diagnostic(code=code, message=message, severity=severity,
                      line=line, source_line=quote_line(src, line), fn=fn)


def quote_line(src: Optional[str], line: int) -> str:
    """The 1-based ``line`` of ``src``, or "" when unavailable."""
    if not src or line <= 0:
        return ""
    lines = src.splitlines()
    if line > len(lines):
        return ""
    return lines[line - 1]


class DiagnosticError(ValueError):
    """Raised by the compile gate.  ``.diagnostics`` holds every finding of
    the failing run (errors first); ``str()`` formats them all."""

    def __init__(self, diagnostics: Sequence[Diagnostic], *,
                 header: str = "analysis failed"):
        self.diagnostics: Tuple[Diagnostic, ...] = tuple(sorted(
            diagnostics, key=lambda d: (d.severity != ERROR, d.line, d.code)))
        body = "\n".join(d.format() for d in self.diagnostics)
        super().__init__(f"{header}:\n{body}" if body else header)

    @property
    def codes(self) -> List[str]:
        return [d.code for d in self.diagnostics]


def entry_error(code: str, message: str) -> DiagnosticError:
    """A single-diagnostic DiagnosticError for SP3xx compile-entry failures.

    The header is the bare message so pre-existing ``pytest.raises(ValueError,
    match=...)`` call sites keep matching on the interesting names."""
    d = Diagnostic(code=code, message=message)
    err = DiagnosticError([d], header=f"{code}: {message}")
    return err


def split(diags: Sequence[Diagnostic]):
    """-> (errors, warnings), each in input order."""
    errs = [d for d in diags if d.severity == ERROR]
    warns = [d for d in diags if d.severity == WARNING]
    return errs, warns
