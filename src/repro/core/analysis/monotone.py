"""Monotonicity analysis for ``fixedPoint`` loops.

A fixed-point iteration converges when its value lattice is bounded and
every update moves one direction — the classic chaotic-iteration argument.
Concretely we prove, per property updated inside the loop body:

* it is only ever updated through ``Min`` (values only decrease) or only
  ever through ``Max`` (values only increase), and
* no plain assignment or ``+=``-style reduction to the same property can
  push it back the other way.

That proof is the legality precondition for every schedule feature that
reorders work inside the loop: delta-stepping priority buckets, push/pull
direction flips, and the priority-sliced distributed exchange all assume
re-relaxing a vertex later can only tighten its value, never corrupt it.

Two diagnostics originate here:

* **SP151** (error): the convergence property (the ``!modified``-style bool
  the loop tests) is never written in the body — the loop cannot terminate.
* **SP153** (warning): a Min/Max-updated property is also written through a
  conflicting kind or a plain overwrite — convergence is not provable.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from .. import ast_nodes as A
from ..semantic import FunctionInfo
from .diagnostics import Diagnostic, diag
from .effects import FixedPointInfo, FixedPointTarget, Region


def conv_prop_of(conv_expr) -> Optional[str]:
    """The convergence property named by a fixedPoint header, mirroring the
    two shapes ``lowering.fixed_point`` accepts: ``!prop`` and
    ``prop == False``."""
    if (isinstance(conv_expr, A.UnaryOp) and conv_expr.op == "!"
            and isinstance(conv_expr.operand, A.Identifier)):
        return conv_expr.operand.name
    if (isinstance(conv_expr, A.BinaryOp) and conv_expr.op == "=="
            and isinstance(conv_expr.left, A.Identifier)
            and isinstance(conv_expr.right, A.Literal)
            and conv_expr.right.value is False):
        return conv_expr.left.name
    return None


def analyze_fixedpoint(
        fp: A.FixedPointStmt, region: Region, info: FunctionInfo,
        src: Optional[str], fn_name: str,
) -> Tuple[FixedPointInfo, List[Diagnostic]]:
    """Classify one fixedPoint loop given its effect region."""
    diags: List[Diagnostic] = []
    conv = conv_prop_of(fp.conv_expr)
    conv_written = False
    if conv is not None:
        pa = region.props.get(conv)
        conv_written = pa is not None and pa.written
        if not conv_written:
            diags.append(diag(
                "SP151",
                f"fixedPoint convergence property {conv!r} is never written "
                f"inside the loop body; the loop can never terminate",
                line=fp.line, fn=fn_name, src=src))

    targets: List[FixedPointTarget] = []
    for prop in sorted(region.props):
        pa = region.props[prop]
        if not pa.minmax:
            continue
        mixed = len(pa.minmax) > 1
        dirty = pa.plain_writes > 0 or bool(pa.reductions)
        monotone = not mixed and not dirty
        kind = "mixed" if mixed else next(iter(pa.minmax))
        if not monotone:
            if mixed:
                why = (f"it is updated through both "
                       f"{' and '.join(sorted(pa.minmax))}")
            else:
                forms = []
                if pa.plain_writes:
                    forms.append("plain assignments")
                if pa.reductions:
                    forms.append("reductions "
                                 + ", ".join(sorted(pa.reductions)))
                why = (f"besides the {kind} update it also receives "
                       f"{' and '.join(forms)}")
            line = min(pa.write_lines) if pa.write_lines else fp.line
            diags.append(diag(
                "SP153",
                f"property {prop!r} is not provably monotone under this "
                f"fixedPoint: {why}; convergence and priority scheduling "
                f"both assume one-directional updates",
                line=line, fn=fn_name, src=src))
        dtype = info.node_props.get(prop, info.edge_props.get(prop, ""))
        targets.append(FixedPointTarget(
            prop=prop, kind=kind, dtype=dtype,
            weighted=pa.minmax_weighted, monotone=monotone, line=fp.line))

    return (FixedPointInfo(line=fp.line, conv_prop=conv,
                           conv_written=conv_written, targets=targets),
            diags)
