"""``python -m repro.analyze`` — the analysis layer as a standalone tool.

Targets are ``.sp`` file paths or bundled program names; ``--bundled`` adds
every bundled program, ``--scan-py`` extracts inline triple-quoted DSL
sources from a Python file (the examples embed their programs that way).
``--schedule k=v`` knobs and ``--backend`` feed the legality check;
``--strict`` promotes warnings to errors for the exit code; ``--json``
emits the machine-readable form (diagnostics + effect summaries).

Exit status: 0 clean, 1 when any target has an error (or, under
``--strict``, any warning); 2 for a frontend failure (parse/semantic).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import sys
from typing import List, Optional, Tuple

from ...schedule import Schedule
from ..lexer import LexError
from ..parser import ParseError
from ..semantic import SemanticError
from . import check_schedule, program_analysis
from .diagnostics import ERROR, WARNING

_SRC_RE = re.compile(r'"""(.*?)"""', re.DOTALL)


def _bundled_dir() -> str:
    return os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "programs")


def _bundled_names() -> List[str]:
    return sorted(f[:-3] for f in os.listdir(_bundled_dir())
                  if f.endswith(".sp"))


def _load_target(t: str) -> Tuple[str, str]:
    """-> (display name, source)."""
    if os.path.exists(t):
        with open(t) as f:
            return t, f.read()
    path = os.path.join(_bundled_dir(), f"{t}.sp")
    if os.path.exists(path):
        with open(path) as f:
            return t, f.read()
    raise FileNotFoundError(
        f"no such file or bundled program: {t!r} "
        f"(bundled: {', '.join(_bundled_names())})")


def _scan_py(path: str) -> List[Tuple[str, str]]:
    """Inline DSL sources embedded as triple-quoted strings in a .py file."""
    with open(path) as f:
        text = f.read()
    out = []
    for i, m in enumerate(_SRC_RE.finditer(text)):
        body = m.group(1)
        if "function " in body and "{" in body:
            out.append((f"{path}#inline{i}", body))
    return out


def _parse_schedule(pairs: List[str]) -> Schedule:
    types = {f.name: f.type for f in dataclasses.fields(Schedule)}
    kwargs = {}
    for pair in pairs:
        if "=" not in pair:
            raise SystemExit(f"--schedule expects k=v, got {pair!r}")
        k, v = pair.split("=", 1)
        if k not in types:
            raise SystemExit(
                f"unknown schedule knob {k!r}; knobs: {', '.join(sorted(types))}")
        ty = str(types[k])
        if "int" in ty:
            kwargs[k] = int(v)
        elif "float" in ty:
            kwargs[k] = float(v)
        else:
            kwargs[k] = v
    return Schedule(**kwargs)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="compile-time effect & schedule-legality analysis")
    ap.add_argument("targets", nargs="*",
                    help=".sp files or bundled program names")
    ap.add_argument("--bundled", action="store_true",
                    help="analyze every bundled program")
    ap.add_argument("--scan-py", action="append", default=[],
                    metavar="FILE.py",
                    help="also analyze inline triple-quoted DSL sources")
    ap.add_argument("--schedule", action="append", default=[], metavar="K=V",
                    help="schedule knob for the legality check (repeatable)")
    ap.add_argument("--backend", default="local",
                    choices=["local", "pallas", "distributed"])
    ap.add_argument("--strict", action="store_true",
                    help="warnings fail the exit code too")
    ap.add_argument("--json", action="store_true", dest="as_json")
    args = ap.parse_args(argv)

    sched = _parse_schedule(args.schedule)
    work: List[Tuple[str, str]] = []
    for t in args.targets:
        work.append(_load_target(t))
    if args.bundled:
        for name in _bundled_names():
            work.append(_load_target(name))
    for py in args.scan_py:
        work.extend(_scan_py(py))
    if not work:
        ap.error("nothing to analyze (give targets, --bundled, or --scan-py)")

    report = []
    n_err = n_warn = 0
    for name, source in work:
        try:
            pa = program_analysis(source)
        except (LexError, ParseError, SemanticError) as e:
            print(f"{name}: frontend error: {e}", file=sys.stderr)
            return 2
        diags = []
        for fn_name, fx in sorted(pa.functions.items()):
            diags.extend(fx.diagnostics)
            diags.extend(check_schedule(fx, sched, args.backend))
        n_err += sum(1 for d in diags if d.severity == ERROR)
        n_warn += sum(1 for d in diags if d.severity == WARNING)
        report.append({
            "target": name,
            "diagnostics": [d.to_dict() for d in diags],
            "functions": pa.summary(),
        })
        if not args.as_json:
            status = ("ok" if not diags else
                      f"{sum(1 for d in diags if d.severity == ERROR)} "
                      f"error(s), "
                      f"{sum(1 for d in diags if d.severity == WARNING)} "
                      f"warning(s)")
            print(f"== {name}: {status}")
            for d in diags:
                print(f"  {d.format()}")

    if args.as_json:
        print(json.dumps({"schedule": dataclasses.asdict(sched),
                          "backend": args.backend,
                          "strict": args.strict,
                          "targets": report}, indent=2, sort_keys=True))
    else:
        print(f"-- {len(work)} target(s): {n_err} error(s), "
              f"{n_warn} warning(s)")
    if n_err or (args.strict and n_warn):
        return 1
    return 0
