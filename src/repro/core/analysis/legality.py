"""Schedule legality: reject unsound knob × program-structure combinations.

``check_schedule(effects, schedule, backend)`` is a pure function from the
effect/monotonicity analysis of one DSL function plus a ``Schedule`` to a
list of diagnostics.  It never inspects runtime data — everything here is
decidable at compile time, which is the point: an illegal combination fails
with an actionable SPxxx message instead of a runtime fallback, a cryptic
JAX error, or a silently wrong answer.

Knobs left at their dataclass defaults are treated as ambient rather than
intentional: e.g. the default ``batch_sources=32`` on a program with no
source-set loop is not worth a warning (every compile would emit it), but an
explicitly nonstandard value signals intent and gets SP204.
"""
from __future__ import annotations

from typing import List

from ...schedule import Schedule
from .diagnostics import Diagnostic, diag
from .effects import FunctionEffects

_DEFAULTS = Schedule()


def check_schedule(fx: FunctionEffects, schedule: Schedule,
                   backend: str = "local") -> List[Diagnostic]:
    out: List[Diagnostic] = []
    s = schedule
    fn = fx.name

    if s.priority == "delta":
        target = fx.delta_target()
        if target is None:
            out.append(diag(
                "SP201",
                f"priority=\"delta\" requires a unique monotone int-valued "
                f"Min-relax fixedPoint; {fn!r} has none — delta-stepping "
                f"priority buckets are only sound when re-relaxation can "
                f"only decrease the keyed property",
                fn=fn))
        elif not target.weighted:
            out.append(diag(
                "SP202",
                f"priority=\"delta\" keyed on unweighted relax of "
                f"{target.prop!r}: every relaxation lands in the current "
                f"bucket, so delta-stepping degenerates to plain sweeps",
                line=target.line, fn=fn))

    if (backend == "distributed" and s.dist_frontier in ("compact", "auto")
            and not fx.has_iter_loop):
        out.append(diag(
            "SP203",
            f"dist_frontier={s.dist_frontier!r} carries changed-entry views "
            f"across supersteps, but {fn!r} has no iterative construct "
            f"(fixedPoint / BFS / while); the exchange machinery has "
            f"nothing to carry",
            fn=fn))

    if (s.batch_sources != _DEFAULTS.batch_sources and s.batch_sources > 1
            and not fx.has_set_loop):
        out.append(diag(
            "SP204",
            f"batch_sources={s.batch_sources} set explicitly but {fn!r} has "
            f"no `forall(... in <SetN>)` loop to batch over",
            fn=fn))

    if s.direction in ("push", "pull") and not fx.has_relax:
        out.append(diag(
            "SP205",
            f"direction={s.direction!r} pinned but {fn!r} has no "
            f"direction-switchable neighbor relax or BFS traversal",
            fn=fn))

    if (backend == "distributed" and s.dist_frontier in ("compact", "auto")
            and s.dist_gather_frac >= 0.5):
        out.append(diag(
            "SP206",
            f"dist_gather_frac={s.dist_gather_frac} >= 0.5: the compact "
            f"exchange cap (2 slots per changed entry) never beats a dense "
            f"row, so the schedule statically degrades to dense",
            fn=fn))

    if s.delta_bucket != _DEFAULTS.delta_bucket and s.priority == "none":
        out.append(diag(
            "SP207",
            f"delta_bucket={s.delta_bucket} has no effect while "
            f"priority=\"none\"",
            fn=fn))

    if (s.refresh_threshold_frac != _DEFAULTS.refresh_threshold_frac
            and not fx.has_iter_loop):
        out.append(diag(
            "SP208",
            f"refresh_threshold_frac={s.refresh_threshold_frac} set "
            f"explicitly but {fn!r} has no iterative construct (fixedPoint "
            f"/ while / do-while / BFS) to warm-start — "
            f"`BoundProgram.refresh` raises on this program and the knob "
            f"does nothing",
            fn=fn))

    return out
