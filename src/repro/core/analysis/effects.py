"""Effect analysis: per-region read/write/reduce sets over the typed AST.

Runs after ``semantic.analyze`` (it relies on the ``.sym`` /
``.filter_sugar_iter`` annotations that pass leaves on identifier nodes) and
builds a region tree — one :class:`Region` per ``forall`` / ``fixedPoint`` /
``while`` / BFS construct — whose nodes carry a :class:`PropAccess` record
per property: reads, self-writes, cross-vertex writes, reduction operators,
and Min/Max update kinds.

The race check is the same property StarPlat's atomics insertion relies on
(paper §4): a *plain* property assignment whose destination slot is shared
across iterations of an enclosing parallel loop is a write-write race →
SP101.  A slot is shared when some parallel loop other than the one binding
the destination iterator encloses the write; ``forall(src in sourceSet)``
loops are exempt because the batched engine gives every source its own
``[N, B]`` lane (properties declared per-source never alias across sources).
Min/Max multi-assignments and reduction assignments (``+=`` or the
``x = x + t`` fold, mirroring ``lowering.assign``) are synchronized updates,
never races.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from .. import ast_nodes as A
from ..semantic import FunctionInfo
from .diagnostics import Diagnostic, diag

#: ops `lowering.assign` folds from `x = x <op> t` into a reduce-assign
_FOLD_OPS = ("+", "*")

_ELEM_ITERS = ("iter_vertex", "iter_nbr", "iter_set", "iter_bfs")


# --------------------------------------------------------------------------
# Data model
# --------------------------------------------------------------------------

@dataclass
class PropAccess:
    """Access record for one property within one region."""
    reads: int = 0
    self_writes: int = 0          # destination slot private to the iteration
    cross_writes: int = 0         # scatter / shared-slot writes
    plain_writes: int = 0         # unsynchronized assignments (race candidates)
    extra_writes: int = 0         # Min/Max-synchronized extra targets
    reductions: Set[str] = field(default_factory=set)
    minmax: Set[str] = field(default_factory=set)
    minmax_weighted: bool = False  # some Min/Max candidate reads an edge weight
    read_lines: Set[int] = field(default_factory=set)
    write_lines: Set[int] = field(default_factory=set)

    @property
    def written(self) -> bool:
        return bool(self.plain_writes or self.extra_writes
                    or self.reductions or self.minmax)

    def summary(self) -> dict:
        return {
            "reads": self.reads,
            "self_writes": self.self_writes,
            "cross_writes": self.cross_writes,
            "plain_writes": self.plain_writes,
            "extra_writes": self.extra_writes,
            "reductions": sorted(self.reductions),
            "minmax": sorted(self.minmax),
            "minmax_weighted": self.minmax_weighted,
        }


@dataclass
class Region:
    """One lexical parallel/iterative construct and its property effects."""
    kind: str                     # function|forall|for|fixedpoint|while|do_while|bfs|bfs_reverse
    line: int = 0
    iterator: str = ""
    parallel: bool = False
    props: Dict[str, PropAccess] = field(default_factory=dict)
    children: List["Region"] = field(default_factory=list)

    def access(self, prop: str) -> PropAccess:
        return self.props.setdefault(prop, PropAccess())

    def summary(self) -> dict:
        return {
            "kind": self.kind,
            "line": self.line,
            "iterator": self.iterator,
            "parallel": self.parallel,
            "props": {p: self.props[p].summary() for p in sorted(self.props)},
            "children": [c.summary() for c in self.children],
        }


@dataclass
class FixedPointTarget:
    """One Min/Max-updated property inside a fixedPoint loop."""
    prop: str
    kind: str                     # Min | Max | mixed
    dtype: str
    weighted: bool
    monotone: bool
    line: int = 0

    def summary(self) -> dict:
        return {"prop": self.prop, "kind": self.kind, "dtype": self.dtype,
                "weighted": self.weighted, "monotone": self.monotone}


@dataclass
class FixedPointInfo:
    line: int
    conv_prop: Optional[str]
    conv_written: bool
    targets: List[FixedPointTarget] = field(default_factory=list)

    def summary(self) -> dict:
        return {"line": self.line, "conv_prop": self.conv_prop,
                "conv_written": self.conv_written,
                "targets": [t.summary() for t in self.targets]}


@dataclass
class FunctionEffects:
    """The full analysis result for one DSL function."""
    name: str
    region: Region
    fixedpoints: List[FixedPointInfo] = field(default_factory=list)
    diagnostics: List[Diagnostic] = field(default_factory=list)
    node_props: Dict[str, str] = field(default_factory=dict)
    edge_props: Dict[str, str] = field(default_factory=dict)
    has_set_loop: bool = False
    has_bfs: bool = False
    has_iter_loop: bool = False   # fixedPoint / while / do-while / BFS
    has_relax: bool = False       # any Min/Max update (direction-switchable)
    # Self-gated peeling: a while/do-while whose body plain-writes a property
    # that gates which vertices the enclosing forall / if visits (k-core's
    # `filter(core == 1) { ... v.core = 0 }`).  The converged state is the
    # fixpoint of an erosion, not a monotone relax — warm-starting it from a
    # pre-update run is unsound, so `bound.refresh` refuses (SP209).
    refresh_unsafe: bool = False
    refresh_unsafe_reason: str = ""
    refresh_unsafe_line: int = 0

    def delta_target(self) -> Optional[FixedPointTarget]:
        """The unique monotone int32 Min-relax property eligible for
        delta-stepping, or None — mirrors ``local_jax._delta_target``."""
        cands = []
        for fp in self.fixedpoints:
            if fp.conv_prop is None:
                continue
            for t in fp.targets:
                if (t.monotone and t.kind == "Min" and t.dtype == "int32"
                        and t.prop != fp.conv_prop):
                    cands.append(t)
        return cands[0] if len(cands) == 1 else None

    def summary(self) -> dict:
        return {
            "name": self.name,
            "region": self.region.summary(),
            "fixedpoints": [fp.summary() for fp in self.fixedpoints],
            "flags": {
                "has_set_loop": self.has_set_loop,
                "has_bfs": self.has_bfs,
                "has_iter_loop": self.has_iter_loop,
                "has_relax": self.has_relax,
                "refresh_unsafe": self.refresh_unsafe,
                "delta_target": (self.delta_target().prop
                                 if self.delta_target() else None),
            },
            "diagnostics": [d.to_dict() for d in self.diagnostics],
        }


@dataclass
class _LoopEntry:
    iterator: str
    parallel: bool
    kind: str          # semantic iterator kind (iter_vertex|iter_nbr|iter_set|iter_bfs) or ""
    # sharing: concurrent iterations of this loop can alias property slots
    # bound elsewhere.  Source-set foralls are excluded: the batched engine
    # gives each source its own [N, B] lane.
    sharing: bool = False


# --------------------------------------------------------------------------
# Walker
# --------------------------------------------------------------------------

class _EffectWalker:
    def __init__(self, fn: A.Function, info: FunctionInfo,
                 src: Optional[str]):
        self.fn = fn
        self.info = info
        self.src = src
        self.root = Region(kind="function", line=fn.line, iterator="",
                           parallel=False)
        self.regions: List[Region] = [self.root]
        self.loops: List[_LoopEntry] = []
        # SP209 detection state: depth of enclosing while/do-while regions,
        # and a stack of gate-prop sets (props read by enclosing forall
        # filters / if conditions — they decide which slots get visited)
        self.while_depth = 0
        self.gate_props: List[Set[str]] = []
        self.scalar_depths: Dict[str, int] = {
            p.name: 0 for p in info.params}
        self.diagnostics: List[Diagnostic] = []
        self.fixedpoints: List[FixedPointInfo] = []
        self.fx = FunctionEffects(name=fn.name, region=self.root,
                                  node_props=dict(info.node_props),
                                  edge_props=dict(info.edge_props))

    def run(self) -> FunctionEffects:
        self._block(self.fn.body)
        self.fx.fixedpoints = self.fixedpoints
        self.fx.diagnostics = self.diagnostics
        return self.fx

    # ---- helpers ---------------------------------------------------------

    def _emit(self, code: str, msg: str, line: int):
        self.diagnostics.append(
            diag(code, msg, line=line, fn=self.fn.name, src=self.src))

    def _push_region(self, kind: str, line: int, iterator: str = "",
                     parallel: bool = False) -> Region:
        r = Region(kind=kind, line=line, iterator=iterator, parallel=parallel)
        self.regions[-1].children.append(r)
        self.regions.append(r)
        return r

    def _pop_region(self):
        self.regions.pop()

    def _is_prop(self, name: str) -> bool:
        return name in self.info.node_props or name in self.info.edge_props

    def _record_read(self, prop: str, line: int):
        for r in self.regions:
            pa = r.access(prop)
            pa.reads += 1
            pa.read_lines.add(line)

    def _binding_index(self, name: str) -> Optional[int]:
        for i in range(len(self.loops) - 1, -1, -1):
            if self.loops[i].iterator == name:
                return i
        return None

    def _shared_slot(self, binding_idx: Optional[int]) -> bool:
        """True when a parallel loop other than the destination's binding
        loop encloses the write — concurrent iterations hit the same slot."""
        return any(e.sharing for i, e in enumerate(self.loops)
                   if i != binding_idx)

    # ---- reads -----------------------------------------------------------

    def _read(self, e):
        if e is None:
            return
        if isinstance(e, A.Identifier):
            sym = getattr(e, "sym", None)
            if getattr(e, "filter_sugar_iter", None) is not None:
                self._record_read(e.name, e.line)
            elif sym is not None and sym.kind in ("prop_node", "prop_edge"):
                self._record_read(e.name, e.line)
        elif isinstance(e, A.MemberAccess):
            if self._is_prop(e.member) or e.member == "weight":
                self._record_read(e.member, e.line)
            self._read(e.target)
        elif isinstance(e, A.BinaryOp):
            self._read(e.left)
            self._read(e.right)
        elif isinstance(e, A.UnaryOp):
            self._read(e.operand)
        elif isinstance(e, A.ProcCall):
            self._read(e.target)
            for a in e.args:
                self._read(a)
            for _, v in e.kwargs:
                self._read(v)
        elif isinstance(e, A.MinMaxExpr):
            for a in e.args:
                self._read(a)

    def _prop_reads(self, e) -> Set[str]:
        """Property names read anywhere in ``e`` (filter sugar included)."""
        props: Set[str] = set()
        if e is None:
            return props

        def visit(n):
            if isinstance(n, A.Identifier):
                sym = getattr(n, "sym", None)
                if getattr(n, "filter_sugar_iter", None) is not None or (
                        sym is not None
                        and sym.kind in ("prop_node", "prop_edge")):
                    props.add(n.name)
            elif isinstance(n, A.MemberAccess):
                if self._is_prop(n.member):
                    props.add(n.member)
        A.walk(e, visit)
        return props

    def _weighted(self, e) -> bool:
        """Does the expression read an edge weight / edge property?"""
        found = [False]

        def visit(n):
            if isinstance(n, A.MemberAccess) and (
                    n.member == "weight" or n.member in self.info.edge_props):
                found[0] = True
        A.walk(e, visit)
        return found[0]

    # ---- writes ----------------------------------------------------------

    def _record_write(self, prop: str, line: int, *, cross: bool,
                      reduce_op: Optional[str] = None,
                      minmax: Optional[str] = None,
                      weighted: bool = False, extra: bool = False):
        for r in self.regions:
            pa = r.access(prop)
            pa.write_lines.add(line)
            if cross:
                pa.cross_writes += 1
            else:
                pa.self_writes += 1
            if minmax is not None:
                pa.minmax.add(minmax)
                pa.minmax_weighted |= weighted
            elif extra:
                pa.extra_writes += 1
            elif reduce_op is not None:
                pa.reductions.add(reduce_op)
            else:
                pa.plain_writes += 1

    def _write_member(self, ma: A.MemberAccess, line: int, *,
                      reduce_op: Optional[str] = None,
                      minmax: Optional[str] = None,
                      weighted: bool = False, extra: bool = False):
        prop = ma.member
        tgt = ma.target
        if not isinstance(tgt, A.Identifier):
            return
        tsym = getattr(tgt, "sym", None)
        if tsym is None:
            return
        if tsym.kind == "edge_var":
            # an edge var is unique per (src, nbr) iteration pair — private
            self._record_write(prop, line, cross=False, reduce_op=reduce_op,
                               minmax=minmax, weighted=weighted, extra=extra)
            return
        binding = (self._binding_index(tgt.name)
                   if tsym.kind in _ELEM_ITERS else None)
        shared = self._shared_slot(binding)
        cross = tsym.kind == "iter_nbr" or shared
        self._record_write(prop, line, cross=cross, reduce_op=reduce_op,
                           minmax=minmax, weighted=weighted, extra=extra)
        if (reduce_op is None and minmax is None and not extra
                and self.while_depth > 0 and not self.fx.refresh_unsafe
                and any(prop in g for g in self.gate_props)):
            # plain write to a prop that gates visitation, inside a while
            # region: the self-gated peeling pattern (see FunctionEffects)
            self.fx.refresh_unsafe = True
            self.fx.refresh_unsafe_line = line
            self.fx.refresh_unsafe_reason = (
                f"property {prop!r} is plain-assigned inside a while loop "
                f"and also gates which vertices are visited (a filter/if "
                f"condition reads it); this peeling-style fixpoint is not "
                f"monotone over graph updates, so a warm start from the "
                f"pre-update state is unsound")
        if shared and reduce_op is None and minmax is None and not extra:
            self._emit(
                "SP101",
                f"property {prop!r} is plain-assigned through {tgt.name!r} "
                f"inside a parallel loop; concurrent iterations write the "
                f"same slot — use a reduction (`+=`) or a "
                f"`<Min(...)>`/`<Max(...)>` update",
                line)

    def _fold_reduce(self, s: A.AssignmentStmt) -> Optional[str]:
        """Mirror ``lowering.assign``'s `x = x <op> t` fold."""
        if s.reduce_op is not None:
            return s.reduce_op
        rhs = s.rhs
        if not (isinstance(rhs, A.BinaryOp) and rhs.op in _FOLD_OPS):
            return None
        if self._lhs_key(s.lhs) is not None and \
                self._lhs_key(rhs.left) == self._lhs_key(s.lhs):
            return rhs.op
        return None

    @staticmethod
    def _lhs_key(e) -> Optional[str]:
        if isinstance(e, A.Identifier):
            return f"id:{e.name}"
        if isinstance(e, A.MemberAccess) and isinstance(e.target, A.Identifier):
            return f"prop:{e.target.name}.{e.member}"
        return None

    # ---- statements ------------------------------------------------------

    def _block(self, b: A.BlockStmt):
        for s in b.stmts:
            self._stmt(s)

    def _stmt(self, s):
        if isinstance(s, A.DeclarationStmt):
            self.scalar_depths[s.name] = len(self.loops)
            self._read(s.init)
        elif isinstance(s, A.AssignmentStmt):
            self._assign(s)
        elif isinstance(s, A.MultiAssignmentStmt):
            self._multi(s)
        elif isinstance(s, A.ForallStmt):
            self._forall(s)
        elif isinstance(s, A.FixedPointStmt):
            self._fixedpoint(s)
        elif isinstance(s, A.WhileStmt):
            self.fx.has_iter_loop = True
            self._push_region("while", s.line)
            self._read(s.cond)
            self.while_depth += 1
            self._block(s.body)
            self.while_depth -= 1
            self._pop_region()
        elif isinstance(s, A.DoWhileStmt):
            self.fx.has_iter_loop = True
            self._push_region("do_while", s.line)
            self.while_depth += 1
            self._block(s.body)
            self.while_depth -= 1
            self._read(s.cond)
            self._pop_region()
        elif isinstance(s, A.IfStmt):
            self._read(s.cond)
            self.gate_props.append(self._prop_reads(s.cond))
            self._block(s.then_body)
            if s.else_body is not None:
                self._block(s.else_body)
            self.gate_props.pop()
        elif isinstance(s, A.IterateInBFSStmt):
            self._bfs(s)
        elif isinstance(s, A.ProcCallStmt):
            self._proc_call(s.call, s.line)
        elif isinstance(s, A.ReturnStmt):
            self._read(s.value)
        elif isinstance(s, A.BlockStmt):
            self._block(s)

    def _assign(self, s: A.AssignmentStmt):
        reduce_op = self._fold_reduce(s)
        self._read(s.rhs)
        lhs = s.lhs
        if isinstance(lhs, A.MemberAccess):
            self._write_member(lhs, s.line, reduce_op=reduce_op)
            return
        if not isinstance(lhs, A.Identifier):
            return
        sym = getattr(lhs, "sym", None)
        if sym is None:
            return
        if sym.kind in ("prop_node", "prop_edge"):
            # whole-property copy (`pageRank = pageRank_nxt`)
            shared = self._shared_slot(None)
            self._record_write(lhs.name, s.line, cross=shared,
                               reduce_op=reduce_op)
            if shared and reduce_op is None:
                self._emit(
                    "SP101",
                    f"whole-property assignment to {lhs.name!r} inside a "
                    f"parallel loop races across iterations",
                    s.line)
        elif sym.kind == "scalar":
            decl = self.scalar_depths.get(lhs.name, 0)
            shared = any(e.sharing for e in self.loops[decl:])
            if shared and reduce_op is None:
                self._emit(
                    "SP102",
                    f"scalar {lhs.name!r} is plain-assigned inside a "
                    f"parallel loop (last-writer-wins); use a reduction "
                    f"form such as `{lhs.name} = {lhs.name} + ...`",
                    s.line)

    def _multi(self, s: A.MultiAssignmentStmt):
        if (s.values and isinstance(s.values[0], A.MinMaxExpr)
                and s.targets and isinstance(s.targets[0], A.MemberAccess)):
            mm = s.values[0]
            self.fx.has_relax = True
            for a in mm.args:
                self._read(a)
            self._write_member(s.targets[0], s.line, minmax=mm.kind,
                               weighted=self._weighted(mm))
            for t, v in zip(s.targets[1:], s.values[1:]):
                self._read(v)
                if isinstance(t, A.MemberAccess):
                    self._write_member(t, s.line, extra=True)
        else:
            for t, v in zip(s.targets, s.values):
                self._read(v)
                if isinstance(t, A.MemberAccess):
                    self._write_member(t, s.line)

    def _forall(self, s: A.ForallStmt):
        it_sym = getattr(s, "iter_sym", None)
        it_kind = it_sym.kind if it_sym is not None else ""
        if it_kind == "iter_set":
            self.fx.has_set_loop = True
        kind = "forall" if s.parallel else "for"
        self._push_region(kind, s.line, iterator=s.iterator.name,
                          parallel=s.parallel)
        self.loops.append(_LoopEntry(
            iterator=s.iterator.name, parallel=s.parallel, kind=it_kind,
            sharing=s.parallel and it_kind != "iter_set"))
        if isinstance(s.range_call, A.ProcCall):
            self._read(s.range_call)
        if s.filter_expr is not None:
            self._read(s.filter_expr)
        self.gate_props.append(self._prop_reads(s.filter_expr))
        self._block(s.body)
        self.gate_props.pop()
        self.loops.pop()
        self._pop_region()

    def _fixedpoint(self, s: A.FixedPointStmt):
        from .monotone import analyze_fixedpoint  # local: avoid import cycle
        self.fx.has_iter_loop = True
        region = self._push_region("fixedpoint", s.line)
        self._read(s.conv_expr)
        self._block(s.body)
        self._pop_region()
        info, diags = analyze_fixedpoint(s, region, self.info,
                                         self.src, self.fn.name)
        self.fixedpoints.append(info)
        self.diagnostics.extend(diags)

    def _bfs(self, s: A.IterateInBFSStmt):
        self.fx.has_bfs = True
        self.fx.has_iter_loop = True
        self.fx.has_relax = True   # BFS levels are direction-switchable
        self._read(s.root)
        self._push_region("bfs", s.line, iterator=s.iterator.name,
                          parallel=True)
        self.loops.append(_LoopEntry(iterator=s.iterator.name, parallel=True,
                                     kind="iter_bfs", sharing=True))
        if s.filter_expr is not None:
            self._read(s.filter_expr)
        self._block(s.body)
        self.loops.pop()
        self._pop_region()
        if s.reverse is not None:
            rev = s.reverse
            self._push_region("bfs_reverse", rev.line or s.line,
                              iterator=s.iterator.name, parallel=True)
            self.loops.append(_LoopEntry(iterator=s.iterator.name,
                                         parallel=True, kind="iter_bfs",
                                         sharing=True))
            if rev.filter_expr is not None:
                self._read(rev.filter_expr)
            self._block(rev.body)
            self.loops.pop()
            self._pop_region()

    def _proc_call(self, call: A.ProcCall, line: int):
        if call.name in ("attachNodeProperty", "attachEdgeProperty"):
            shared = self._shared_slot(None)
            for prop, vexpr in call.kwargs:
                self._read(vexpr)
                self._record_write(prop, line, cross=shared)
                if shared:
                    self._emit(
                        "SP101",
                        f"{call.name}({prop}=...) inside a parallel loop "
                        f"rewrites the whole property concurrently",
                        line)
        else:
            self._read(call)


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------

def analyze_function(fn: A.Function, info: FunctionInfo,
                     src: Optional[str] = None) -> FunctionEffects:
    """Effect-analyze one semantically-annotated function."""
    return _EffectWalker(fn, info, src).run()
