"""repro.core.analysis — compile-time effect & legality analysis.

Public surface:

* :func:`program_analysis` — parse + semantic + effect/monotone analysis of
  a DSL source, memoized by source digest (the compile gate calls this on
  every ``compile_program``, including cache hits).
* :func:`check_schedule` — pure schedule-legality check per function.
* :class:`Diagnostic` / :class:`DiagnosticError` / ``REGISTRY`` — the stable
  SPxxx code registry and the one structured error shape the gate raises.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict

from ..parser import parse
from ..semantic import analyze as semantic_analyze
from .diagnostics import (ERROR, REGISTRY, SEVERITIES, WARNING, Diagnostic,
                          DiagnosticError, diag, entry_error, quote_line,
                          severity_of, split)
from .effects import (FixedPointInfo, FixedPointTarget, FunctionEffects,
                      PropAccess, Region, analyze_function)
from .legality import check_schedule
from .monotone import analyze_fixedpoint, conv_prop_of

__all__ = [
    "Diagnostic", "DiagnosticError", "REGISTRY", "SEVERITIES", "ERROR",
    "WARNING", "diag", "entry_error", "quote_line", "severity_of", "split",
    "FunctionEffects", "FixedPointInfo", "FixedPointTarget", "PropAccess",
    "Region", "analyze_function", "analyze_fixedpoint", "conv_prop_of",
    "check_schedule", "ProgramAnalysis", "program_analysis",
    "analysis_cache_clear",
]


@dataclass
class ProgramAnalysis:
    """Analysis of every function in one DSL source."""
    source: str
    functions: Dict[str, FunctionEffects] = field(default_factory=dict)

    def summary(self) -> dict:
        return {name: fx.summary()
                for name, fx in sorted(self.functions.items())}


_CACHE: Dict[str, ProgramAnalysis] = {}


def program_analysis(source: str) -> ProgramAnalysis:
    """Full compile-time analysis of ``source``, memoized by digest.

    Raises the frontend's own ``ParseError`` / ``SemanticError`` unchanged —
    the analysis layer only speaks for well-formed programs."""
    digest = hashlib.sha256(source.encode()).hexdigest()
    hit = _CACHE.get(digest)
    if hit is not None:
        return hit
    prog = parse(source)
    infos = semantic_analyze(prog)
    pa = ProgramAnalysis(source=source, functions={
        fn.name: analyze_function(fn, infos[fn.name], source)
        for fn in prog.functions})
    _CACHE[digest] = pa
    return pa


def analysis_cache_clear():
    _CACHE.clear()
