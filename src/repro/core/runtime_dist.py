"""Distributed runtime for the MPI-analogue backend (shard_map + collectives).

The paper's MPI backend (§3.2): 1-D block vertex partitioning, BSP steps of
local compute + communication, send-buffer aggregation ("a single message
with the local minimum" §4.2). Here:

  * each device owns a contiguous vertex block (`own_ids`), the last block
    padded — exactly the paper's scheme;
  * property exchange = `all_gather` (tiled) over the `data` axis;
  * update combining = `pmin`/`psum` over scattered candidate arrays — the
    communication-aggregation optimization is the collective itself;
  * the fixed-point flag = a global OR (psum of local any()).

`prepare_graph_1d` builds the device-stacked arrays consumed by the
generated per-device body. All collectives are `jax.lax` ops inside
`shard_map`, so the same generated code lowers to ICI collectives on a real
TPU mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from ..graph.partition import block_partition_1d
from . import runtime as rt

AXIS = "data"


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-compat shard_map: `jax.shard_map(..., check_vma=False)` on
    new jax, `jax.experimental.shard_map.shard_map(..., check_rep=False)`
    on 0.4.x — same semantics (replication checking off; the generated
    bodies use collectives explicitly)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def axis_size(name: str) -> int:
    """Static mesh-axis size from inside a shard_map body. `psum(1, axis)`
    constant-folds to a Python int on every jax line; `lax.axis_size` only
    exists on newer ones."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


# --------------------------------------------------------------------------
# Graph preparation (host side)
# --------------------------------------------------------------------------

def prepare_graph_1d(g: CSRGraph, num_devices: int, *, ell: bool = False) -> dict:
    """Device-stacked arrays for the 1-D partitioned backend.

    Keys with leading [P] shard over the mesh 'data' axis; `*_rep` keys are
    replicated static graph structure (degree tables, the sorted edge key
    for is_an_edge)."""
    p = num_devices
    out = block_partition_1d(g, p)                      # out-edges by src block
    # in-edges partitioned by dst block: build from the reverse CSR
    rev = CSRGraph(
        indptr=g.rev_indptr, indices=g.rev_indices, weights=g.rev_weights,
        edge_src=g.rev_edge_dst, rev_indptr=g.indptr, rev_indices=g.indices,
        rev_weights=g.weights, rev_edge_dst=g.edge_src,
        out_degree=g.in_degree, in_degree=g.out_degree,
        edge_key=g.rev_edge_dst * jnp.int32(g.num_nodes) + g.rev_indices,
        num_nodes=g.num_nodes, num_edges=g.num_edges,
        max_out_degree=g.max_in_degree, max_in_degree=g.max_out_degree)
    inn = block_partition_1d(rev, p)                    # (dst, src) pairs by dst block
    block = out.block
    n_pad = out.num_nodes_padded
    own_ids = (np.arange(p)[:, None] * block + np.arange(block)[None, :]).astype(np.int32)

    deg_out = np.zeros(n_pad, np.int32)
    deg_out[: g.num_nodes] = np.asarray(g.out_degree)
    deg_in = np.zeros(n_pad, np.int32)
    deg_in[: g.num_nodes] = np.asarray(g.in_degree)

    gd = {
        "esrc": jnp.asarray(out.src), "edst": jnp.asarray(out.dst),
        "ew": jnp.asarray(out.weight), "evalid": jnp.asarray(out.valid),
        # local slot of the source vertex; padding edges clipped to 0 and
        # neutralized by the valid mask
        "esrc_local": jnp.asarray(np.clip(
            out.src - (np.arange(p) * block)[:, None], 0, block - 1).astype(np.int32)),
        # in-edge arrays: src field of `inn` is the OWNED dst, dst field is the in-neighbor
        "idst": jnp.asarray(inn.src), "isrc": jnp.asarray(inn.dst),
        "iw": jnp.asarray(inn.weight), "ivalid": jnp.asarray(inn.valid),
        "idst_local": jnp.asarray(np.clip(
            inn.src - (np.arange(p) * block)[:, None], 0, block - 1).astype(np.int32)),
        "own_ids": jnp.asarray(own_ids),
        "out_degree_rep": jnp.asarray(deg_out),
        "in_degree_rep": jnp.asarray(deg_in),
        "n_true_rep": jnp.asarray(g.num_nodes, jnp.int32),
    }
    gd["edge_key_rep"] = g.edge_key   # cached, built once in from_edges
    if ell:
        from ..graph.csr import to_ell
        e = to_ell(g)
        cols = np.asarray(e.cols)
        cols_pad = np.full((n_pad, e.max_deg), n_pad, np.int32)
        cols_pad[: g.num_nodes] = np.where(cols == g.num_nodes, n_pad, cols)
        gd["ell_cols"] = jnp.asarray(
            cols_pad.reshape(p, block, e.max_deg))
    return gd


def partition_specs(gd: dict, mesh):
    """PartitionSpec per gd key: stacked arrays shard on 'data', *_rep replicate."""
    from jax.sharding import PartitionSpec as P
    specs = {}
    for k, v in gd.items():
        if k.endswith("_rep"):
            specs[k] = P()
        else:
            specs[k] = P(AXIS, *([None] * (v.ndim - 1)))
    return specs


# --------------------------------------------------------------------------
# Collective helpers (used by generated code)
# --------------------------------------------------------------------------

def gather(x):
    """Property exchange: every device receives the full array (BSP step)."""
    return jax.lax.all_gather(x, AXIS, tiled=True)


def pmin(x):
    return jax.lax.pmin(x, AXIS)


def pmax(x):
    return jax.lax.pmax(x, AXIS)


def psum(x):
    return jax.lax.psum(x, AXIS)


def por(x):  # global OR of a local bool scalar
    return jax.lax.psum(x.astype(jnp.int32), AXIS) > 0


def any_global(x):  # global OR over a local bool array
    return por(jnp.any(x))


def combine_scatter_min(n_pad: int, idx, cand, dtype):
    """Paper §4.2 'communication aggregation': local scatter-min into a
    full-size buffer, then a single min-combine across devices."""
    buf = jnp.full((n_pad,), rt.inf_for(dtype), dtype)
    return pmin(buf.at[idx].min(cand))


def combine_scatter_add(n_pad: int, idx, vals, dtype):
    buf = jnp.zeros((n_pad,), dtype)
    return psum(buf.at[idx].add(vals))


def combine_scatter_max(n_pad: int, idx, cand, dtype):
    buf = jnp.full((n_pad,), -rt.inf_for(dtype) if jnp.dtype(dtype).kind != "b" else False, dtype)
    return pmax(buf.at[idx].max(cand))


# --------------------------------------------------------------------------
# Distributed BFS (iterateInBFS construct)
# --------------------------------------------------------------------------

def bfs_levels_1d(esrc, edst, evalid, own_ids, root, n_pad: int):
    """Level-synchronous distributed BFS over 1-D partitioned out-edges.
    Returns (level_blk[int32 B], depth)."""
    level0 = jnp.where(own_ids == root, 0, -1).astype(jnp.int32)

    def cond(state):
        return state[2]

    def body(state):
        level_blk, cur, _ = state
        level_full = gather(level_blk)
        src_on = (level_full[esrc] == cur) & evalid
        unseen = level_full[edst] < 0
        reach = combine_scatter_add(n_pad, edst, (src_on & unseen).astype(jnp.int32), jnp.int32)
        newly = (reach[own_ids] > 0) & (level_blk < 0)
        level_blk = jnp.where(newly, cur + 1, level_blk)
        return level_blk, cur + 1, any_global(newly)

    level, depth, _ = jax.lax.while_loop(
        cond, body, (level0, jnp.int32(0), jnp.bool_(True)))
    return level, depth


# --------------------------------------------------------------------------
# Distributed triangle counting (wedge pattern over own rows)
# --------------------------------------------------------------------------

def wedge_count_1d(ell_cols, own_ids, edge_key, n_true, chunk: int = 256):
    """Fig. 20 wedge count for the owned vertex block; caller psums."""
    b, d = ell_cols.shape
    chunk = min(chunk, b)
    num_chunks = -(-b // chunk)

    def chunk_count(c, acc):
        ridx = c * chunk + jnp.arange(chunk)
        row_ok = ridx < b
        ridx = jnp.clip(ridx, 0, b - 1)
        rows = ell_cols[ridx]
        vs = own_ids[ridx]
        valid = rows < n_true            # padding slots point past the graph
        u = rows[:, :, None]
        w = rows[:, None, :]
        vv = vs[:, None, None]
        mask = (valid[:, :, None] & valid[:, None, :] & (u < vv) & (w > vv)
                & (vv < n_true) & row_ok[:, None, None])
        q = u.astype(jnp.int32) * n_true + w.astype(jnp.int32)
        pos = jnp.clip(jnp.searchsorted(edge_key, q.ravel()), 0, edge_key.shape[0] - 1)
        hit = (edge_key[pos] == q.ravel()).reshape(q.shape)
        return acc + jnp.sum(jnp.where(mask, hit, False).astype(jnp.int32))

    local = jax.lax.fori_loop(0, num_chunks, chunk_count, jnp.int32(0))
    return psum(local)
