"""Distributed runtime for the MPI-analogue backend (shard_map + collectives).

The paper's MPI backend (§3.2): 1-D block vertex partitioning, BSP steps of
local compute + communication, send-buffer aggregation ("a single message
with the local minimum" §4.2). Here:

  * each device owns a contiguous vertex block (`own_ids`), the last block
    padded — exactly the paper's scheme;
  * property exchange = `all_gather` (tiled) over the `data` axis, or the
    frontier-compressed `exchange` (changed entries only, through fixed
    per-shard buffers) when the compiled Schedule's `dist_frontier` policy
    asks for it;
  * update combining = `pmin`/`psum` over scattered candidate arrays — the
    communication-aggregation optimization is the collective itself;
  * the fixed-point flag = a global OR (psum of local any()).

`prepare_graph_1d` builds the device-stacked arrays consumed by the
generated per-device body. All collectives are `jax.lax` ops inside
`shard_map`, so the same generated code lowers to ICI collectives on a real
TPU mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from ..graph.partition import block_partition_1d
from . import runtime as rt

AXIS = "data"


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-compat shard_map: `jax.shard_map(..., check_vma=False)` on
    new jax, `jax.experimental.shard_map.shard_map(..., check_rep=False)`
    on 0.4.x — same semantics (replication checking off; the generated
    bodies use collectives explicitly)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def axis_size(name: str) -> int:
    """Static mesh-axis size from inside a shard_map body. `psum(1, axis)`
    constant-folds to a Python int on every jax line; `lax.axis_size` only
    exists on newer ones."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


# --------------------------------------------------------------------------
# Graph preparation (host side)
# --------------------------------------------------------------------------

def prepare_graph_1d(g: CSRGraph, num_devices: int, *, ell: bool = False) -> dict:
    """Device-stacked arrays for the 1-D partitioned backend.

    Keys with leading [P] shard over the mesh 'data' axis; `*_rep` keys are
    replicated static graph structure (degree tables, the sorted edge key
    for is_an_edge)."""
    p = num_devices
    out = block_partition_1d(g, p)                      # out-edges by src block
    # in-edges partitioned by dst block: build from the reverse CSR
    rev = CSRGraph(
        indptr=g.rev_indptr, indices=g.rev_indices, weights=g.rev_weights,
        edge_src=g.rev_edge_dst, rev_indptr=g.indptr, rev_indices=g.indices,
        rev_weights=g.weights, rev_edge_dst=g.edge_src,
        out_degree=g.in_degree, in_degree=g.out_degree,
        edge_key=g.rev_edge_dst * jnp.int32(g.num_nodes) + g.rev_indices,
        num_nodes=g.num_nodes, num_edges=g.num_edges,
        max_out_degree=g.max_in_degree, max_in_degree=g.max_out_degree)
    inn = block_partition_1d(rev, p)                    # (dst, src) pairs by dst block
    block = out.block
    n_pad = out.num_nodes_padded
    own_ids = (np.arange(p)[:, None] * block + np.arange(block)[None, :]).astype(np.int32)

    deg_out = np.zeros(n_pad, np.int32)
    deg_out[: g.num_nodes] = np.asarray(g.out_degree)
    deg_in = np.zeros(n_pad, np.int32)
    deg_in[: g.num_nodes] = np.asarray(g.in_degree)

    gd = {
        "esrc": jnp.asarray(out.src), "edst": jnp.asarray(out.dst),
        "ew": jnp.asarray(out.weight), "evalid": jnp.asarray(out.valid),
        # local slot of the source vertex; padding edges clipped to 0 and
        # neutralized by the valid mask
        "esrc_local": jnp.asarray(np.clip(
            out.src - (np.arange(p) * block)[:, None], 0, block - 1).astype(np.int32)),
        # in-edge arrays: src field of `inn` is the OWNED dst, dst field is the in-neighbor
        "idst": jnp.asarray(inn.src), "isrc": jnp.asarray(inn.dst),
        "iw": jnp.asarray(inn.weight), "ivalid": jnp.asarray(inn.valid),
        "idst_local": jnp.asarray(np.clip(
            inn.src - (np.arange(p) * block)[:, None], 0, block - 1).astype(np.int32)),
        "own_ids": jnp.asarray(own_ids),
        "out_degree_rep": jnp.asarray(deg_out),
        "in_degree_rep": jnp.asarray(deg_in),
        "n_true_rep": jnp.asarray(g.num_nodes, jnp.int32),
    }
    gd["edge_key_rep"] = g.edge_key   # cached, built once in from_edges
    if ell:
        from ..graph.csr import to_ell
        e = to_ell(g)
        cols = np.asarray(e.cols)
        cols_pad = np.full((n_pad, e.max_deg), n_pad, np.int32)
        cols_pad[: g.num_nodes] = np.where(cols == g.num_nodes, n_pad, cols)
        gd["ell_cols"] = jnp.asarray(
            cols_pad.reshape(p, block, e.max_deg))
    return gd


def partition_specs(gd: dict, mesh):
    """PartitionSpec per gd key: stacked arrays shard on 'data', *_rep replicate."""
    from jax.sharding import PartitionSpec as P
    specs = {}
    for k, v in gd.items():
        if k.endswith("_rep"):
            specs[k] = P()
        else:
            specs[k] = P(AXIS, *([None] * (v.ndim - 1)))
    return specs


# --------------------------------------------------------------------------
# Collective helpers (used by generated code)
# --------------------------------------------------------------------------

def gather(x):
    """Property exchange: every device receives the full array (BSP step)."""
    return jax.lax.all_gather(x, AXIS, tiled=True)


def gather_rows(x):
    """Batched property exchange: [S, B] lane blocks -> [S, N_pad] full rows
    (all-gather along the vertex axis; lanes ride along)."""
    return jax.lax.all_gather(x, AXIS, tiled=True, axis=1)


def compact_cap(block: int, frac: float) -> int:
    """Static per-shard compact-buffer capacity for a [block]-sized shard."""
    return max(min(int(block * frac), block), 1)


def exchange(full_prev, blk, own_ids, gather_frac: float = 0.25, *,
             skip_empty: bool = True, within=None, _dense=None):
    """Frontier-compressed BSP property exchange.

    `full_prev` is the [N_pad] view every shard agreed on last superstep;
    `blk` is this shard's current [B] block. Entries that differ are the
    communication frontier. Three regimes, chosen per superstep on device
    (the predicate is a collective scalar, so every shard branches the same
    way — the Beamer direction switch, applied to communication volume):

      * empty   — nothing changed anywhere: skip the collective entirely
                  (only when `skip_empty`, the "auto" policy);
      * compact — every shard's change count fits the fixed-size buffer
                  (`cap = compact_cap(B, gather_frac)`): all-gather only
                  (id, value) pairs — stacked into ONE [cap, 2] int32
                  buffer so the whole exchange is a single collective —
                  and scatter them into `full_prev`, moving 2*cap*P
                  elements instead of N_pad — the paper's §4.2 send-buffer
                  aggregation, volume edition;
      * dense   — overflow fallback: the classic full all-gather.

    `within` (optional bool [B]) restricts the exchange to a slice of the
    changed entries — the delta-stepping priority slice: only changes whose
    value sits in the current bucket window ship now. Out-of-window changes
    stay local; the caller must guarantee (and delta-stepping does, because
    values only decrease) that they still differ from `full_prev` when
    their bucket arrives, so they ship then. Stale out-of-window entries in
    the returned view are the caller's contract to mask.

    Returns `(full, gathered_elems)` where `gathered_elems` is the number
    of elements this superstep actually moved (int32, on device). Padded
    slots (own_ids >= num true nodes) are exchanged like any other only if
    they change, which initialized-but-never-written padding never does —
    so poison seeded into padding stays untouched (tested)."""
    n_pad = full_prev.shape[0]
    cap = compact_cap(blk.shape[0], gather_frac)
    p = axis_size(AXIS)
    chg = blk != full_prev[own_ids]
    if within is not None:
        chg = chg & within
    cnt = jnp.sum(chg.astype(jnp.int32))

    def skip(_):
        return full_prev, jnp.int32(0)

    def dense(_):
        # `_dense` overrides the fallback gather when the flat layout is a
        # view of something an all-gather cannot reproduce by concatenation
        # (the [S, B] lane blocks of `exchange_rows`). Under `within` the
        # dense gather publishes out-of-window entries EARLY — harmless:
        # they are fresh (not stale) values, and the slicing contract only
        # forbids serving stale in-window entries.
        return (gather(blk) if _dense is None else _dense()), jnp.int32(n_pad)

    def compact(_):
        order = jnp.argsort(~chg)            # stable: changed slots first
        sel = order[:cap]
        lane_ok = jnp.arange(cap) < cnt
        # out-of-range ids mark the padding lanes; scatter drops them
        ids = jnp.where(lane_ok, own_ids[sel], n_pad)
        vals = blk[sel]
        # one collective for the whole exchange: the (id, value) pairs ride
        # a single [cap, 2] int32 buffer (bool widens, float32 bitcasts —
        # both lossless round trips), halving collective launches without
        # changing the 2*cap*P element volume
        if vals.dtype == jnp.bool_:
            lane = vals.astype(jnp.int32)
        elif vals.dtype == jnp.int32:
            lane = vals
        else:
            lane = jax.lax.bitcast_convert_type(vals, jnp.int32)
        pairs = jax.lax.all_gather(
            jnp.stack([ids, lane], axis=1), AXIS, tiled=True)
        ids_all, vals_all = pairs[:, 0], pairs[:, 1]
        if vals.dtype == jnp.bool_:
            vals_all = vals_all.astype(jnp.bool_)
        elif vals.dtype != jnp.int32:
            vals_all = jax.lax.bitcast_convert_type(vals_all, vals.dtype)
        return full_prev.at[ids_all].set(vals_all), jnp.int32(2 * cap * p)

    if 2 * cap * p >= n_pad:   # compact cannot beat dense at this capacity
        if not skip_empty:
            return dense(None)
        total = psum(cnt)
        return jax.lax.cond(total == 0, skip, dense, 0)

    worst = pmax(cnt)
    fits = worst <= cap
    if not skip_empty:
        return jax.lax.cond(fits, compact, dense, 0)
    total = psum(cnt)
    return jax.lax.cond(
        total == 0, skip,
        lambda _: jax.lax.cond(fits, compact, dense, 0), 0)


def exchange_rows(full_prev, blk, own_ids, gather_frac: float = 0.25, *,
                  skip_empty: bool = True):
    """Batched-lane `exchange`: full_prev [S, N_pad], blk [S, B]. Lanes are
    flattened into one composite id space (lane * N_pad + vertex), so the
    compact buffer is shared across lanes — a lane whose frontier emptied
    donates its capacity to the others."""
    s, n_pad = full_prev.shape
    own2d = (jnp.arange(s, dtype=jnp.int32)[:, None] * n_pad
             + own_ids[None, :]).reshape(-1)
    full, elems = exchange(full_prev.reshape(-1), blk.reshape(-1), own2d,
                           gather_frac, skip_empty=skip_empty,
                           _dense=lambda: gather_rows(blk).reshape(-1))
    return full.reshape(s, n_pad), elems


def pmin(x):
    return jax.lax.pmin(x, AXIS)


def pmax(x):
    return jax.lax.pmax(x, AXIS)


def psum(x):
    return jax.lax.psum(x, AXIS)


def por(x):  # global OR of a local bool scalar
    return jax.lax.psum(x.astype(jnp.int32), AXIS) > 0


def any_global(x):  # global OR over a local bool array
    return por(jnp.any(x))


def min_global(x):  # global min over a local array (delta bucket advance)
    return pmin(jnp.min(x))


def combine_scatter_min(n_pad: int, idx, cand, dtype):
    """Paper §4.2 'communication aggregation': local scatter-min into a
    full-size buffer, then a single min-combine across devices."""
    buf = jnp.full((n_pad,), rt.inf_for(dtype), dtype)
    return pmin(buf.at[idx].min(cand))


def combine_scatter_add(n_pad: int, idx, vals, dtype):
    buf = jnp.zeros((n_pad,), dtype)
    return psum(buf.at[idx].add(vals))


def combine_scatter_max(n_pad: int, idx, cand, dtype):
    buf = jnp.full((n_pad,), -rt.inf_for(dtype) if jnp.dtype(dtype).kind != "b" else False, dtype)
    return pmax(buf.at[idx].max(cand))


def combine_scatter_add_rows(n_pad: int, idx, vals, dtype):
    """Batched-lane combine: vals [S, E] scattered by idx [E] into a
    [S, n_pad] buffer, psum'd across shards (one combine for all lanes)."""
    buf = jnp.zeros((vals.shape[0], n_pad), dtype)
    return psum(buf.at[:, idx].add(vals))


def dist_should_push(frontier_full, threshold_frac: float):
    """Replicated-frontier occupancy test: True when the frontier is sparse
    enough that a push superstep (scatter + global combine) beats the pull
    form (local segment reduction over the gathered arrays). The input is
    a full [N_pad] (or [S, N_pad]) mask every shard holds identically, so
    the predicate is shard-uniform by construction."""
    cap = max(int(frontier_full.size * threshold_frac), 1)
    return jnp.sum(frontier_full.astype(jnp.int32)) <= jnp.int32(cap)


# --------------------------------------------------------------------------
# Distributed BFS (iterateInBFS construct)
# --------------------------------------------------------------------------

def bfs_levels_1d(esrc, edst, evalid, isrc, idst_local, ivalid, own_ids,
                  root, n_pad: int, *, frontier: str = "dense",
                  gather_frac: float = 0.25, direction: str = "auto",
                  threshold_frac: float = 1.0 / 16.0):
    """Level-synchronous distributed BFS over the 1-D partition.

    `frontier` is the Schedule's `dist_frontier` policy for the per-level
    exchange of the level array (dense gather vs changed-entry compact
    buffers); `direction` picks the expansion:

      push — scatter reached-flags over out-edges of frontier vertices and
             combine globally (a psum over [N_pad], the paper's scheme);
      pull — each shard segment-reduces over its *in*-edge partition from
             the replicated level array: no combine collective at all;
      auto — per-level Beamer switch on frontier occupancy against
             `threshold_frac` (shard-uniform: the frontier is replicated).

    Both directions mark exactly the unseen out-neighborhood of the
    frontier, so the choice never changes results. Returns
    (level_blk int32[B], depth, gathered_elems) — the element counter is
    f32 (exact to 2^24; int64 is unavailable under default jax config and
    int32 would wrap on deep large-N runs)."""
    B = own_ids.shape[0]
    level0 = jnp.where(own_ids == root, 0, -1).astype(jnp.int32)
    full0 = gather(level0)

    def cond(state):
        return state[3]

    def body(state):
        level_blk, level_full, cur, _, elems = state

        def push(_):
            src_on = (level_full[esrc] == cur) & evalid
            unseen = level_full[edst] < 0
            reach = combine_scatter_add(
                n_pad, edst, (src_on & unseen).astype(jnp.int32), jnp.int32)
            return reach[own_ids] > 0

        def pull(_):
            on = (level_full[isrc] == cur) & ivalid
            return rt.segment_max(on.astype(jnp.int32), idst_local, B,
                                  sorted_ids=False) > 0

        if direction == "push":
            reach_blk = push(0)
        elif direction == "pull":
            reach_blk = pull(0)
        else:
            reach_blk = jax.lax.cond(
                dist_should_push(level_full == cur, threshold_frac),
                push, pull, 0)
        newly = reach_blk & (level_blk < 0)
        level_blk = jnp.where(newly, cur + 1, level_blk)
        if frontier == "dense":
            level_full = gather(level_blk)
            elems = elems + jnp.int32(n_pad)
        else:
            level_full, step = exchange(level_full, level_blk, own_ids,
                                        gather_frac,
                                        skip_empty=(frontier == "auto"))
            elems = elems + step
        return level_blk, level_full, cur + 1, any_global(newly), elems

    level, _, depth, _, elems = jax.lax.while_loop(
        cond, body,
        (level0, full0, jnp.int32(0), jnp.bool_(True), jnp.float32(n_pad)))
    return level, depth, elems


def bfs_levels_1d_batch(esrc, edst, evalid, isrc, idst_local, ivalid,
                        own_ids, roots, n_pad: int, *,
                        frontier: str = "dense", gather_frac: float = 0.25,
                        direction: str = "auto",
                        threshold_frac: float = 1.0 / 16.0):
    """Batched `bfs_levels_1d`: one BSP loop serves all S roots. State is
    [S, B] per shard / [S, N_pad] replicated; the per-level exchange moves
    all lanes' frontiers through one shared compact buffer. `direction` is
    chosen once per level for the whole batch (the occupancy test sums over
    lanes). Returns (level_blk int32[S, B], depth, gathered_elems); depth
    is the deepest lane's level count — shallower lanes simply see empty
    frontiers at the tail levels, exactly like the local batch engine."""
    B = own_ids.shape[0]
    level0 = jnp.where(own_ids[None, :] == roots[:, None], 0, -1).astype(jnp.int32)
    full0 = gather_rows(level0)

    def cond(state):
        return state[3]

    def body(state):
        level_blk, level_full, cur, _, elems = state

        def push(_):
            src_on = (level_full[:, esrc] == cur) & evalid
            unseen = level_full[:, edst] < 0
            reach = combine_scatter_add_rows(
                n_pad, edst, (src_on & unseen).astype(jnp.int32), jnp.int32)
            return reach[:, own_ids] > 0

        def pull(_):
            on = (level_full[:, isrc] == cur) & ivalid
            return rt.segment_max_batch(on.astype(jnp.int32), idst_local, B,
                                        sorted_ids=False) > 0

        if direction == "push":
            reach_blk = push(0)
        elif direction == "pull":
            reach_blk = pull(0)
        else:
            reach_blk = jax.lax.cond(
                dist_should_push(level_full == cur, threshold_frac),
                push, pull, 0)
        newly = reach_blk & (level_blk < 0)
        level_blk = jnp.where(newly, cur + 1, level_blk)
        if frontier == "dense":
            level_full = gather_rows(level_blk)
            elems = elems + jnp.int32(level_full.size)
        else:
            level_full, step = exchange_rows(level_full, level_blk, own_ids,
                                             gather_frac,
                                             skip_empty=(frontier == "auto"))
            elems = elems + step
        return level_blk, level_full, cur + 1, any_global(newly), elems

    level, _, depth, _, elems = jax.lax.while_loop(
        cond, body,
        (level0, full0, jnp.int32(0), jnp.bool_(True),
         jnp.float32(full0.size)))
    return level, depth, elems


# --------------------------------------------------------------------------
# Distributed triangle counting (wedge pattern over own rows)
# --------------------------------------------------------------------------

def wedge_count_1d(ell_cols, own_ids, edge_key, n_true, chunk: int = 256):
    """Fig. 20 wedge count for the owned vertex block; caller psums."""
    b, d = ell_cols.shape
    chunk = min(chunk, b)
    num_chunks = -(-b // chunk)

    def chunk_count(c, acc):
        ridx = c * chunk + jnp.arange(chunk)
        row_ok = ridx < b
        ridx = jnp.clip(ridx, 0, b - 1)
        rows = ell_cols[ridx]
        vs = own_ids[ridx]
        valid = rows < n_true            # padding slots point past the graph
        u = rows[:, :, None]
        w = rows[:, None, :]
        vv = vs[:, None, None]
        mask = (valid[:, :, None] & valid[:, None, :] & (u < vv) & (w > vv)
                & (vv < n_true) & row_ok[:, None, None])
        q = u.astype(jnp.int32) * n_true + w.astype(jnp.int32)
        pos = jnp.clip(jnp.searchsorted(edge_key, q.ravel()), 0, edge_key.shape[0] - 1)
        hit = (edge_key[pos] == q.ravel()).reshape(q.shape)
        return acc + jnp.sum(jnp.where(mask, hit, False).astype(jnp.int32))

    local = jax.lax.fori_loop(0, num_chunks, chunk_count, jnp.int32(0))
    return psum(local)
