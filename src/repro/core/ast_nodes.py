"""Abstract syntax tree for the StarPlat language (paper §2.4).

Mirrors the paper's node hierarchy: every meaningful non-terminal is an
`ASTNode`; statements and expressions specialize it; `forallStmt` is composed
of an iterator Identifier, a range proc-call, an optional filter Expression,
and a body statement — exactly as described in the paper.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass
class ASTNode:
    line: int = field(default=0, compare=False)


# --------------------------------------------------------------------------
# Types
# --------------------------------------------------------------------------

@dataclass
class TypeNode(ASTNode):
    name: str = ""                      # int|bool|long|float|double|Graph|node|edge|propNode|propEdge|SetN|SetE
    elem: Optional[str] = None          # propNode<int> -> elem='int'; SetN<g> -> elem='g'

    @property
    def is_property(self) -> bool:
        return self.name in ("propNode", "propEdge")

    @property
    def is_set(self) -> bool:
        return self.name in ("SetN", "SetE")


# --------------------------------------------------------------------------
# Expressions
# --------------------------------------------------------------------------

@dataclass
class Expression(ASTNode):
    pass


@dataclass
class Identifier(Expression):
    name: str = ""


@dataclass
class Literal(Expression):
    value: object = None                # int | float | bool
    kind: str = "int"                  # int|float|bool|inf


@dataclass
class MemberAccess(Expression):
    target: Expression = None           # v.dist -> target=Identifier('v')
    member: str = ""


@dataclass
class ProcCall(Expression):
    """g.nodes(), g.neighbors(v), g.attachNodeProperty(...), nodes().filter(...)"""
    target: Optional[Expression] = None  # receiver (Identifier or another ProcCall)
    name: str = ""
    args: List[Expression] = field(default_factory=list)
    kwargs: List[Tuple[str, Expression]] = field(default_factory=list)  # attachNodeProperty(dist=INF)


@dataclass
class BinaryOp(Expression):
    op: str = ""                        # + - * / % < > <= >= == != && ||
    left: Expression = None
    right: Expression = None


@dataclass
class UnaryOp(Expression):
    op: str = ""                        # ! -
    operand: Expression = None


@dataclass
class MinMaxExpr(Expression):
    """Min(a, b) / Max(a, b) inside a multiple-assignment (paper §2.3.4)."""
    kind: str = "Min"
    args: List[Expression] = field(default_factory=list)


# --------------------------------------------------------------------------
# Statements
# --------------------------------------------------------------------------

@dataclass
class Statement(ASTNode):
    pass


@dataclass
class BlockStmt(Statement):
    stmts: List[Statement] = field(default_factory=list)


@dataclass
class DeclarationStmt(Statement):
    ty: TypeNode = None
    name: str = ""
    init: Optional[Expression] = None


@dataclass
class AssignmentStmt(Statement):
    lhs: Expression = None               # Identifier or MemberAccess
    rhs: Expression = None
    reduce_op: Optional[str] = None      # '+' for +=, '*' for *=, '&&', '||' (paper Table 1)


@dataclass
class MultiAssignmentStmt(Statement):
    """<nbr.dist, nbr.modified> = <Min(nbr.dist, v.dist + e.weight), True>;
    Translates to a synchronized conditional update (paper §2.3.4)."""
    targets: List[Expression] = field(default_factory=list)
    values: List[Expression] = field(default_factory=list)


@dataclass
class ForallStmt(Statement):
    iterator: Identifier = None
    range_call: ProcCall = None          # g.nodes() / g.neighbors(v) / g.nodes_to(v)
    filter_expr: Optional[Expression] = None
    body: BlockStmt = None
    parallel: bool = True                # forall vs for


@dataclass
class FixedPointStmt(Statement):
    var: str = ""                        # finished
    conv_expr: Expression = None         # !modified
    body: BlockStmt = None


@dataclass
class DoWhileStmt(Statement):
    body: BlockStmt = None
    cond: Expression = None


@dataclass
class WhileStmt(Statement):
    cond: Expression = None
    body: BlockStmt = None


@dataclass
class IfStmt(Statement):
    cond: Expression = None
    then_body: BlockStmt = None
    else_body: Optional[BlockStmt] = None


@dataclass
class IterateInBFSStmt(Statement):
    iterator: Identifier = None
    root: Expression = None
    filter_expr: Optional[Expression] = None
    body: BlockStmt = None
    reverse: Optional["IterateInReverseStmt"] = None


@dataclass
class IterateInReverseStmt(Statement):
    filter_expr: Optional[Expression] = None   # (v != src)
    body: BlockStmt = None


@dataclass
class ProcCallStmt(Statement):
    call: ProcCall = None


@dataclass
class ReturnStmt(Statement):
    value: Optional[Expression] = None


# --------------------------------------------------------------------------
# Top level
# --------------------------------------------------------------------------

@dataclass
class FormalParam(ASTNode):
    ty: TypeNode = None
    name: str = ""


@dataclass
class Function(ASTNode):
    name: str = ""
    params: List[FormalParam] = field(default_factory=list)
    body: BlockStmt = None


@dataclass
class Program(ASTNode):
    functions: List[Function] = field(default_factory=list)


def walk(node, fn):
    """Pre-order traversal applying fn to every ASTNode."""
    if node is None:
        return
    if isinstance(node, ASTNode):
        fn(node)
        for f in dataclasses.fields(node):
            walk(getattr(node, f.name), fn)
    elif isinstance(node, (list, tuple)):
        for x in node:
            walk(x, fn)
