"""Semantic analysis: symbol table + type/role resolution (paper frontend pass).

The paper populates AST metadata "during an additional pass through the
already built AST" and performs "a rudimentary analysis of the AST" for the
CUDA backend (local vs transferred variables). This module is that pass:
it classifies every identifier (graph / node param / property / scalar /
set / iterator / edge var), resolves bare property names inside filters
(`filter(modified == True)` → iterator.modified), and records which
properties each loop reads and writes — the information the backends need
to place all-gathers (MPI analogue) and kernel I/O (CUDA analogue).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from . import ast_nodes as A

PRIMS = {"int", "bool", "long", "float", "double"}

_DTYPE = {"int": "int32", "long": "int32", "bool": "bool",
          "float": "float32", "double": "float64"}


class SemanticError(Exception):
    pass


def _quote(src: Optional[str], line: int) -> str:
    """The 1-based source line, for inclusion in error messages."""
    if not src or line <= 0:
        return ""
    lines = src.splitlines()
    return lines[line - 1].strip() if line <= len(lines) else ""


@dataclass
class Symbol:
    name: str
    kind: str            # graph|node_param|prop_node|prop_edge|scalar|set_n|set_e|iter_vertex|iter_nbr|iter_set|edge_var|iter_bfs
    dtype: Optional[str] = None      # jnp dtype string for props/scalars
    decl_depth: int = 0              # 0 = function scope
    param: bool = False
    # iterators
    source_iter: Optional[str] = None   # for iter_nbr: the vertex it iterates around
    direction: Optional[str] = None     # 'out' (neighbors) | 'in' (nodes_to)
    # edge vars: the (src_iter, dst_iter) it connects
    edge_between: Optional[tuple] = None


@dataclass
class FunctionInfo:
    name: str
    symbols: Dict[str, Symbol] = field(default_factory=dict)
    graph: Optional[str] = None
    node_props: Dict[str, str] = field(default_factory=dict)   # name -> dtype
    edge_props: Dict[str, str] = field(default_factory=dict)
    params: List[Symbol] = field(default_factory=list)
    returns: Optional[str] = None


def dtype_of(ty: A.TypeNode) -> str:
    base = ty.elem if ty.is_property else ty.name
    if base not in _DTYPE:
        raise SemanticError(f"unsupported element type {base!r}")
    return _DTYPE[base]


class Analyzer:
    """Single-function analyzer. Walks the AST, building the symbol table and
    annotating nodes in place (adds `.sym`, `.resolved` attributes)."""

    def __init__(self, fn: A.Function, src: Optional[str] = None):
        self.fn = fn
        self.src = src
        self.info = FunctionInfo(name=fn.name)
        self.loop_depth = 0

    def err(self, line: int, msg: str):
        """Raise a SemanticError quoting the offending source line."""
        where = f"line {line}: " if line else ""
        quoted = _quote(self.src, line)
        suffix = f"\n    | {quoted}" if quoted else ""
        raise SemanticError(f"{where}{msg}{suffix}")

    def run(self) -> FunctionInfo:
        info = self.info
        for p in self.fn.params:
            sym = self._declare_param(p)
            info.params.append(sym)
        if info.graph is None:
            raise SemanticError(f"{self.fn.name}: no Graph parameter")
        self._block(self.fn.body)
        return info

    # ---- declarations ------------------------------------------------------
    def _declare_param(self, p: A.FormalParam) -> Symbol:
        ty = p.ty
        if ty.name == "Graph":
            sym = Symbol(p.name, "graph", param=True)
            self.info.graph = p.name
        elif ty.name == "node":
            sym = Symbol(p.name, "node_param", param=True)
        elif ty.name == "edge":
            sym = Symbol(p.name, "edge_var", param=True)
        elif ty.name == "propNode":
            sym = Symbol(p.name, "prop_node", dtype=dtype_of(ty), param=True)
            self.info.node_props[p.name] = sym.dtype
        elif ty.name == "propEdge":
            sym = Symbol(p.name, "prop_edge", dtype=dtype_of(ty), param=True)
            self.info.edge_props[p.name] = sym.dtype
        elif ty.name == "SetN":
            sym = Symbol(p.name, "set_n", param=True)
        elif ty.name == "SetE":
            sym = Symbol(p.name, "set_e", param=True)
        elif ty.name in PRIMS:
            sym = Symbol(p.name, "scalar", dtype=_DTYPE[ty.name], param=True)
        else:
            raise SemanticError(f"bad param type {ty.name}")
        self.info.symbols[p.name] = sym
        return sym

    def _declare_local(self, d: A.DeclarationStmt) -> Symbol:
        ty = d.ty
        if ty.name == "propNode":
            sym = Symbol(d.name, "prop_node", dtype=dtype_of(ty),
                         decl_depth=self.loop_depth)
            self.info.node_props[d.name] = sym.dtype
        elif ty.name == "propEdge":
            sym = Symbol(d.name, "prop_edge", dtype=dtype_of(ty),
                         decl_depth=self.loop_depth)
            self.info.edge_props[d.name] = sym.dtype
        elif ty.name == "edge":
            sym = Symbol(d.name, "edge_var", decl_depth=self.loop_depth)
        elif ty.name in PRIMS:
            sym = Symbol(d.name, "scalar", dtype=_DTYPE[ty.name],
                         decl_depth=self.loop_depth)
        else:
            self.err(d.line, f"cannot declare {ty.name} locally")
        self.info.symbols[d.name] = sym
        return sym

    # ---- traversal -----------------------------------------------------------
    def _block(self, b: A.BlockStmt):
        for s in b.stmts:
            self._stmt(s)

    def _stmt(self, s: A.Statement):
        if isinstance(s, A.DeclarationStmt):
            sym = self._declare_local(s)
            if isinstance(s.init, A.ProcCall) and s.init.name == "getEdge":
                args = s.init.args
                sym.edge_between = (self._ident_name(args[0]),
                                    self._ident_name(args[1]))
            elif s.init is not None:
                self._expr(s.init)
            s.sym = sym
        elif isinstance(s, A.AssignmentStmt):
            self._expr(s.lhs)
            self._expr(s.rhs)
        elif isinstance(s, A.MultiAssignmentStmt):
            for t in s.targets:
                self._expr(t)
            for v in s.values:
                self._expr(v)
        elif isinstance(s, A.ForallStmt):
            self._forall(s)
        elif isinstance(s, A.FixedPointStmt):
            # fixedPoint until (finished: !modified): conv prop must be bool
            self.info.symbols[s.var] = self.info.symbols.get(
                s.var, Symbol(s.var, "scalar", dtype="bool"))
            self._expr(s.conv_expr)
            self._block(s.body)
        elif isinstance(s, A.DoWhileStmt):
            self._block(s.body)
            self._expr(s.cond)
        elif isinstance(s, A.WhileStmt):
            self._expr(s.cond)
            self._block(s.body)
        elif isinstance(s, A.IfStmt):
            self._expr(s.cond)
            self._block(s.then_body)
            if s.else_body:
                self._block(s.else_body)
        elif isinstance(s, A.IterateInBFSStmt):
            self._bfs(s)
        elif isinstance(s, A.ProcCallStmt):
            self._expr(s.call)
        elif isinstance(s, A.ReturnStmt):
            if s.value:
                self._expr(s.value)
        elif isinstance(s, A.BlockStmt):
            self._block(s)
        else:
            raise SemanticError(f"unhandled statement {type(s).__name__}")

    def _ident_name(self, e: A.Expression) -> str:
        if isinstance(e, A.Identifier):
            return e.name
        self.err(e.line, "expected identifier")

    def _forall(self, s: A.ForallStmt):
        rng = s.range_call
        it_name = s.iterator.name
        if isinstance(rng, A.ProcCall):
            if rng.name == "nodes":
                sym = Symbol(it_name, "iter_vertex", decl_depth=self.loop_depth + 1)
            elif rng.name in ("neighbors", "nodesTo", "nodes_to", "nodesFrom", "nodes_from"):
                src = self._ident_name(rng.args[0])
                direction = "out" if rng.name in ("neighbors", "nodesFrom", "nodes_from") else "in"
                sym = Symbol(it_name, "iter_nbr", decl_depth=self.loop_depth + 1,
                             source_iter=src, direction=direction)
            else:
                self.err(s.line, f"unknown range {rng.name}()")
        elif isinstance(rng, A.Identifier):
            base = self.info.symbols.get(rng.name)
            if base is None or base.kind not in ("set_n", "set_e"):
                self.err(s.line, f"cannot iterate over {rng.name}")
            sym = Symbol(it_name, "iter_set", decl_depth=self.loop_depth + 1,
                         source_iter=rng.name)
        else:
            self.err(s.line, "bad forall range")
        saved = self.info.symbols.get(it_name)
        self.info.symbols[it_name] = sym
        s.iter_sym = sym
        self.loop_depth += 1
        if s.filter_expr is not None:
            self._expr(s.filter_expr, filter_iter=it_name)
        self._block(s.body)
        self.loop_depth -= 1
        if saved is not None:
            self.info.symbols[it_name] = saved

    def _bfs(self, s: A.IterateInBFSStmt):
        it_name = s.iterator.name
        sym = Symbol(it_name, "iter_bfs", decl_depth=self.loop_depth + 1)
        self.info.symbols[it_name] = sym
        s.iter_sym = sym
        self._expr(s.root)
        self.loop_depth += 1
        self._block(s.body)
        if s.reverse is not None:
            if s.reverse.filter_expr is not None:
                self._expr(s.reverse.filter_expr, filter_iter=it_name)
            self._block(s.reverse.body)
        self.loop_depth -= 1

    # ---- expressions -----------------------------------------------------------
    def _expr(self, e: A.Expression, filter_iter: Optional[str] = None):
        """Annotates identifiers with `.sym`. Inside a filter, a bare property
        name is sugar for `<iterator>.<prop>` (paper Fig. 3/4 usage)."""
        if isinstance(e, A.Identifier):
            sym = self.info.symbols.get(e.name)
            if sym is None:
                self.err(e.line, f"undefined {e.name!r}")
            e.sym = sym
            if filter_iter and sym.kind in ("prop_node", "prop_edge"):
                e.filter_sugar_iter = filter_iter   # means filter_iter.<prop>
        elif isinstance(e, A.MemberAccess):
            self._expr(e.target, filter_iter)
        elif isinstance(e, A.BinaryOp):
            self._expr(e.left, filter_iter)
            self._expr(e.right, filter_iter)
        elif isinstance(e, A.UnaryOp):
            self._expr(e.operand, filter_iter)
        elif isinstance(e, A.ProcCall):
            if e.target is not None:
                self._expr(e.target, filter_iter)
            for a in e.args:
                self._expr(a, filter_iter)
            for _, v in e.kwargs:
                self._expr(v, filter_iter)
        elif isinstance(e, A.MinMaxExpr):
            for a in e.args:
                self._expr(a, filter_iter)
        elif isinstance(e, A.Literal):
            pass
        else:
            raise SemanticError(f"unhandled expression {type(e).__name__}")


def analyze(prog: A.Program) -> Dict[str, FunctionInfo]:
    src = getattr(prog, "src_text", None)
    return {fn.name: Analyzer(fn, src=src).run() for fn in prog.functions}
