// Paper Fig. 20: triangle counting via the node-iterator wedge pattern.
function Compute_TC(Graph g) {
    int triangle_count = 0;
    forall(v in g.nodes()) {
        forall(u in g.neighbors(v).filter(u < v)) {
            forall(w in g.neighbors(v).filter(w > v)) {
                if (g.is_an_edge(u, w)) {
                    triangle_count += 1;
                }
            }
        }
    }
}
