// SSSP, pull variant: each vertex gathers over in-neighbors (nodesTo) that
// changed last round. Same fixed point as the push form; the backends map it
// to segment reductions instead of scatter combines.
function Compute_SSSP(Graph g, propNode<int> dist, propNode<bool> modified, node src) {
    g.attachNodeProperty(dist = INF, modified = False);
    src.modified = True;
    src.dist = 0;
    bool finished = False;
    fixedPoint until (finished : !modified) {
        forall(v in g.nodes()) {
            forall(nbr in g.nodesTo(v).filter(modified == True)) {
                edge e = g.getEdge(nbr, v);
                <v.dist, v.modified> = <Min(v.dist, nbr.dist + e.weight), True>;
            }
        }
    }
}
