// Beyond-paper program: personalized PageRank over a source set. Each
// source's restart vector is an indicator on that source; the per-source
// do-while runs on the batched [B, N] lanes (one sweep serves B
// personalization vectors), and the shared `ppr` output accumulates the
// lane results — the aggregate PPR of the seed set (PPR is linear in the
// restart vector, so per-user rows are recoverable by singleton sets).
function Compute_PPR(Graph g, float beta, float delta, int maxIter, propNode<float> ppr, SetN<g> sourceSet) {
    g.attachNodeProperty(ppr = 0);
    forall(src in sourceSet) {
        propNode<float> rank;
        propNode<float> rank_nxt;
        propNode<float> restart;
        g.attachNodeProperty(rank = 0, rank_nxt = 0, restart = 0);
        src.restart = 1;
        src.rank = 1;
        int iterCount = 0;
        float diff = 0.0;
        do {
            diff = 0.0;
            forall(v in g.nodes()) {
                float sum = 0.0;
                forall(nbr in g.nodesTo(v)) {
                    sum = sum + nbr.rank / g.count_outNbrs(nbr);
                }
                float newRank = (1 - delta) * v.restart + delta * sum;
                diff += abs(newRank - v.rank);
                v.rank_nxt = newRank;
            }
            rank = rank_nxt;
            iterCount++;
        } while ((diff > beta) && (iterCount < maxIter));
        forall(v in g.nodes()) {
            v.ppr += v.rank;
        }
    }
}
