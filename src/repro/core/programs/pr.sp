// Paper Fig. 18: PageRank (pull over in-neighbors, L1 convergence test).
function Compute_PR(Graph g, float beta, float delta, int maxIter, propNode<float> pageRank) {
    float numNodes = g.num_nodes();
    propNode<float> pageRank_nxt;
    g.attachNodeProperty(pageRank = 1 / numNodes);
    int iterCount = 0;
    float diff = 0.0;
    do {
        diff = 0.0;
        forall(v in g.nodes()) {
            float sum = 0.0;
            forall(nbr in g.nodesTo(v)) {
                sum = sum + nbr.pageRank / g.count_outNbrs(nbr);
            }
            float newPageRank = (1 - delta) / numNodes + delta * sum;
            diff += abs(newPageRank - v.pageRank);
            v.pageRank_nxt = newPageRank;
        }
        pageRank = pageRank_nxt;
        iterCount++;
    } while ((diff > beta) && (iterCount < maxIter));
}
