// Paper Fig. 3: SSSP, push variant (Bellman-Ford with a modified-frontier).
function Compute_SSSP(Graph g, propNode<int> dist, propNode<bool> modified, node src) {
    g.attachNodeProperty(dist = INF, modified = False);
    src.modified = True;
    src.dist = 0;
    bool finished = False;
    fixedPoint until (finished : !modified) {
        forall(v in g.nodes().filter(modified == True)) {
            forall(nbr in g.neighbors(v)) {
                edge e = g.getEdge(v, nbr);
                <nbr.dist, nbr.modified> = <Min(nbr.dist, v.dist + e.weight), True>;
            }
        }
    }
}
