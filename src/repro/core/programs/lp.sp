// Beyond-paper program: label-propagation community detection by min-label
// relaxation along edge direction, pull AND push in one fixedPoint sweep —
// both sides lower to the frontier-relax hybrid (the unweighted Min relax),
// so the schedule's direction/threshold knobs apply to each.
function Compute_LP(Graph g, propNode<int> label, propNode<bool> modified) {
    g.attachNodeProperty(label = 0, modified = True);
    forall(v in g.nodes()) {
        v.label = v;
    }
    bool finished = False;
    fixedPoint until (finished : !modified) {
        forall(v in g.nodes()) {
            forall(nbr in g.nodesTo(v).filter(modified == True)) {
                <v.label, v.modified> = <Min(v.label, nbr.label), True>;
            }
        }
        forall(v in g.nodes().filter(modified == True)) {
            forall(nbr in g.neighbors(v)) {
                <nbr.label, nbr.modified> = <Min(nbr.label, v.label), True>;
            }
        }
    }
}
