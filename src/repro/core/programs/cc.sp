// Beyond-paper program: connected components by min-label propagation.
// Shows the DSL is not hard-wired to the four published algorithms.
function Compute_CC(Graph g, propNode<int> comp, propNode<bool> modified) {
    g.attachNodeProperty(comp = 0, modified = True);
    forall(v in g.nodes()) {
        v.comp = v;
    }
    bool finished = False;
    fixedPoint until (finished : !modified) {
        forall(v in g.nodes()) {
            forall(nbr in g.nodesTo(v).filter(modified == True)) {
                <v.comp, v.modified> = <Min(v.comp, nbr.comp), True>;
            }
        }
    }
}
