// Beyond-paper program: k-core decomposition by iterative degree peeling
// (directed: out-degree within the surviving subgraph). core == 1 marks
// vertices still in the k-core; each sweep peels every survivor whose
// surviving out-degree dropped below k, until a sweep peels nothing.
// NOTE: peeling is non-monotone over graph updates (an edge deletion can
// only shrink the core, an insertion only grow it, but the converged
// `core` flags cannot be warm-started soundly) — the analysis layer flags
// this program refresh-unsafe (SP209) and `bound.refresh` rejects it.
function Compute_KCore(Graph g, int k, propNode<int> core) {
    g.attachNodeProperty(core = 1);
    int changed = 1;
    while (changed > 0) {
        changed = 0;
        forall(v in g.nodes().filter(core == 1)) {
            int deg = 0;
            forall(nbr in g.neighbors(v).filter(core == 1)) {
                deg = deg + 1;
            }
            if (deg < k) {
                v.core = 0;
                changed += 1;
            }
        }
    }
}
