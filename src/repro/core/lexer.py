"""Tokenizer for the StarPlat language."""
from __future__ import annotations

import dataclasses
from typing import List

KEYWORDS = {
    "function", "forall", "for", "in", "filter", "fixedPoint", "until",
    "iterateInBFS", "iterateInReverse", "from", "do", "while", "if", "else",
    "return", "True", "False", "INF", "Min", "Max",
    "Graph", "node", "edge", "propNode", "propEdge", "SetN", "SetE",
    "int", "bool", "long", "float", "double",
}

# longest-match first
SYMBOLS = [
    "&&=", "||=", "<=", ">=", "==", "!=", "&&", "||", "+=", "-=", "*=", "/=",
    "++", "--", "(", ")", "{", "}", "[", "]", "<", ">", "=", "+", "-", "*",
    "/", "%", ".", ",", ";", ":", "!",
]


@dataclasses.dataclass
class Token:
    kind: str      # 'kw' | 'id' | 'int' | 'float' | 'sym' | 'eof'
    value: str
    line: int

    def __repr__(self):
        return f"{self.kind}:{self.value}"


class LexError(Exception):
    pass


def tokenize(src: str) -> List[Token]:
    toks: List[Token] = []
    i, line, n = 0, 1, len(src)
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        if src.startswith("//", i):
            j = src.find("\n", i)
            i = n if j < 0 else j
            continue
        if src.startswith("/*", i):
            j = src.find("*/", i)
            if j < 0:
                raise LexError(f"line {line}: unterminated comment")
            line += src.count("\n", i, j)
            i = j + 2
            continue
        if c.isalpha() or c == "_":
            j = i
            while j < n and (src[j].isalnum() or src[j] == "_"):
                j += 1
            word = src[i:j]
            toks.append(Token("kw" if word in KEYWORDS else "id", word, line))
            i = j
            continue
        if c.isdigit():
            j = i
            while j < n and src[j].isdigit():
                j += 1
            if j < n and src[j] == "." and j + 1 < n and src[j + 1].isdigit():
                j += 1
                while j < n and src[j].isdigit():
                    j += 1
                if j < n and src[j] in "eE":
                    j += 1
                    if j < n and src[j] in "+-":
                        j += 1
                    while j < n and src[j].isdigit():
                        j += 1
                toks.append(Token("float", src[i:j], line))
            else:
                toks.append(Token("int", src[i:j], line))
            i = j
            continue
        for sym in SYMBOLS:
            if src.startswith(sym, i):
                toks.append(Token("sym", sym, line))
                i += len(sym)
                break
        else:
            raise LexError(f"line {line}: unexpected character {c!r}")
    toks.append(Token("eof", "", line))
    return toks
