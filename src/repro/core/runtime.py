"""Runtime library for StarPlat-generated JAX code.

These are the "batteries included" utility functions of the paper (§2),
implemented TPU-natively: every primitive is shape-static, mask-based, and
free of data-dependent control flow, so one compiled program serves a graph
regardless of frontier contents.

Race handling (the paper's atomics) is structural here: `scatter_min` uses
XLA's associative scatter-min combinator (deterministic, no CAS needed) and
pull-reductions use sorted segment ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..graph.csr import CSRGraph, ENGINE, INF_I32

INF = jnp.int32(INF_I32)


# --- scatter / segment combine (the Min/Max construct, reductions) -----------

def scatter_min(current: jax.Array, idx: jax.Array, cand: jax.Array) -> jax.Array:
    """min-combine `cand` into `current` at positions `idx` (push relax)."""
    return current.at[idx].min(cand)


def scatter_max(current, idx, cand):
    return current.at[idx].max(cand)


def scatter_add(current, idx, vals):
    return current.at[idx].add(vals)


def scatter_or(current, idx, vals):
    return current.at[idx].max(vals)  # bool max == or


def segment_sum(vals, seg_ids, num_segments, sorted_ids=True):
    return jax.ops.segment_sum(vals, seg_ids, num_segments=num_segments,
                               indices_are_sorted=sorted_ids)


def segment_min(vals, seg_ids, num_segments, sorted_ids=True):
    return jax.ops.segment_min(vals, seg_ids, num_segments=num_segments,
                               indices_are_sorted=sorted_ids)


def segment_max(vals, seg_ids, num_segments, sorted_ids=True):
    return jax.ops.segment_max(vals, seg_ids, num_segments=num_segments,
                               indices_are_sorted=sorted_ids)


# --- batched (multi-source) scatter / segment combines -----------------------
#
# The batched engine carries per-source properties as [B, N] matrices; the
# per-edge values they induce are [B, E]. Segment ops segment over the
# LEADING axis, so the batched variants run on the [E, B] transpose — one
# fused segmented reduction with B lanes, not B reductions.

def _seg_batch(op, vals, seg_ids, num_segments, sorted_ids):
    return op(jnp.swapaxes(vals, 0, 1), seg_ids, num_segments=num_segments,
              indices_are_sorted=sorted_ids).swapaxes(0, 1)


def segment_sum_batch(vals, seg_ids, num_segments, sorted_ids=True):
    """vals [B, E], seg_ids [E] → [B, num_segments]."""
    return _seg_batch(jax.ops.segment_sum, vals, seg_ids, num_segments, sorted_ids)


def segment_min_batch(vals, seg_ids, num_segments, sorted_ids=True):
    return _seg_batch(jax.ops.segment_min, vals, seg_ids, num_segments, sorted_ids)


def segment_max_batch(vals, seg_ids, num_segments, sorted_ids=True):
    return _seg_batch(jax.ops.segment_max, vals, seg_ids, num_segments, sorted_ids)


def scatter_min_rows(current, idx, cand):
    """Row-wise scatter-min: current [B, N], idx [E], cand [B, E]."""
    return current.at[:, idx].min(cand)


def scatter_add_rows(current, idx, vals):
    return current.at[:, idx].add(vals)


def scatter_or_rows(current, idx, vals):
    return current.at[:, idx].max(vals)


# --- graph queries ------------------------------------------------------------

def _edge_key_fits_i32(n: int) -> bool:
    return n * n < 2**31


def _is_an_edge_keyed(g: CSRGraph, u, w):
    """Fast path: binary search over the cached sorted (src·N + dst) int32
    key — only valid while N² fits int32."""
    key = g.edge_key
    q = u.astype(jnp.int32) * g.num_nodes + w.astype(jnp.int32)
    pos = jnp.searchsorted(key, q)
    pos = jnp.clip(pos, 0, key.shape[0] - 1)
    return key[pos] == q


def _is_an_edge_rowsearch(g: CSRGraph, u, w):
    """Large-graph path (N² ≥ 2³¹): per-query binary search of `w` inside
    CSR row `u` — a fixed-iteration lower_bound over indices[indptr[u] :
    indptr[u+1]], so no composite key (and no int64) is ever formed."""
    e = g.num_edges
    n = g.num_nodes
    uc = jnp.clip(u, 0, n - 1)
    lo = g.indptr[uc].astype(jnp.int32)
    row_end = g.indptr[uc + 1].astype(jnp.int32)
    lo = jnp.broadcast_to(lo, jnp.broadcast_shapes(lo.shape, jnp.shape(w)))
    hi = jnp.broadcast_to(row_end, lo.shape)
    steps = max(int(g.max_out_degree), 1).bit_length() + 1

    def body(_, state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi) >> 1
        v = g.indices[jnp.clip(mid, 0, e - 1)]
        go_right = v < w
        return (jnp.where(active & go_right, mid + 1, lo),
                jnp.where(active & ~go_right, mid, hi))

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return ((lo < row_end) & (g.indices[jnp.clip(lo, 0, e - 1)] == w)
            & (u >= 0) & (u < n))   # match the keyed path on out-of-range u


def is_an_edge(g: CSRGraph, u: jax.Array, w: jax.Array) -> jax.Array:
    """Membership test — the paper's `is_an_edge` with sorted-CSR binary
    search (§5.1 TC). Small graphs search the cached composite int32 key;
    graphs whose N² would overflow int32 fall back to a row-range binary
    search (no key materialized). Broadcasts over u/w."""
    if g.num_edges == 0:
        return jnp.zeros(jnp.broadcast_shapes(jnp.shape(u), jnp.shape(w)),
                         jnp.bool_)
    u = jnp.asarray(u)
    w = jnp.asarray(w)
    if _edge_key_fits_i32(g.num_nodes):
        return _is_an_edge_keyed(g, u, w)
    return _is_an_edge_rowsearch(g, u, w)


# --- frontier engine (direction-optimizing traversal) --------------------------
#
# The paper gets performance per backend by restructuring the same IR; the
# TPU restructuring here is Beamer-style direction optimization with
# shape-static state: the frontier is a dense bool[N] threaded through the
# while_loop carry (the fixedPoint conv property IS the frontier), and each
# step picks push (scatter from frontier sources) or pull (gather/segment
# over in-edges) via an on-device occupancy test — both branches compute the
# identical relaxation, so lax.cond is exact, not approximate.

def frontier_size(frontier: jax.Array) -> jax.Array:
    """On-device occupancy count of a dense bool frontier."""
    return jnp.sum(frontier.astype(jnp.int32))


def frontier_should_push(frontier: jax.Array, n: int,
                         threshold_frac: float | None = None,
                         direction: str = "auto") -> jax.Array:
    """True when the frontier is sparse enough that push (scatter from the
    few active sources) beats a pull sweep. The knob is
    `Schedule.push_threshold_frac` (fraction of N) — generated code passes
    it explicitly; `None` falls back to the deprecated `ENGINE` shim. A
    pinned `direction` short-circuits the occupancy test."""
    if direction == "push":
        return jnp.bool_(True)
    if direction == "pull":
        return jnp.bool_(False)
    frac = ENGINE.push_threshold_frac if threshold_frac is None else threshold_frac
    return frontier_size(frontier) <= jnp.int32(max(int(n * frac), 1))


def relax_minplus_hybrid(g: CSRGraph, dist: jax.Array,
                         frontier: jax.Array | None = None,
                         threshold_frac: float | None = None,
                         direction: str = "auto",
                         weighted: bool = True) -> jax.Array:
    """One SSSP/min-plus relaxation restricted to `frontier` sources, with
    push/pull direction chosen on-device.

      push: scatter-min dist[u]+w over out-edges of frontier vertices
      pull: per-vertex min over in-edges, sources masked to the frontier

    Both compute dist'[v] = min(dist[v], min_{(u,v)∈E, frontier[u]} dist[u]+w)
    exactly, so the switch never changes results. `frontier=None` is a dense
    sweep (every vertex contributes). `weighted=False` drops the `+ w` term
    (the candidate is just dist[u]) — the unweighted Min relax of connected
    components, which takes the same push/pull machinery.

    NOTE: this push/pull relaxation pair exists in four places — here, the
    batched form below (`relax_minplus_hybrid_batch`), the kernel-backed
    ops (kernels/ell_spmv/ops.py `_relax_push`/`_relax_sliced_pull`), and
    inline in the local backend's generated source
    (local_jax.emit_relax_hybrid, kept inline so the lowering stays
    inspectable). A semantic change to any copy must be applied to all."""
    n = g.num_nodes

    def push(d):
        cand = d[g.edge_src] + g.weights if weighted else d[g.edge_src]
        if frontier is not None:
            cand = jnp.where(frontier[g.edge_src], cand, INF)
        return scatter_min(d, g.indices, cand)

    def pull(d):
        cand = d[g.rev_indices] + g.rev_weights if weighted \
            else d[g.rev_indices]
        if frontier is not None:
            cand = jnp.where(frontier[g.rev_indices], cand, INF)
        return jnp.minimum(d, segment_min(cand, g.rev_edge_dst, n))

    if frontier is None:
        return pull(dist)
    if direction == "push":
        return push(dist)
    if direction == "pull":
        return pull(dist)
    return jax.lax.cond(frontier_should_push(frontier, n, threshold_frac),
                        push, pull, dist)


# --- delta-stepping (priority-bucketed) relaxation -----------------------------
#
# Schedule.priority == "delta" restricts each fixedPoint sweep to the
# vertices whose tentative value falls below the current bucket boundary
# (k + 1) * delta_bucket — Meyer/Sanders delta-stepping expressed over the
# same frontier machinery. Min relaxation is monotone, so any frontier
# restriction that eventually processes every modified vertex reaches the
# identical fixed point; the payoff is per-sweep WORK: a settled bucket's
# frontier is tiny, and the compact path below relaxes only its out-rows
# (O(cap * max_deg) via a padded ELL gather) instead of sweeping all E edges.

def relax_minplus_delta(g: CSRGraph, dist: jax.Array, frontier: jax.Array,
                        ell=None, cap: int | None = None,
                        threshold_frac: float | None = None,
                        direction: str = "auto",
                        weighted: bool = True) -> jax.Array:
    """One bucketed min relaxation over `frontier` sources (the caller has
    already restricted the frontier to the current delta bucket).

    When a padded forward ELL view and a static `cap` are supplied and the
    frontier fits, the compact path runs: frontier ids are compacted into a
    [cap] buffer by an O(N) cumsum (no sort), their padded out-rows
    gathered, and the candidates scatter-min'd. Pad cells (col == n) and
    unused slots are masked to INF and scattered out of bounds, which XLA
    drops. Overflowing frontiers — and `ell=None` (hub-heavy graphs where
    max_deg makes the ELL view uneconomical) — fall back to the dense
    hybrid sweep, which computes the same relaxation."""
    if ell is None or cap is None or cap <= 0:
        return relax_minplus_hybrid(g, dist, frontier, threshold_frac,
                                    direction, weighted)
    n = g.num_nodes
    cap = int(min(cap, n))

    def compact(d):
        pos = jnp.cumsum(frontier.astype(jnp.int32)) - 1
        slot = jnp.where(frontier & (pos < cap), pos, cap)   # cap = trash slot
        ids = jnp.full((cap + 1,), n, jnp.int32).at[slot].set(
            jnp.arange(n, dtype=jnp.int32))[:cap]
        row_ok = ids < n
        idc = jnp.where(row_ok, ids, 0)
        cols = ell.cols[idc]                                  # [cap, D]
        valid = row_ok[:, None] & (cols < n)
        src = d[idc][:, None]
        cand = src + ell.wts[idc] if weighted \
            else jnp.broadcast_to(src, cols.shape)
        cand = jnp.where(valid, cand, INF)
        tgt = jnp.where(valid, cols, n)                       # n → dropped
        return d.at[tgt.ravel()].min(cand.ravel())

    def dense(d):
        return relax_minplus_hybrid(g, d, frontier, threshold_frac,
                                    direction, weighted)

    return jax.lax.cond(frontier_size(frontier) <= jnp.int32(cap),
                        compact, dense, dist)


# --- BFS (iterateInBFS construct) ----------------------------------------------

def bfs_levels(g: CSRGraph, root, max_levels: int | None = None, *,
               threshold_frac: float | None = None,
               direction: str = "auto"):
    """Level-synchronous BFS with direction-optimizing expansion. Dense
    frontier: level[v] = -1 until visited; frontier = (level == cur).

      push (small frontier): scatter-or over out-edges of frontier vertices
      pull (large frontier): segment-or over in-edges from frontier sources

    Both mark exactly the unseen out-neighborhood of the frontier, so the
    switch is result-invariant. Returns (level[int32 N], num_levels)."""
    n = g.num_nodes
    level0 = jnp.full((n,), -1, jnp.int32).at[root].set(0)

    def cond(state):
        _, cur, changed = state
        return changed

    def body(state):
        level, cur, _ = state
        frontier = level == cur

        def push(fr):
            hit = scatter_or(jnp.zeros((n,), jnp.bool_), g.indices,
                             fr[g.edge_src])
            return hit

        def pull(fr):
            return segment_max(fr[g.rev_indices].astype(jnp.int32),
                               g.rev_edge_dst, n) > 0

        if direction == "push":
            reach = push(frontier)
        elif direction == "pull":
            reach = pull(frontier)
        else:
            reach = jax.lax.cond(
                frontier_should_push(frontier, n, threshold_frac),
                push, pull, frontier)
        newly = reach & (level < 0)
        level = jnp.where(newly, cur + 1, level)
        return level, cur + 1, jnp.any(newly)

    level, depth, _ = jax.lax.while_loop(cond, body, (level0, jnp.int32(0), jnp.bool_(True)))
    return level, depth


# --- batched multi-source traversal engine -------------------------------------
#
# S independent traversals over the same graph run the same kernels S times;
# batching B sources turns every per-bucket SpMV into an SpMM with B lanes
# (Brandes-style multi-source BC, multi-query SSSP). State is [B, N]: row b
# is source b's property vector. The direction choice generalizes per batch
# ROW — each source's frontier empties on its own schedule — with whole-batch
# fast paths (all-push / all-pull) so the homogeneous case, by far the most
# common, still evaluates only one direction.

def frontier_rows_should_push(frontier: jax.Array, n: int,
                              threshold_frac: float | None = None) -> jax.Array:
    """Per-row push/pull choice for a [B, N] batched frontier → bool[B].
    `None` falls back to the deprecated `ENGINE` shim; generated code
    always passes the compiled `Schedule`'s threshold explicitly."""
    frac = ENGINE.push_threshold_frac if threshold_frac is None else threshold_frac
    occ = jnp.sum(frontier.astype(jnp.int32), axis=1)
    return occ <= jnp.int32(max(int(n * frac), 1))


def _cond_by_rows(rows_push, push_all, pull_all, mixed, arg):
    """Dispatch on the per-row direction vector: homogeneous batches take a
    single-direction branch; mixed batches evaluate both, each masked to its
    rows (the masks make the two halves disjoint, so combining is exact)."""
    return jax.lax.cond(
        jnp.all(rows_push), push_all,
        lambda a: jax.lax.cond(jnp.any(rows_push), mixed, pull_all, a),
        arg)


def relax_minplus_hybrid_batch(g: CSRGraph, dist: jax.Array,
                               frontier: jax.Array | None = None,
                               threshold_frac: float | None = None,
                               direction: str = "auto",
                               weighted: bool = True) -> jax.Array:
    """Batched SSSP/min-plus relaxation: dist [B, N], frontier [B, N] bool.

    Row-for-row identical to `relax_minplus_hybrid` on each dist row with its
    frontier row — push rows scatter-min over out-edges, pull rows gather/
    segment-min over in-edges, and rows are routed independently. (One of
    the four push/pull copies — see the NOTE on `relax_minplus_hybrid`.)"""
    n = g.num_nodes

    def push(d, fr):
        cand = d[:, g.edge_src] + g.weights[None, :] if weighted \
            else d[:, g.edge_src]
        if fr is not None:
            cand = jnp.where(fr[:, g.edge_src], cand, INF)
        return scatter_min_rows(d, g.indices, cand)

    def pull(d, fr):
        cand = d[:, g.rev_indices] + g.rev_weights[None, :] if weighted \
            else d[:, g.rev_indices]
        if fr is not None:
            cand = jnp.where(fr[:, g.rev_indices], cand, INF)
        return jnp.minimum(d, segment_min_batch(cand, g.rev_edge_dst, n))

    if frontier is None:
        return pull(dist, None)
    if direction == "push":
        return push(dist, frontier)
    if direction == "pull":
        return pull(dist, frontier)
    rows_push = frontier_rows_should_push(frontier, n, threshold_frac)
    return _cond_by_rows(
        rows_push,
        lambda d: push(d, frontier),
        lambda d: pull(d, frontier),
        lambda d: pull(push(d, frontier & rows_push[:, None]),
                       frontier & ~rows_push[:, None]),
        dist)


def relax_minplus_delta_batch(g: CSRGraph, dist: jax.Array,
                              frontier: jax.Array,
                              threshold_frac: float | None = None,
                              direction: str = "auto",
                              weighted: bool = True) -> jax.Array:
    """Batched bucketed min relaxation: dist [B, N], frontier [B, N] already
    restricted per row to that row's current delta bucket. Each source lane
    settles its own bucket sequence, so there is no whole-batch compact
    buffer — the restriction itself (far fewer active sources per sweep) is
    the win, and the relaxation routes through the batched hybrid."""
    return relax_minplus_hybrid_batch(g, dist, frontier, threshold_frac,
                                      direction, weighted)


def bfs_levels_batch(g: CSRGraph, roots: jax.Array,
                     threshold_frac: float | None = None,
                     direction: str = "auto"):
    """Batched level-synchronous BFS from roots[B] with per-row direction
    optimization. Returns (level int32[B, N], depth) — row b equals
    `bfs_levels(g, roots[b])[0]`; depth is the deepest row's level count, so
    shallower rows simply see empty frontiers at the tail levels."""
    n = g.num_nodes
    b = roots.shape[0]
    lanes = jnp.arange(b, dtype=jnp.int32)
    level0 = jnp.full((b, n), -1, jnp.int32).at[lanes, roots].set(0)

    def cond(state):
        _, cur, changed = state
        return changed

    def body(state):
        level, cur, _ = state
        frontier = level == cur

        def push(fr):
            return scatter_or_rows(jnp.zeros((b, n), jnp.bool_), g.indices,
                                   fr[:, g.edge_src])

        def pull(fr):
            return segment_max_batch(fr[:, g.rev_indices].astype(jnp.int32),
                                     g.rev_edge_dst, n) > 0

        if direction == "push":
            reach = push(frontier)
        elif direction == "pull":
            reach = pull(frontier)
        else:
            rows_push = frontier_rows_should_push(frontier, n, threshold_frac)
            reach = _cond_by_rows(
                rows_push, push, pull,
                lambda fr: push(fr & rows_push[:, None]) | pull(fr & ~rows_push[:, None]),
                frontier)
        newly = reach & (level < 0)
        level = jnp.where(newly, cur + 1, level)
        return level, cur + 1, jnp.any(newly)

    level, depth, _ = jax.lax.while_loop(
        cond, body, (level0, jnp.int32(0), jnp.bool_(True)))
    return level, depth


def sssp_multi(g: CSRGraph, sources: jax.Array,
               threshold_frac: float | None = None,
               direction: str = "auto",
               priority: str = "none",
               delta_bucket: int = 64) -> jax.Array:
    """Multi-query SSSP: one batched fixed point answering B source queries
    per sweep. Returns dist int32[B, N]; row b == SSSP from sources[b].

    `priority="delta"` runs each lane's fixed point as delta-stepping: a
    sweep relaxes only the lane's vertices below its current bucket
    boundary, and a lane whose bucket settled jumps straight to the bucket
    of its smallest pending value. The fixed point is unchanged (Min is
    monotone); only the per-sweep work shrinks."""
    n = g.num_nodes
    b = sources.shape[0]
    lanes = jnp.arange(b, dtype=jnp.int32)
    dist0 = jnp.full((b, n), INF, jnp.int32).at[lanes, sources].set(0)
    fr0 = jnp.zeros((b, n), jnp.bool_).at[lanes, sources].set(True)

    def cond(state):
        return jnp.any(state[1])

    if priority != "delta":
        def body(state):
            d, fr = state
            d2 = relax_minplus_hybrid_batch(g, d, fr, threshold_frac,
                                            direction)
            return d2, d2 < d

        dist, _ = jax.lax.while_loop(cond, body, (dist0, fr0))
        return dist

    delta = jnp.int32(delta_bucket)

    def body(state):
        d, mod, bk = state
        # fused bucket advance: a lane whose window emptied jumps to the
        # bucket of its smallest pending value (upper-bound-only window)
        pend_min = jnp.min(jnp.where(mod, d, INF), axis=1)
        bk = jnp.where(jnp.any(mod & (d < (bk + 1)[:, None] * delta), axis=1),
                       bk, pend_min // delta)
        fr = mod & (d < (bk + 1)[:, None] * delta)
        d2 = relax_minplus_delta_batch(g, d, fr, threshold_frac, direction)
        return d2, (d2 < d) | (mod & ~fr), bk

    dist, _, _ = jax.lax.while_loop(
        cond, body, (dist0, fr0, jnp.zeros((b,), jnp.int32)))
    return dist


def ppr_multi(g: CSRGraph, sources: jax.Array, delta: float = 0.85,
              beta: float = 1e-4, max_iter: int = 100) -> jax.Array:
    """Multi-query personalized PageRank: one batched sweep serving B
    personalization vectors. Returns float32[B, N]; row b is the PPR with
    the restart vector concentrated on sources[b] — the same per-source
    do-while ppr.sp lowers to, so lanes converge independently (per-lane L1
    diff vs `beta`) and converged lanes are frozen while the rest sweep."""
    n = g.num_nodes
    b = sources.shape[0]
    lanes = jnp.arange(b, dtype=jnp.int32)
    restart = jnp.zeros((b, n), jnp.float32).at[lanes, sources].set(1.0)
    inv_deg = 1.0 / jnp.maximum(g.out_degree, 1).astype(jnp.float32)

    def cond(state):
        _, act, _ = state
        return jnp.any(act)

    def body(state):
        rank, act, it = state
        contrib = (rank * inv_deg[None, :])[:, g.rev_indices]   # [B, E]
        pulled = segment_sum_batch(contrib, g.rev_edge_dst, n)
        nxt = (1.0 - delta) * restart + delta * pulled
        diff = jnp.sum(jnp.abs(nxt - rank), axis=1)
        rank = jnp.where(act[:, None], nxt, rank)
        act = act & (diff > beta) & (it + 1 < max_iter)
        return rank, act, it + 1

    rank, _, _ = jax.lax.while_loop(
        cond, body, (restart, jnp.ones((b,), jnp.bool_), jnp.int32(0)))
    return rank


# --- triangle counting (the paper's Fig. 20 wedge pattern) ----------------------

def wedge_count(g: CSRGraph, chunk: int = 512) -> jax.Array:
    """Vectorized node-iterator TC: for v, u in N(v) with u<v, w in N(v) with
    w>v, count (u, w) ∈ E. Wedges are enumerated on an ELL padded view in
    vertex chunks of `chunk` rows to bound memory (the OpenMP backend's
    parallel-for over v, restructured for a vector unit)."""
    n = g.num_nodes
    if g.num_edges == 0:
        return jnp.int32(0)
    max_deg = max(g.max_out_degree, 1)   # static (host-side) metadata

    def row_nbrs(vs):
        # [C, D] neighbor ids (n = padding)
        offs = g.indptr[vs][:, None] + jnp.arange(max_deg)[None, :]
        valid = jnp.arange(max_deg)[None, :] < g.out_degree[vs][:, None]
        cols = jnp.where(valid, g.indices[jnp.clip(offs, 0, g.num_edges - 1)], n)
        return cols, valid

    num_chunks = -(-n // chunk)

    def chunk_count(c, acc):
        vs = c * chunk + jnp.arange(chunk)
        vs_ok = vs < n
        vs_c = jnp.clip(vs, 0, n - 1)
        cols, valid = row_nbrs(vs_c)
        u = cols[:, :, None]                      # [C, D, 1]
        w = cols[:, None, :]                      # [C, 1, D]
        vv = vs_c[:, None, None]
        mask = (valid[:, :, None] & valid[:, None, :]
                & (u < vv) & (w > vv) & vs_ok[:, None, None])
        hit = is_an_edge(g, u, w)        # keyed or row-search, per graph size
        return acc + jnp.sum(jnp.where(mask, hit, False).astype(jnp.int32))

    return jax.lax.fori_loop(0, num_chunks, chunk_count, jnp.int32(0))


# --- property helpers ------------------------------------------------------------

def init_prop(n, dtype, value=None):
    dt = jnp.dtype(dtype)
    if value is None:
        return jnp.zeros((n,), dt)
    return jnp.full((n,), value, dt)


def warm_start(init, warm, reset=None):
    """Per-property warm start for an incremental refresh (`__refresh`
    codegen variants call this right before the iterative construct).

    `init` is the property AFTER the program's own init statements ran, so
    source writes (e.g. `dist[src] = 0`) survive for reset vertices. With
    no previous value the cold init stands; with one, `reset` marks the
    vertices whose previous value may be stale (the deletion cone) and
    falls back to the cold init there, keeping the still-exact warm values
    everywhere else."""
    if warm is None:
        return init
    warm = jnp.asarray(warm, init.dtype)
    if reset is None:
        return warm
    return jnp.where(jnp.asarray(reset), init, warm)


def init_prop_batch(b, n, dtype, value=None):
    """[B, N] per-source property block (batched set-loop chunk). `value`
    may be a scalar or an [N] vector (broadcast across the batch rows)."""
    dt = jnp.dtype(dtype)
    if value is None:
        return jnp.zeros((b, n), dt)
    return jnp.broadcast_to(jnp.asarray(value, dt), (b, n))


def inf_for(dtype):
    dt = jnp.dtype(dtype)
    if dt.kind == "i":
        return INF
    if dt.kind == "b":
        return jnp.bool_(True)
    return jnp.asarray(jnp.inf, dt)


def reduce_identity(op: str, dtype):
    dt = jnp.dtype(dtype)
    if op == "+":
        return jnp.zeros((), dt)
    if op == "*":
        return jnp.ones((), dt)
    if op == "&&":
        return jnp.bool_(True)
    if op == "||":
        return jnp.bool_(False)
    raise ValueError(op)
