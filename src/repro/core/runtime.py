"""Runtime library for StarPlat-generated JAX code.

These are the "batteries included" utility functions of the paper (§2),
implemented TPU-natively: every primitive is shape-static, mask-based, and
free of data-dependent control flow, so one compiled program serves a graph
regardless of frontier contents.

Race handling (the paper's atomics) is structural here: `scatter_min` uses
XLA's associative scatter-min combinator (deterministic, no CAS needed) and
pull-reductions use sorted segment ops.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..graph.csr import CSRGraph, ENGINE, INF_I32

INF = jnp.int32(INF_I32)


# --- scatter / segment combine (the Min/Max construct, reductions) -----------

def scatter_min(current: jax.Array, idx: jax.Array, cand: jax.Array) -> jax.Array:
    """min-combine `cand` into `current` at positions `idx` (push relax)."""
    return current.at[idx].min(cand)


def scatter_max(current, idx, cand):
    return current.at[idx].max(cand)


def scatter_add(current, idx, vals):
    return current.at[idx].add(vals)


def scatter_or(current, idx, vals):
    return current.at[idx].max(vals)  # bool max == or


def segment_sum(vals, seg_ids, num_segments, sorted_ids=True):
    return jax.ops.segment_sum(vals, seg_ids, num_segments=num_segments,
                               indices_are_sorted=sorted_ids)


def segment_min(vals, seg_ids, num_segments, sorted_ids=True):
    return jax.ops.segment_min(vals, seg_ids, num_segments=num_segments,
                               indices_are_sorted=sorted_ids)


def segment_max(vals, seg_ids, num_segments, sorted_ids=True):
    return jax.ops.segment_max(vals, seg_ids, num_segments=num_segments,
                               indices_are_sorted=sorted_ids)


# --- graph queries ------------------------------------------------------------

def _edge_key_dtype(n: int):
    if n * n >= 2**31:
        raise ValueError(
            f"is_an_edge key space overflows int32 for n={n}; "
            "enable x64 or use the ELL membership path")
    return jnp.int32


def is_an_edge(g: CSRGraph, u: jax.Array, w: jax.Array) -> jax.Array:
    """Membership test via binary search over the sorted (src, dst) key —
    the paper's `is_an_edge` with sorted-CSR binary search (§5.1 TC). The
    key array is cached on the graph (built once in `from_edges`)."""
    if g.num_edges == 0:
        return jnp.zeros(jnp.broadcast_shapes(u.shape, w.shape), jnp.bool_)
    dt = _edge_key_dtype(g.num_nodes)
    key = g.edge_key
    q = u.astype(dt) * g.num_nodes + w.astype(dt)
    pos = jnp.searchsorted(key, q)
    pos = jnp.clip(pos, 0, key.shape[0] - 1)
    return key[pos] == q


# --- frontier engine (direction-optimizing traversal) --------------------------
#
# The paper gets performance per backend by restructuring the same IR; the
# TPU restructuring here is Beamer-style direction optimization with
# shape-static state: the frontier is a dense bool[N] threaded through the
# while_loop carry (the fixedPoint conv property IS the frontier), and each
# step picks push (scatter from frontier sources) or pull (gather/segment
# over in-edges) via an on-device occupancy test — both branches compute the
# identical relaxation, so lax.cond is exact, not approximate.

def frontier_size(frontier: jax.Array) -> jax.Array:
    """On-device occupancy count of a dense bool frontier."""
    return jnp.sum(frontier.astype(jnp.int32))


def frontier_should_push(frontier: jax.Array, n: int,
                         threshold_frac: float | None = None) -> jax.Array:
    """True when the frontier is sparse enough that push (scatter from the
    few active sources) beats a pull sweep. The knob is
    `ENGINE.push_threshold_frac` (fraction of N)."""
    frac = ENGINE.push_threshold_frac if threshold_frac is None else threshold_frac
    return frontier_size(frontier) <= jnp.int32(max(int(n * frac), 1))


def relax_minplus_hybrid(g: CSRGraph, dist: jax.Array,
                         frontier: jax.Array | None = None,
                         threshold_frac: float | None = None) -> jax.Array:
    """One SSSP/min-plus relaxation restricted to `frontier` sources, with
    push/pull direction chosen on-device.

      push: scatter-min dist[u]+w over out-edges of frontier vertices
      pull: per-vertex min over in-edges, sources masked to the frontier

    Both compute dist'[v] = min(dist[v], min_{(u,v)∈E, frontier[u]} dist[u]+w)
    exactly, so the switch never changes results. `frontier=None` is a dense
    sweep (every vertex contributes).

    NOTE: the local backend emits this same push/pull pair inline
    (local_jax.emit_relax_hybrid) so the generated source stays inspectable;
    keep the two in sync."""
    n = g.num_nodes

    def push(d):
        cand = d[g.edge_src] + g.weights
        if frontier is not None:
            cand = jnp.where(frontier[g.edge_src], cand, INF)
        return scatter_min(d, g.indices, cand)

    def pull(d):
        cand = d[g.rev_indices] + g.rev_weights
        if frontier is not None:
            cand = jnp.where(frontier[g.rev_indices], cand, INF)
        return jnp.minimum(d, segment_min(cand, g.rev_edge_dst, n))

    if frontier is None:
        return pull(dist)
    return jax.lax.cond(frontier_should_push(frontier, n, threshold_frac),
                        push, pull, dist)


# --- BFS (iterateInBFS construct) ----------------------------------------------

def bfs_levels(g: CSRGraph, root, max_levels: int | None = None):
    """Level-synchronous BFS with direction-optimizing expansion. Dense
    frontier: level[v] = -1 until visited; frontier = (level == cur).

      push (small frontier): scatter-or over out-edges of frontier vertices
      pull (large frontier): segment-or over in-edges from frontier sources

    Both mark exactly the unseen out-neighborhood of the frontier, so the
    switch is result-invariant. Returns (level[int32 N], num_levels)."""
    n = g.num_nodes
    level0 = jnp.full((n,), -1, jnp.int32).at[root].set(0)

    def cond(state):
        _, cur, changed = state
        return changed

    def body(state):
        level, cur, _ = state
        frontier = level == cur

        def push(fr):
            hit = scatter_or(jnp.zeros((n,), jnp.bool_), g.indices,
                             fr[g.edge_src])
            return hit

        def pull(fr):
            return segment_max(fr[g.rev_indices].astype(jnp.int32),
                               g.rev_edge_dst, n) > 0

        reach = jax.lax.cond(frontier_should_push(frontier, n), push, pull,
                             frontier)
        newly = reach & (level < 0)
        level = jnp.where(newly, cur + 1, level)
        return level, cur + 1, jnp.any(newly)

    level, depth, _ = jax.lax.while_loop(cond, body, (level0, jnp.int32(0), jnp.bool_(True)))
    return level, depth


# --- triangle counting (the paper's Fig. 20 wedge pattern) ----------------------

def wedge_count(g: CSRGraph, chunk: int = 512) -> jax.Array:
    """Vectorized node-iterator TC: for v, u in N(v) with u<v, w in N(v) with
    w>v, count (u, w) ∈ E. Wedges are enumerated on an ELL padded view in
    vertex chunks of `chunk` rows to bound memory (the OpenMP backend's
    parallel-for over v, restructured for a vector unit)."""
    n = g.num_nodes
    if g.num_edges == 0:
        return jnp.int32(0)
    max_deg = max(g.max_out_degree, 1)   # static (host-side) metadata
    dt = _edge_key_dtype(n)
    key = g.edge_key                     # cached sorted (src·N + dst)

    def row_nbrs(vs):
        # [C, D] neighbor ids (n = padding)
        offs = g.indptr[vs][:, None] + jnp.arange(max_deg)[None, :]
        valid = jnp.arange(max_deg)[None, :] < g.out_degree[vs][:, None]
        cols = jnp.where(valid, g.indices[jnp.clip(offs, 0, g.num_edges - 1)], n)
        return cols, valid

    num_chunks = -(-n // chunk)

    def chunk_count(c, acc):
        vs = c * chunk + jnp.arange(chunk)
        vs_ok = vs < n
        vs_c = jnp.clip(vs, 0, n - 1)
        cols, valid = row_nbrs(vs_c)
        u = cols[:, :, None]                      # [C, D, 1]
        w = cols[:, None, :]                      # [C, 1, D]
        vv = vs_c[:, None, None]
        mask = (valid[:, :, None] & valid[:, None, :]
                & (u < vv) & (w > vv) & vs_ok[:, None, None])
        q = u.astype(dt) * n + w.astype(dt)
        pos = jnp.clip(jnp.searchsorted(key, q.ravel()), 0, key.shape[0] - 1)
        hit = (key[pos] == q.ravel()).reshape(q.shape)
        return acc + jnp.sum(jnp.where(mask, hit, False).astype(jnp.int32))

    return jax.lax.fori_loop(0, num_chunks, chunk_count, jnp.int32(0))


# --- property helpers ------------------------------------------------------------

def init_prop(n, dtype, value=None):
    dt = jnp.dtype(dtype)
    if value is None:
        return jnp.zeros((n,), dt)
    return jnp.full((n,), value, dt)


def inf_for(dtype):
    dt = jnp.dtype(dtype)
    if dt.kind == "i":
        return INF
    if dt.kind == "b":
        return jnp.bool_(True)
    return jnp.asarray(jnp.inf, dt)


def reduce_identity(op: str, dtype):
    dt = jnp.dtype(dtype)
    if op == "+":
        return jnp.zeros((), dt)
    if op == "*":
        return jnp.ones((), dt)
    if op == "&&":
        return jnp.bool_(True)
    if op == "||":
        return jnp.bool_(False)
    raise ValueError(op)
