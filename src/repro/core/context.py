"""GraphContext: the per-graph registry of derived execution structures.

Every backend wants something built from a `CSRGraph` once and reused
across calls — the pallas backend its degree-bucketed sliced-ELL views
(forward or reverse, including the COO hub tail), the distributed backend
its 1-D partitioned device arrays, benchmarks the dense padded ELL view.
Before this module each consumer kept its own cache (the pallas codegen
hid one inside every compiled program's closure); now all derived state
for a graph lives in ONE `GraphContext`, found through a weakref-keyed
module registry:

    ctx = get_context(g)                 # registered on first touch
    ell = ctx.sliced_ell(schedule)       # built once per (layout, reverse)
    gd  = ctx.dist_arrays(num_shards)    # built once per partitioning

Entries hold a WEAK reference to the graph: `id(g)` alone is unsafe (ids
are reused after GC, so a dead graph could alias a new one's views) and a
strong reference would leak every graph ever run. The weakref callback
evicts the entry the moment the graph is collected, and the `ref() is g`
check guards the window before the callback fires.

`prepare(g, schedule)` is the explicit warm-up entry point: call it before
serving traffic so the first query does not pay the host-side view build.

The context also owns the graph's *identity and shape* for the autotuner
(`repro.autotune`): `fingerprint()` is a stable content digest (keys
persisted `TuningRecord`s, so a stored schedule is never replayed against
a different graph), and `stats()` summarizes the degree distribution and
frontier growth (skew, average degree, a BFS probe) — the signals the
tuner's search-space pruning branches on. Both are memoized views like
everything else here. See `docs/architecture.md` for how the
Schedule / GraphContext / compile-cache triad fits together.
"""
from __future__ import annotations

import dataclasses
import hashlib
import weakref
from typing import Optional

import numpy as np

from ..graph.csr import (CSRGraph, pad_nodes, resolve_schedule, to_ell,
                         to_sliced_ell)
from ..schedule import Schedule


class GraphContext:
    """Owns every derived structure of one graph, keyed by (kind, layout).

    Views are built lazily and memoized; two schedules that share a
    `layout_key()` (same bucket structure) share the same sliced view, and
    all programs compiled against the graph share this one context."""

    __slots__ = ("_graph_ref", "_views")

    def __init__(self, graph: CSRGraph):
        self._graph_ref = weakref.ref(graph)
        self._views: dict = {}

    @property
    def graph(self) -> CSRGraph:
        g = self._graph_ref()
        if g is None:
            raise ReferenceError(
                "the graph behind this GraphContext was garbage-collected")
        return g

    def view(self, key, build):
        """Memoized derived structure: `build(graph)` runs at most once."""
        v = self._views.get(key)
        if v is None:
            v = self._views[key] = build(self.graph)
        return v

    def view_keys(self) -> list:
        """The (kind, ...) keys of every view built so far (introspection)."""
        return sorted(self._views, key=repr)

    # ---- memory accounting + eviction ------------------------------------
    # views that are metadata (a digest string, a stats dict), not device
    # memory: never worth evicting, and they key persisted tuning records
    _META_VIEWS = ("fingerprint", "stats")

    def view_nbytes(self) -> dict:
        """Approximate bytes held by each built view, keyed like `_views`.

        Counts array buffers (anything with `.nbytes`) reachable through
        dataclass fields / dicts / sequences; scalars and strings count as
        zero. The padded/dist views replicate the graph's own arrays, so
        this measures what *eviction would free*, not unique residency."""
        return {k: _approx_nbytes(v) for k, v in self._views.items()}

    def total_view_nbytes(self) -> int:
        """Approximate bytes held by every derived view (metadata views are
        ~0 by construction)."""
        return sum(self.view_nbytes().values())

    def drop_view(self, key) -> bool:
        """Forget one memoized view (it rebuilds lazily on next request).
        Returns True when the key was present."""
        return self._views.pop(key, None) is not None

    def drop_derived_views(self) -> int:
        """Evict every *derived* view (sliced-ELL, delta-ELL, padded ELL,
        padded graphs, distributed partitions), keeping the metadata views
        (`fingerprint`, `stats`) that key tuning records. Returns the
        approximate bytes freed. Consumers resolve views through the
        context per call, so the next query transparently re-prepares."""
        freed = 0
        for key in list(self._views):
            if key[0] in self._META_VIEWS:
                continue
            freed += _approx_nbytes(self._views.pop(key))
        return freed

    # ---- the derived structures ------------------------------------------
    def sliced_ell(self, schedule: Optional[Schedule] = None, *,
                   reverse: bool = True):
        """Degree-bucketed sliced-ELL view (+ COO hub tail). `reverse=True`
        is the pull orientation the engine relaxes/gathers over."""
        sched = resolve_schedule(schedule)
        key = ("sliced_ell", bool(reverse), sched.layout_key())
        return self.view(key, lambda g: to_sliced_ell(
            g, reverse=reverse, schedule=sched))

    def ell(self, *, reverse: bool = False):
        """Dense padded `[N, max_deg]` ELL view (benchmark baseline)."""
        return self.view(("ell", bool(reverse)),
                         lambda g: to_ell(g, reverse=reverse))

    # a padded forward ELL costs N * round8(max_deg) cells; past this many
    # multiples of E (hub-heavy degree distributions) the compact bucket
    # relax would gather mostly padding, so delta-stepping falls back dense
    DELTA_ELL_MAX_BLOWUP = 8

    def delta_ell(self):
        """Forward padded ELL view for the delta-stepping compact relax
        (`rt.relax_minplus_delta` gathers frontier out-rows from it), or
        None when the padding blowup makes it uneconomical — the relax then
        takes its dense fallback, which computes the same fixed point."""
        def build(g):
            cells = g.num_nodes * max(-(-max(int(g.max_out_degree), 1) // 8) * 8, 8)
            if cells > self.DELTA_ELL_MAX_BLOWUP * max(g.num_edges, 1):
                return None
            return to_ell(g, reverse=False)
        return self.view(("delta_ell",), build)

    def padded(self, multiple: int) -> CSRGraph:
        """Node-count-padded copy of the graph (device-shard alignment)."""
        return self.view(("padded", int(multiple)),
                         lambda g: pad_nodes(g, multiple))

    def dist_arrays(self, num_shards: int, *, ell: bool = False) -> dict:
        """1-D block-partitioned device arrays for the distributed backend."""
        from . import runtime_dist as rtd
        key = ("dist_1d", int(num_shards), bool(ell))
        return self.view(key, lambda g: rtd.prepare_graph_1d(
            g, num_shards, ell=ell))

    def fingerprint(self) -> str:
        """Stable content digest of the graph (structure + weights).

        Keys persisted autotuning records: two CSRGraphs with identical
        edges hash equal regardless of object identity, and any edit to
        the graph yields a different fingerprint, so a stored schedule is
        re-tuned rather than silently replayed against the wrong graph."""
        return self.view(("fingerprint",), _graph_fingerprint)

    def stats(self) -> dict:
        """Degree-distribution + frontier-growth summary (host-side, memoized).

        The autotuner's search-space pruning branches on these: a power-law
        graph (high ``skew``/``deg_cv``, explosive ``probe_growth``) wants
        deep bucket layouts and direction switching; a road-like graph
        (uniform degree, ``probe_depth`` at the cap, flat frontier) wants a
        single narrow bucket and a pinned sparse-frontier direction."""
        return self.view(("stats",), _graph_stats)


# --------------------------------------------------------------------------
# view memory accounting
# --------------------------------------------------------------------------

def _approx_nbytes(v, _seen=None) -> int:
    """Bytes of array buffer reachable from a derived view: walks dataclass
    fields (CSRGraph, EllGraph, SlicedEllGraph are all frozen dataclasses),
    dicts (dist partitions), and sequences; an object with `.nbytes` is a
    buffer and counted directly. Shared buffers are counted once."""
    if _seen is None:
        _seen = set()
    if id(v) in _seen:
        return 0
    _seen.add(id(v))
    nb = getattr(v, "nbytes", None)
    if isinstance(nb, (int, np.integer)):
        return int(nb)
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return sum(_approx_nbytes(getattr(v, f.name), _seen)
                   for f in dataclasses.fields(v))
    if isinstance(v, dict):
        return sum(_approx_nbytes(x, _seen) for x in v.values())
    if isinstance(v, (list, tuple)):
        return sum(_approx_nbytes(x, _seen) for x in v)
    return 0


# --------------------------------------------------------------------------
# graph identity + statistics (autotuner inputs)
# --------------------------------------------------------------------------

PROBE_MAX_LEVELS = 64   # frontier probe cap: deep graphs saturate the signal


def _graph_fingerprint(g: CSRGraph) -> str:
    """sha256 over (N, E, version, indptr, indices, weights), truncated to
    16 hex chars. Content-addressed up to the update generation:
    independent of object identity and of every derived view, but an
    `update()` bumps `version` so even a content-identical successor (e.g.
    delete-then-reinsert) keys fresh tuning records and bind-cache
    entries instead of aliasing the pre-update graph's."""
    h = hashlib.sha256()
    h.update(f"{g.num_nodes}:{g.num_edges}:{g.version}:".encode())
    for arr in (g.indptr, g.indices, g.weights):
        h.update(np.ascontiguousarray(np.asarray(arr)).tobytes())
    return h.hexdigest()[:16]


def _graph_stats(g: CSRGraph) -> dict:
    """Host-side numpy summary of the degree distribution plus a capped
    level-synchronous BFS probe from the highest-out-degree vertex."""
    n, e = g.num_nodes, g.num_edges
    out_deg = np.asarray(g.out_degree)
    avg = e / n if n else 0.0
    std = float(out_deg.std()) if n else 0.0
    weights = np.asarray(g.weights)
    avg_w = float(weights.mean()) if e else 0.0
    stats = {
        "num_nodes": n,
        "num_edges": e,
        "avg_degree": round(avg, 3),
        "max_out_degree": int(g.max_out_degree),
        "max_in_degree": int(g.max_in_degree),
        # degree skew: how far the heaviest hub sits above the mean
        "skew": round(g.max_out_degree / avg, 3) if avg else 1.0,
        # coefficient of variation: 0 for regular graphs, >1 for power laws
        "deg_cv": round(std / avg, 3) if avg else 0.0,
        # weight scale: candidate delta_bucket widths are multiples of the
        # mean edge weight (a bucket spans ~avg_weight * k relaxed hops)
        "avg_weight": round(avg_w, 3),
        "max_weight": int(weights.max()) if e else 0,
    }
    if e == 0:
        stats.update(probe_depth=0, probe_max_frontier_frac=0.0,
                     probe_growth=1.0, probe_reach_frac=0.0)
        return stats
    # frontier-growth probe: BFS from the heaviest hub, recording per-level
    # frontier sizes (edge-parallel sweep per level — O(E) each, capped)
    edge_src = np.asarray(g.edge_src)
    indices = np.asarray(g.indices)
    root = int(out_deg.argmax())
    level = np.full(n, -1, np.int32)
    level[root] = 0
    front = np.zeros(n, bool)
    front[root] = True
    sizes = [1]
    for lvl in range(PROBE_MAX_LEVELS):
        hit = np.zeros(n, bool)
        hit[indices[front[edge_src]]] = True
        newly = hit & (level < 0)
        if not newly.any():
            break
        level[newly] = lvl + 1
        front = newly
        sizes.append(int(newly.sum()))
    growth = max((b / a for a, b in zip(sizes, sizes[1:])), default=1.0)
    stats.update(
        probe_depth=len(sizes) - 1,                  # levels until exhaustion/cap
        probe_max_frontier_frac=round(max(sizes) / n, 4),
        probe_growth=round(growth, 2),               # peak level-over-level ratio
        probe_reach_frac=round(sum(sizes) / n, 4),   # fraction reached from hub
    )
    return stats


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_REGISTRY: dict = {}   # id(graph) -> (weakref(graph), GraphContext)


def get_context(g: CSRGraph) -> GraphContext:
    """The graph's `GraphContext`, creating (and registering) it on first
    touch. Cheap enough to call per query: one dict probe + one weakref
    deref on the hot path."""
    key = id(g)
    entry = _REGISTRY.get(key)
    if entry is None or entry[0]() is not g:
        ref = weakref.ref(g, lambda _r, _k=key: _REGISTRY.pop(_k, None))
        _REGISTRY[key] = entry = (ref, GraphContext(g))
    return entry[1]


def contains(g: CSRGraph) -> bool:
    """True if `g` currently has a live registered context."""
    entry = _REGISTRY.get(id(g))
    return entry is not None and entry[0]() is g


def registry_size() -> int:
    return len(_REGISTRY)


def clear() -> None:
    """Drop every registered context (tests / memory pressure)."""
    _REGISTRY.clear()


def prepare(g: CSRGraph, schedule: Optional[Schedule] = None, *,
            backend: str = "pallas", mesh=None, program=None) -> GraphContext:
    """Explicit warm-up: build the derived structures `backend` needs so the
    first query served against `g` pays no host-side view construction.

    * ``pallas`` — the reverse sliced-ELL view for `schedule`'s layout;
    * ``distributed`` — the 1-D partition for `mesh` (default: one shard
      per local device); pass `program=` so programs whose generated body
      needs the replicated ELL view (`dist_meta["needs_ell"]`, e.g. TC)
      warm the exact partition `bind` will request;
    * ``local`` — nothing derived (the CSR arrays ARE the layout); the
      context is still registered so `bind` is uniform.

    `program=` also supplies the schedule/backend defaults:
    `prepare(g, program=prog)` warms precisely what `prog.bind(g)` needs.

    Returns the graph's `GraphContext` (the same object every consumer of
    `g` sees). Idempotent and cheap when already warm."""
    if program is not None:
        if schedule is None:
            schedule = getattr(program, "schedule", None)
        backend = getattr(program, "backend", backend)
    sched = resolve_schedule(schedule)
    ctx = get_context(g)
    if backend == "pallas":
        ctx.sliced_ell(sched, reverse=True)
    elif backend == "distributed":
        from . import runtime_dist as rtd
        if mesh is None:
            from .dist import make_mesh_1d
            mesh = make_mesh_1d()
        meta = (getattr(program, "dist_meta", None) or {})
        ctx.dist_arrays(mesh.shape[rtd.AXIS],
                        ell=meta.get("needs_ell", False))
    elif backend != "local":
        raise ValueError(
            f"unknown backend {backend!r}; expected 'local', 'pallas', or "
            "'distributed'")
    return ctx


def adopt_patched_views(delta) -> GraphContext:
    """Carry the old graph's sliced-ELL views across a `g.update()`.

    `apply_update` calls this eagerly with the `GraphDelta` it built: every
    `("sliced_ell", reverse, layout)` view the OLD graph's context holds is
    delta-patched (`repro.graph.dynamic.patch_sliced_ell` — in-place bucket
    row rewrites, hub-tail absorption of degree-class migrations) and
    installed into the NEW graph's context, so post-update queries skip the
    O(N + E) view rebuild. Other derived views (dense/delta ELL, padded
    graphs, distributed partitions) are left to rebuild lazily — they are
    either whole-graph reshapes with no cheap patch or benchmark-only.

    Returns the new graph's context (registered even when the old graph
    never had one, so the fingerprint/bind machinery sees the new
    `version` immediately)."""
    from ..graph.dynamic import patch_sliced_ell
    new_ctx = get_context(delta.graph)
    if contains(delta.old):
        old_ctx = get_context(delta.old)
        for key in old_ctx.view_keys():
            if key[0] != "sliced_ell" or key in new_ctx._views:
                continue
            _, rev, _layout = key
            new_ctx._views[key] = patch_sliced_ell(
                old_ctx._views[key], delta, reverse=rev)
    return new_ctx
