"""Model assembly: decoder-only LM (dense / MoE / hybrid / xLSTM stacks).

Every architecture exposes the same surface:
    init(key, cfg)                       → params
    forward(params, cfg, tokens|embeds)  → logits  (training path)
    init_cache(cfg, batch, max_len)      → decode cache
    decode_step(params, cfg, tok, cache) → logits, cache

Layer stacks use jax.lax.scan over [L]-stacked params with
jax.checkpoint (remat) on the body — compile-time and memory sane at 94
layers × 512 devices. Hybrid stacks (zamba2) scan the Mamba backbone and
apply the SHARED attention block (one weight set, distinct KV per call
site) every `attn_every` layers via an inner switch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (attention_block, attention_decode, attn_init,
                        init_kv_cache)
from .layers import (dense_init, embed_init, layer_slice, maybe_constrain,
                     mlp, mlp_init, rmsnorm, rmsnorm_init, stack_layers)
from .moe import moe_ffn, moe_init
from .ssm import (mamba2_block, mamba2_decode, mamba2_init, mamba2_init_state,
                  mlstm_block, mlstm_decode, mlstm_init, mlstm_init_state,
                  slstm_block, slstm_decode, slstm_init)


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# per-layer init / apply (dense + moe families)
# --------------------------------------------------------------------------

def _dense_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p = {"ln1": rmsnorm_init(cfg.d_model, dtype),
         "attn": attn_init(k1, cfg, dtype),
         "ln2": rmsnorm_init(cfg.d_model, dtype)}
    if cfg.family == "moe":
        p["moe"] = moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _dense_layer_apply(p, cfg, x, positions, impl):
    scale = cfg.scale_depth / (cfg.n_layers ** 0.5) if cfg.scale_depth else 1.0
    h = attention_block(p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                        positions, causal=True, impl=impl)
    x = x + h * scale
    hin = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        h, aux = moe_ffn(p["moe"], cfg, hin)
    else:
        h, aux = mlp(p["mlp"], hin), 0.0
    return x + h * scale, aux


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init(key, cfg):
    dtype = _dt(cfg)
    keys = jax.random.split(key, 8)
    params = {"embed": embed_init(keys[0], cfg.vocab_padded, cfg.d_model, dtype),
              "ln_f": rmsnorm_init(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_padded), dtype)

    if cfg.family in ("dense", "moe"):
        params["layers"] = stack_layers(
            keys[2], cfg.n_layers, lambda k: _dense_layer_init(k, cfg, dtype))
    elif cfg.family == "hybrid":       # zamba2: mamba backbone + shared attn
        params["layers"] = stack_layers(
            keys[2], cfg.n_layers, lambda k: mamba2_init(k, cfg, dtype))
        params["shared_attn"] = _dense_layer_init(keys[3], cfg, dtype)
    elif cfg.family == "ssm":          # xlstm: mLSTM stack + periodic sLSTM
        n_s = cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 0
        n_m = cfg.n_layers - n_s
        params["mlstm"] = stack_layers(
            keys[2], n_m, lambda k: mlstm_init(k, cfg, dtype))
        if n_s:
            params["slstm"] = stack_layers(
                keys[3], n_s, lambda k: slstm_init(k, cfg, dtype))
    else:
        raise ValueError(cfg.family)
    return params


# --------------------------------------------------------------------------
# forward (training / prefill)
# --------------------------------------------------------------------------

def forward(params, cfg, tokens=None, embeds=None, *, impl="ref",
            remat: bool = True, last_only: bool = False):
    """tokens: [B, S] int32 (or embeds: [B, S, d] for stub-frontend archs).
    Returns (logits [B, S, V], aux_loss)."""
    if embeds is None:
        x = params["embed"][tokens] * cfg.scale_emb
    else:
        x = embeds.astype(_dt(cfg)) * cfg.scale_emb
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    if cfg.family in ("dense", "moe"):
        def body(carry, lp):
            x, aux = carry
            x, a = _dense_layer_apply(lp, cfg, x, positions, impl)
            return (x, aux + a), None
        body_fn = jax.checkpoint(body) if remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)), params["layers"])
    elif cfg.family == "hybrid":
        every = cfg.attn_every or (cfg.n_layers + 1)

        def body(carry, inp):
            x, aux = carry
            lp, idx = inp
            x = x + mamba2_block(lp, cfg, x)
            use_attn = (idx % every) == (every - 1)
            shared = params["shared_attn"]

            def with_attn(x):
                h, _ = _dense_layer_apply(shared, cfg, x, positions, impl)
                return h
            x = jax.lax.cond(use_attn, with_attn, lambda x: x, x)
            return (x, aux), None
        body_fn = jax.checkpoint(body) if remat else body
        (x, aux), _ = jax.lax.scan(
            body_fn, (x, jnp.float32(0)),
            (params["layers"], jnp.arange(cfg.n_layers)))
    elif cfg.family == "ssm":
        every = cfg.slstm_every or (cfg.n_layers + 1)
        # interleave: positions k*every-1 are sLSTM; scan mLSTM stack, then
        # apply sLSTM blocks at their positions (sequential python loop over
        # the small sLSTM stack keeps the scan homogeneous).
        def body(carry, lp):
            x = carry
            x = x + mlstm_block(lp, cfg, x)
            return x, None
        body_fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body_fn, x, params["mlstm"])
        aux = jnp.float32(0)
        if "slstm" in params:
            n_s = jax.tree.leaves(params["slstm"])[0].shape[0]
            for i in range(n_s):
                x = x + slstm_block(layer_slice(params["slstm"], i), cfg, x)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if last_only:      # prefill: only the next-token logits are needed
        x = x[:, -1:]
    w_out = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ w_out).astype(jnp.float32)
    # §Perf: the transpose of the ('model','data')-sharded embedding loses
    # the vocab sharding — pin logits to vocab-sharded so the CE reduction
    # runs sharded instead of materializing [B,S,V] replicated.
    logits = maybe_constrain(logits, ("pod", "data"), None, "model")
    return logits, aux


# --------------------------------------------------------------------------
# decode (one token, static cache)
# --------------------------------------------------------------------------

def init_cache(cfg, batch, max_len):
    dtype = _dt(cfg)
    if cfg.family in ("dense", "moe"):
        return {"kv": jax.vmap(lambda _: init_kv_cache(cfg, batch, max_len, dtype))(
            jnp.arange(cfg.n_layers))}
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        return {
            "ssm": jax.vmap(lambda _: mamba2_init_state(cfg, batch, dtype))(
                jnp.arange(cfg.n_layers)),
            "kv": jax.vmap(lambda _: init_kv_cache(cfg, batch, max_len, dtype))(
                jnp.arange(max(n_attn, 1))),
        }
    if cfg.family == "ssm":
        n_s = cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 0
        n_m = cfg.n_layers - n_s
        cache = {"mlstm": jax.vmap(lambda _: mlstm_init_state(cfg, batch))(
            jnp.arange(n_m))}
        if n_s:
            d = cfg.d_model
            cache["slstm"] = {
                "c": jnp.zeros((n_s, batch, d), jnp.float32),
                "n": jnp.zeros((n_s, batch, d), jnp.float32),
                "m": jnp.full((n_s, batch, d), -1e30, jnp.float32)}
        return cache
    raise ValueError(cfg.family)


def decode_step(params, cfg, tokens, cache, pos):
    """tokens: [B, 1]; pos: [] int32. Returns (logits [B, V], cache)."""
    x = params["embed"][tokens] * cfg.scale_emb

    if cfg.family in ("dense", "moe"):
        def body(x_and_aux, inp):
            x = x_and_aux
            lp, lc = inp
            h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            h, lc_new = attention_decode(lp["attn"], cfg, h, lc, pos)
            x = x + h
            hin = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            if cfg.family == "moe":
                h2, _ = moe_ffn(lp["moe"], cfg, hin)
            else:
                h2 = mlp(lp["mlp"], hin)
            return x + h2, lc_new
        x, kv = jax.lax.scan(body, x, (params["layers"], cache["kv"]))
        cache = {"kv": kv}
    elif cfg.family == "hybrid":
        every = cfg.attn_every or (cfg.n_layers + 1)
        ssm_states, kvs = cache["ssm"], cache["kv"]
        new_ssm, new_kv = [], []
        ai = 0
        for i in range(cfg.n_layers):
            lp = layer_slice(params["layers"], i)
            st = jax.tree.map(lambda a: a[i], ssm_states)
            h, st = mamba2_decode(lp, cfg, x, st)
            x = x + h
            new_ssm.append(st)
            if (i % every) == (every - 1):
                lc = jax.tree.map(lambda a: a[ai], kvs)
                shared = params["shared_attn"]
                h = rmsnorm(shared["ln1"], x, cfg.norm_eps)
                h, lc = attention_decode(shared["attn"], cfg, h, lc, pos)
                x = x + h
                h2 = mlp(shared["mlp"], rmsnorm(shared["ln2"], x, cfg.norm_eps))
                x = x + h2
                new_kv.append(lc)
                ai += 1
        cache = {"ssm": jax.tree.map(lambda *a: jnp.stack(a), *new_ssm),
                 "kv": jax.tree.map(lambda *a: jnp.stack(a), *new_kv)}
    elif cfg.family == "ssm":
        def body(x, inp):
            lp, st = inp
            h, st = mlstm_decode(lp, cfg, x, st)
            return x + h, st
        x, mst = jax.lax.scan(body, x, (params["mlstm"], cache["mlstm"]))
        new_cache = {"mlstm": mst}
        if "slstm" in cache:
            n_s = cache["slstm"]["c"].shape[0]
            new_states = []
            for i in range(n_s):
                lp = layer_slice(params["slstm"], i)
                st = jax.tree.map(lambda a: a[i], cache["slstm"])
                h, st = slstm_decode(lp, cfg, x, st)
                x = x + h
                new_states.append(st)
            new_cache["slstm"] = jax.tree.map(lambda *a: jnp.stack(a),
                                              *new_states)
        cache = new_cache
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    w_out = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return (x[:, 0] @ w_out).astype(jnp.float32), cache
