"""Encoder-decoder backbone (seamless-m4t-large-v2).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings [B, S, d]. The decoder is a standard causal
stack with cross-attention into the encoder output; decode keeps both a
self-attention KV cache and the (static) projected cross KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (attention_block, attention_decode, attn_init,
                        init_kv_cache)
from .layers import (embed_init, mlp, mlp_init, rmsnorm,
                     rmsnorm_init, stack_layers)


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn_init(k1, cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)}


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "self_attn": attn_init(k1, cfg, dtype),
            "ln_x": rmsnorm_init(cfg.d_model, dtype),
            "cross_attn": attn_init(k2, cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype)}


def init(key, cfg):
    dtype = _dt(cfg)
    ks = jax.random.split(key, 4)
    return {
        "embed": embed_init(ks[0], cfg.vocab_padded, cfg.d_model, dtype),
        "enc_layers": stack_layers(ks[1], cfg.n_enc_layers,
                                   lambda k: _enc_layer_init(k, cfg, dtype)),
        "dec_layers": stack_layers(ks[2], cfg.n_dec_layers,
                                   lambda k: _dec_layer_init(k, cfg, dtype)),
        "ln_enc": rmsnorm_init(cfg.d_model, dtype),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
    }


def encode(params, cfg, embeds, *, impl="ref", remat=True):
    """embeds: [B, S, d] precomputed frame embeddings (frontend stub)."""
    x = embeds.astype(_dt(cfg))
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, lp):
        h = attention_block(lp["attn"], cfg, rmsnorm(lp["ln1"], x, cfg.norm_eps),
                            positions, causal=False, impl=impl)
        x = x + h
        x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return rmsnorm(params["ln_enc"], x, cfg.norm_eps)


def _cross_kv(p, cfg, enc_out):
    b, s, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def decode_train(params, cfg, tokens, enc_out, *, impl="ref", remat=True,
                 last_only=False):
    """Teacher-forced decoder pass. Returns logits [B, S, V]."""
    x = params["embed"][tokens]
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, lp):
        h = attention_block(lp["self_attn"], cfg,
                            rmsnorm(lp["ln1"], x, cfg.norm_eps),
                            positions, causal=True, impl=impl)
        x = x + h
        kv = _cross_kv(lp["cross_attn"], cfg, enc_out)
        h = attention_block(lp["cross_attn"], cfg,
                            rmsnorm(lp["ln_x"], x, cfg.norm_eps),
                            None, causal=False, impl=impl, kv=kv)
        x = x + h
        x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    return (x @ params["embed"].T).astype(jnp.float32)


def forward(params, cfg, embeds, tokens, *, impl="ref", remat=True,
            last_only=False):
    """Full enc-dec training step: frame embeddings → target logits."""
    enc_out = encode(params, cfg, embeds, impl=impl, remat=remat)
    logits = decode_train(params, cfg, tokens, enc_out, impl=impl, remat=remat,
                          last_only=last_only)
    return logits, jnp.float32(0)


def init_cache(cfg, batch, max_len, enc_len):
    dtype = _dt(cfg)
    return {
        "kv": jax.vmap(lambda _: init_kv_cache(cfg, batch, max_len, dtype))(
            jnp.arange(cfg.n_dec_layers)),
        "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), dtype),
    }


def decode_step(params, cfg, tokens, cache, pos, *, impl="ref"):
    """One decoder token against cached enc_out + self KV."""
    x = params["embed"][tokens]
    enc_out = cache["enc_out"]

    def body(x, inp):
        lp, lc = inp
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        h, lc_new = attention_decode(lp["self_attn"], cfg, h, lc, pos)
        x = x + h
        kv = _cross_kv(lp["cross_attn"], cfg, enc_out)
        h = attention_block(lp["cross_attn"], cfg,
                            rmsnorm(lp["ln_x"], x, cfg.norm_eps),
                            None, causal=False, impl=impl, kv=kv)
        x = x + h
        x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x, lc_new

    x, kv = jax.lax.scan(body, x, (params["dec_layers"], cache["kv"]))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = (x[:, 0] @ params["embed"].T).astype(jnp.float32)
    return logits, {"kv": kv, "enc_out": enc_out}
