"""Mixture-of-Experts FFN: token-choice top-k routing with capacity,
optional always-on shared experts (deepseek-moe), GShard-style einsum
dispatch so expert parallelism is a pure sharding annotation (experts on
the 'model' mesh axis → XLA emits the dispatch all_to_all).

Capacity math: C = ceil(cf · T · k / E) per expert; overflow tokens drop
(standard). The train_step microbatches tokens so T·E·C dispatch tensors
stay VMEM-sane (see train/train_step.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, mlp, mlp_init


def moe_init(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, ff), dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype),
        "w_down": dense_init(ks[3], (e, ff, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, ff * cfg.n_shared_experts, dtype)
    return p


def moe_ffn(p, cfg, x):
    """x: [B, S, d] → [B, S, d] + aux loss (load-balance)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.moe_top_k
    # floor of k keeps tiny-T (decode) calls near-lossless
    cap = max(int(cfg.moe_capacity_factor * t * k / e), k)
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])           # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)     # [T, k, E]
    pos_in_expert = (jnp.cumsum(onehot.reshape(t * k, e), axis=0)
                     .reshape(t, k, e) - 1)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)            # [T, k]
    keep = pos < cap

    # gather/scatter dispatch (§Perf M1): the classic one-hot einsum costs
    # 2·T·E·C·d flops — ~3× the expert FFN itself at E=128. Building an
    # explicit [E, C] token index and gathering is pure data movement.
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)    # [T*k]
    flat_e = gate_idx.reshape(-1)                              # [T*k]
    flat_pos = jnp.where(keep, pos, cap).reshape(-1)           # cap = dropped
    disp = jnp.full((e, cap + 1), t, jnp.int32)                # t = pad row
    disp = disp.at[flat_e, flat_pos].set(
        jnp.where(flat_pos < cap, flat_t, t))[:, :cap]         # [E, C]
    x_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
    xe = x_pad[disp]                                           # [E, C, d]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])            # [E, C, d]
    # combine: gather each kept assignment's expert output, weight, scatter-add
    slot_ok = (flat_pos < cap)
    ye_flat = ye[flat_e, jnp.minimum(flat_pos, cap - 1)]       # [T*k, d]
    wgt = (gate_vals.reshape(-1) * slot_ok).astype(ye_flat.dtype)
    out = jax.ops.segment_sum(ye_flat * wgt[:, None], flat_t,
                              num_segments=t).reshape(b, s, d).astype(x.dtype)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x)

    # load-balance aux loss (Switch): E * Σ_e f_e · p_e
    f = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32),
                         axis=1), axis=0)                     # fraction per expert
    pbar = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f * pbar) * cfg.moe_aux_loss
    return out, aux
