"""SSM / linear-attention layers: Mamba2 (SSD chunked scan), mLSTM, sLSTM.

Both Mamba2 and mLSTM are gated linear recurrences over an outer-product
state — the same chunked "SSD" computation serves both:

    h_t = a_t · h_{t-1} + k_t ⊗ v_t          (state  [N, P])
    y_t = qᵗ_t · h_t                          (readout)

`chunked_linear_attention` evaluates this with O(S·Q) intra-chunk matmuls
(MXU work) + an O(S/Q) inter-chunk scan — the TPU-native dual form. A naive
sequential scan lives alongside as the test oracle and decode path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm, rmsnorm_init


# --------------------------------------------------------------------------
# Core: chunked gated linear attention (SSD dual form)
# --------------------------------------------------------------------------

def chunked_linear_attention(q, k, v, log_a, chunk: int):
    """q,k: [B,S,H,N]; v: [B,S,H,P]; log_a: [B,S,H] (log decay ≤ 0).
    Returns y: [B,S,H,P] where y_t = q_t · (Σ_{s≤t} (∏_{r=s+1..t} a_r) k_s v_sᵀ)."""
    b, s, h, n = q.shape
    p = v.shape[-1]
    nc = s // chunk
    qc = q.reshape(b, nc, chunk, h, n)
    kc = k.reshape(b, nc, chunk, h, n)
    vc = v.reshape(b, nc, chunk, h, p)
    la = log_a.reshape(b, nc, chunk, h)
    cum = jnp.cumsum(la, axis=2)                      # within-chunk cumulative
    total = cum[:, :, -1]                             # [B,nc,H]

    # --- intra-chunk (quadratic in chunk len; MXU matmuls) ---
    # scores[t1,t2] = q_t1·k_t2 · exp(cum_t1 - cum_t2) for t2 ≤ t1
    sc = jnp.einsum("bcthn,bcshn->bchts", qc, kc,
                    preferred_element_type=jnp.float32)
    decay = cum[..., :, None, :] - cum[..., None, :, :]          # [b,nc,t,s,h]
    decay = jnp.moveaxis(decay, -1, 2)                           # [b,nc,h,t,s]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(causal, sc * jnp.exp(decay), 0.0)
    y_intra = jnp.einsum("bchts,bcshp->bcthp", w.astype(v.dtype), vc)

    # --- chunk summaries: state contribution of each chunk ---
    # S_c = Σ_t exp(total - cum_t) k_t ⊗ v_t     [b,nc,h,n,p]
    wk = jnp.exp(total[:, :, None, :] - cum) [..., None] * kc    # [b,nc,t,h,n]
    s_chunk = jnp.einsum("bcthn,bcthp->bchnp", wk.astype(v.dtype), vc)

    # --- inter-chunk scan: h_c = exp(total_c) h_{c-1} + S_c ---
    def step(hprev, inp):
        s_c, tot = inp
        hnew = hprev * jnp.exp(tot)[..., None, None].astype(hprev.dtype) + s_c
        return hnew, hprev                       # emit the state BEFORE chunk c

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, h_prevs = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(s_chunk, 1, 0).astype(jnp.float32),
         jnp.moveaxis(total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)        # [b,nc,h,n,p]

    # --- inter-chunk readout: y_t += exp(cum_t) q_t · h_{c-1} ---
    qdec = (jnp.exp(cum)[..., None] * qc).astype(jnp.float32)
    y_inter = jnp.einsum("bcthn,bchnp->bcthp", qdec, h_prevs.astype(jnp.float32))
    y = y_intra.astype(jnp.float32) + y_inter
    return y.reshape(b, s, h, p)


def linear_attention_ref(q, k, v, log_a):
    """Sequential oracle (and the decode recurrence)."""
    b, s, h, n = q.shape
    p = v.shape[-1]

    def step(hprev, inp):
        qt, kt, vt, lat = inp
        hnew = hprev * jnp.exp(lat)[..., None, None] + \
            jnp.einsum("bhn,bhp->bhnp", kt, vt)
        yt = jnp.einsum("bhn,bhnp->bhp", qt, hnew)
        return hnew, yt

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(q, 1, 0).astype(jnp.float32),
          jnp.moveaxis(k, 1, 0).astype(jnp.float32),
          jnp.moveaxis(v, 1, 0).astype(jnp.float32),
          jnp.moveaxis(log_a, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)                # [B,S,H,P]


# --------------------------------------------------------------------------
# Mamba2 block
# --------------------------------------------------------------------------

def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    n = cfg.ssm_state
    pdim = cfg.ssm_head_dim
    heads = d // pdim
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d + 2 * n + heads), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, d + 2 * n), dtype, scale=0.5),
        "a_log": jnp.zeros((heads,), jnp.float32),     # A = -exp(a_log)
        "dt_bias": jnp.full((heads,), -2.0, jnp.float32),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "out_proj": dense_init(ks[2], (d, d), dtype),
        "norm": rmsnorm_init(d, dtype),
    }


def _causal_conv(x, w):
    """x: [B,S,C]; w: [K,C] depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out


def mamba2_block(p, cfg, x, chunk=None):
    """x: [B,S,d] → [B,S,d] (pre-norm residual inside)."""
    b, s, d = x.shape
    n = cfg.ssm_state
    pdim = cfg.ssm_head_dim
    heads = d // pdim
    chunk = chunk or min(cfg.ssm_chunk, s)
    h = x @ p["in_proj"]                             # [B,S,2d+2n+H]
    z, xin, bc, dt = jnp.split(h, [d, 2 * d, 2 * d + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"]))
    xin, bmat, cmat = jnp.split(conv_out, [d, d + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]
    a = -jnp.exp(p["a_log"])                                      # [H]
    log_a = a * dt                                                # [B,S,H]
    xh = xin.reshape(b, s, heads, pdim)
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, s, heads, n))
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, s, heads, n))
    v = xh * dt[..., None].astype(xh.dtype)
    if s % chunk == 0 and s > 1:
        y = chunked_linear_attention(q, k, v, log_a, chunk)
    else:
        y = linear_attention_ref(q, k, v, log_a)
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d).astype(x.dtype) * jax.nn.silu(z)
    return rmsnorm(p["norm"], y, cfg.norm_eps) @ p["out_proj"]


def mamba2_decode(p, cfg, x, state):
    """One-token decode. state: dict(h: [B,H,N,P], conv: [B,K-1,C])."""
    b, _, d = x.shape
    n = cfg.ssm_state
    pdim = cfg.ssm_head_dim
    heads = d // pdim
    hin = x @ p["in_proj"]
    z, xin, bc, dt = jnp.split(hin, [d, 2 * d, 2 * d + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)     # [B,1,C]
    hist = jnp.concatenate([state["conv"], conv_in], axis=1)   # [B,K,C]
    w = p["conv_w"]
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w))[:, None]
    xin, bmat, cmat = jnp.split(conv_out, [d, d + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]   # [B,H]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(a * dt)                                             # [B,H]
    xh = xin.reshape(b, heads, pdim)
    kt = jnp.broadcast_to(bmat[:, 0, None, :], (b, heads, n))
    qt = jnp.broadcast_to(cmat[:, 0, None, :], (b, heads, n))
    vt = xh * dt[..., None].astype(xh.dtype)
    hnew = state["h"] * decay[..., None, None] + \
        jnp.einsum("bhn,bhp->bhnp", kt.astype(jnp.float32), vt.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", qt.astype(jnp.float32), hnew)
    y = y + p["d_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, 1, d).astype(x.dtype) * jax.nn.silu(z)
    out = rmsnorm(p["norm"], y, cfg.norm_eps) @ p["out_proj"]
    return out, {"h": hnew, "conv": hist[:, 1:]}


def mamba2_init_state(cfg, batch, dtype):
    d = cfg.d_model
    heads = d // cfg.ssm_head_dim
    return {"h": jnp.zeros((batch, heads, cfg.ssm_state, cfg.ssm_head_dim),
                           jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1,
                               d + 2 * cfg.ssm_state), dtype)}


# --------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory) blocks
# --------------------------------------------------------------------------

def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    hd = cfg.hd
    heads = cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, heads * hd), dtype),
        "wk": dense_init(ks[1], (d, heads * hd), dtype),
        "wv": dense_init(ks[2], (d, heads * hd), dtype),
        "wf": dense_init(ks[3], (d, heads), jnp.float32, scale=0.02),
        "wi": dense_init(ks[4], (d, heads), jnp.float32, scale=0.02),
        "wo_gate": dense_init(ks[5], (d, heads * hd), dtype),
        "out": dense_init(jax.random.fold_in(key, 7), (heads * hd, d), dtype),
        "norm": rmsnorm_init(heads * hd, dtype),
    }


def mlstm_block(p, cfg, x, chunk=None):
    """mLSTM ≈ gated linear attention with sigmoid forget / exp input gates."""
    b, s, d = x.shape
    heads, hd = cfg.n_heads, cfg.hd
    chunk = chunk or min(cfg.ssm_chunk, s)
    q = (x @ p["wq"]).reshape(b, s, heads, hd) / (hd ** 0.5)
    k = (x @ p["wk"]).reshape(b, s, heads, hd)
    v = (x @ p["wv"]).reshape(b, s, heads, hd)
    logf = jax.nn.log_sigmoid(x.astype(jnp.float32) @ p["wf"])   # [B,S,H] ≤ 0
    i_gate = jnp.exp(jnp.minimum(x.astype(jnp.float32) @ p["wi"], 8.0))
    k = k * i_gate[..., None].astype(k.dtype)
    if s % chunk == 0 and s > 1:
        y = chunked_linear_attention(q, k, v, logf, chunk)
    else:
        y = linear_attention_ref(q, k, v, logf)
    o = jax.nn.sigmoid(x @ p["wo_gate"]).reshape(b, s, heads, hd)
    y = (y.astype(x.dtype) * o).reshape(b, s, heads * hd)
    return rmsnorm(p["norm"], y, cfg.norm_eps) @ p["out"]


def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    heads = cfg.n_heads
    hd = d // heads
    ks = jax.random.split(key, 5)
    return {
        "wz": dense_init(ks[0], (d, d), dtype),
        "wi": dense_init(ks[1], (d, d), jnp.float32, scale=0.02),
        "wf": dense_init(ks[2], (d, d), jnp.float32, scale=0.02),
        "wo": dense_init(ks[3], (d, d), dtype),
        "out": dense_init(ks[4], (d, d), dtype),
        "norm": rmsnorm_init(d, dtype),
    }


def slstm_block(p, cfg, x):
    """Scalar-memory LSTM with exponential gating — inherently sequential;
    lowered as one lax.scan over the sequence."""
    b, s, d = x.shape
    z = jnp.tanh(x @ p["wz"]).astype(jnp.float32)
    i_pre = x.astype(jnp.float32) @ p["wi"]
    f_pre = x.astype(jnp.float32) @ p["wf"]
    o = jax.nn.sigmoid(x @ p["wo"]).astype(jnp.float32)

    def step(carry, inp):
        c, n, m = carry
        zt, it, ft, ot = inp
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_sc = jnp.exp(it - m_new)
        f_sc = jnp.exp(logf + m - m_new)
        c = f_sc * c + i_sc * zt
        n = f_sc * n + i_sc
        h = ot * c / jnp.maximum(n, 1.0)
        return (c, n, m_new), h

    zero = jnp.zeros((b, d), jnp.float32)
    (c, n, m), hs = jax.lax.scan(
        step, (zero, zero, zero - 1e30),
        (jnp.moveaxis(z, 1, 0), jnp.moveaxis(i_pre, 1, 0),
         jnp.moveaxis(f_pre, 1, 0), jnp.moveaxis(o, 1, 0)))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    return rmsnorm(p["norm"], y, cfg.norm_eps) @ p["out"]


def slstm_decode(p, cfg, x, state):
    """One sLSTM step with carried (c, n, m) state. x: [B, 1, d]."""
    b, _, d = x.shape
    xt = x[:, 0]
    z = jnp.tanh(xt @ p["wz"]).astype(jnp.float32)
    it = (xt.astype(jnp.float32) @ p["wi"])
    ft = (xt.astype(jnp.float32) @ p["wf"])
    o = jax.nn.sigmoid(xt @ p["wo"]).astype(jnp.float32)
    c, n, m = state["c"], state["n"], state["m"]
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_sc = jnp.exp(it - m_new)
    f_sc = jnp.exp(logf + m - m_new)
    c = f_sc * c + i_sc * z
    n = f_sc * n + i_sc
    h = (o * c / jnp.maximum(n, 1.0)).astype(x.dtype)
    y = rmsnorm(p["norm"], h[:, None], cfg.norm_eps) @ p["out"]
    return y, {"c": c, "n": n, "m": m_new}


def mlstm_decode(p, cfg, x, state):
    """One-token mLSTM decode. state: dict(h [B,H,N,P], m [B,H], n [B,H,N])."""
    b, _, d = x.shape
    heads, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, heads, hd) / (hd ** 0.5)
    k = (x @ p["wk"]).reshape(b, heads, hd)
    v = (x @ p["wv"]).reshape(b, heads, hd)
    logf = jax.nn.log_sigmoid(x.astype(jnp.float32) @ p["wf"])[:, 0]  # [B,H]
    i_gate = jnp.exp(jnp.minimum(x.astype(jnp.float32) @ p["wi"], 8.0))[:, 0]
    k = k * i_gate[..., None].astype(k.dtype)
    hnew = state["h"] * jnp.exp(logf)[..., None, None] + \
        jnp.einsum("bhn,bhp->bhnp", k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), hnew)
    o = jax.nn.sigmoid(x @ p["wo_gate"]).reshape(b, heads, hd)
    y = (y.astype(x.dtype) * o).reshape(b, 1, heads * hd)
    out = rmsnorm(p["norm"], y, cfg.norm_eps) @ p["out"]
    return out, {"h": hnew, "m": state["m"], "n": state["n"]}


def mlstm_init_state(cfg, batch):
    heads, hd = cfg.n_heads, cfg.hd
    return {"h": jnp.zeros((batch, heads, hd, hd), jnp.float32),
            "m": jnp.zeros((batch, heads), jnp.float32),
            "n": jnp.zeros((batch, heads, hd), jnp.float32)}
