"""GQA attention block: train (chunked-online-softmax or Pallas kernel) and
decode (KV cache) paths.

Implementation selection:
  * 'ref'     — materialized scores; small shapes (smoke tests)
  * 'chunked' — scan over query blocks with online softmax: the pure-XLA
                mirror of the flash kernel. Used by the dry-run so HLO
                bytes reflect flash-style O(S·D) memory, not O(S²).
  * 'kernel'  — kernels/flash_attention (TPU execution path)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import os

from ..kernels.flash_attention.ops import gqa_attention
from ..kernels.flash_attention.ref import attention_ref
from .layers import apply_rope, dense_init, maybe_constrain, rmsnorm, rmsnorm_init

NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(p, cfg, x, positions):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if positions is not None:   # rope (decoder); None for encoder w/o rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q, k, v, *, causal: bool, q_chunk: int = 512,
                      k_chunk: int = 1024):
    """[B,H,S,D] online-softmax attention, O(chunk·S) live memory.
    Mirrors the Pallas kernel so the dry-run HLO carries flash-like bytes."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, skv)
    scale = 1.0 / (d ** 0.5)
    nq = sq // q_chunk
    nk = skv // k_chunk
    offset = skv - sq
    qr = q.reshape(b, h, nq, q_chunk, d)

    def q_block(qi, qb):
        # qb: [B,H,Cq,D]
        def kv_step(carry, ki):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, ki * k_chunk, k_chunk, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(v, ki * k_chunk, k_chunk, axis=2)
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, ks,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                rows = qi * q_chunk + offset + jnp.arange(q_chunk)[:, None]
                cols = ki * k_chunk + jnp.arange(k_chunk)[None, :]
                s = jnp.where(rows >= cols, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vs.dtype), vs,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((b, h, q_chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(lambda c, i: kv_step(c, i),
                                      (m0, l0, a0), jnp.arange(nk))
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

    out = jax.lax.map(lambda i: q_block(i, qr[:, :, i]), jnp.arange(nq))
    # [nq, B, H, Cq, D] → [B, H, S, D]
    return jnp.moveaxis(out, 0, 2).reshape(b, h, sq, d)


def _repeat_kv(k, groups):
    return jnp.repeat(k, groups, axis=1)


def attention_block(p, cfg, x, positions, *, causal=True, impl="ref",
                    kv=None):
    """Self-attention. kv: optional (k_ext, v_ext) to attend over instead
    (cross-attention); x provides queries only in that case."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    if kv is not None:
        k, v = kv
    q = q.transpose(0, 2, 1, 3)                 # [B,H,S,D]
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if os.environ.get("REPRO_ATTN_SHARD") == "seq":
        # §Perf H2: context parallelism — shard SEQ over 'model' during
        # attention (heads often don't divide the model axis; seq always
        # does). One planned gather per layer replaces per-chunk reshards.
        q = maybe_constrain(q, ("pod", "data"), None, "model", None)
        k = maybe_constrain(k, ("pod", "data"), None, "model", None)
        v = maybe_constrain(v, ("pod", "data"), None, "model", None)
    groups = cfg.n_heads // cfg.n_kv_heads
    if impl == "kernel":
        o = gqa_attention(q, k, v, causal=causal)   # handles GQA repeat
        o = o.transpose(0, 2, 1, 3)
    else:
        k = _repeat_kv(k, groups)
        v = _repeat_kv(v, groups)
        if impl == "chunked":
            o = chunked_attention(q, k, v, causal=causal)
        else:
            bh = b * cfg.n_heads
            o = attention_ref(q.reshape(bh, s, cfg.hd),
                              k.reshape(bh, -1, cfg.hd),
                              v.reshape(bh, -1, cfg.hd), causal=causal)
            o = o.reshape(b, cfg.n_heads, s, cfg.hd)
        o = o.transpose(0, 2, 1, 3)
    o = o.reshape(b, s, cfg.n_heads * cfg.hd)
    return o @ p["wo"]


def attention_decode(p, cfg, x, cache, pos):
    """One-token decode with a static KV cache.

    x: [B, 1, d]; cache: dict(k, v: [B, S_cache, Hkv, D], length: [] int);
    pos: [] int32 current position. Returns (out [B,1,d], new cache)."""
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(p, cfg, x, pos[None, None].astype(jnp.int32)
                                   * jnp.ones((b, 1), jnp.int32))
    k_cache = cache["k"].at[:, cache["length"]].set(k_new[:, 0])
    v_cache = cache["v"].at[:, cache["length"]].set(v_new[:, 0])
    groups = cfg.n_heads // cfg.n_kv_heads
    qh = q.transpose(0, 2, 1, 3)                              # [B,H,1,D]
    kh = _repeat_kv(k_cache.transpose(0, 2, 1, 3), groups)    # [B,H,S,D]
    vh = _repeat_kv(v_cache.transpose(0, 2, 1, 3), groups)
    scale = 1.0 / (cfg.hd ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", qh, kh,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(kh.shape[2])[None, None, None, :] <= cache["length"]
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(vh.dtype)
    o = jnp.einsum("bhqk,bhkd->bhqd", w, vh)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.hd)
    new_cache = {"k": k_cache, "v": v_cache, "length": cache["length"] + 1}
    return o @ p["wo"], new_cache


def init_kv_cache(cfg, batch, max_len, dtype):
    return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
            "length": jnp.zeros((), jnp.int32)}
