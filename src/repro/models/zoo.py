"""Architecture zoo: uniform entry points keyed by config.

    model = zoo.build(cfg)
    params = model.init(key)
    logits, aux = model.forward(params, batch)     # training path
    cache = model.init_cache(batch, max_len)
    logits, cache = model.decode_step(params, tok, cache, pos)
"""
from __future__ import annotations

import dataclasses
from typing import Callable


from ..configs.base import ModelConfig
from . import encdec, transformer


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable            # (params, batch_dict, impl=...) → (logits, aux)
    init_cache: Callable
    decode_step: Callable


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        def fwd(params, batch, impl="ref", remat=True, last_only=False):
            return encdec.forward(params, cfg, batch["embeds"],
                                  batch["tokens"], impl=impl, remat=remat,
                                  last_only=last_only)

        def dec(params, tokens, cache, pos, impl="ref"):
            return encdec.decode_step(params, cfg, tokens, cache, pos, impl=impl)

        return Model(
            cfg=cfg,
            init=lambda key: encdec.init(key, cfg),
            forward=fwd,
            init_cache=lambda batch, max_len, enc_len=None: encdec.init_cache(
                cfg, batch, max_len, enc_len or max_len),
            decode_step=dec,
        )

    def fwd(params, batch, impl="ref", remat=True, last_only=False):
        if cfg.input_kind == "embeddings":
            return transformer.forward(params, cfg, embeds=batch["embeds"],
                                       impl=impl, remat=remat,
                                       last_only=last_only)
        return transformer.forward(params, cfg, tokens=batch["tokens"],
                                   impl=impl, remat=remat, last_only=last_only)

    return Model(
        cfg=cfg,
        init=lambda key: transformer.init(key, cfg),
        forward=fwd,
        init_cache=lambda batch, max_len: transformer.init_cache(cfg, batch, max_len),
        decode_step=lambda params, tokens, cache, pos: transformer.decode_step(
            params, cfg, tokens, cache, pos),
    )
