"""Shared layers: norms, RoPE, SwiGLU MLP, embeddings.

Pure functions over parameter pytrees (nested dicts of jnp arrays). Layer
stacks are scan-compatible: per-layer params carry a leading [L] dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


_CONSTRAINT_MESH = [None]


def set_constraint_mesh(mesh):
    """Install the mesh activation constraints target (launch layer calls
    this before lowering; None disables — single-device tests)."""
    _CONSTRAINT_MESH[0] = mesh


def maybe_constrain(x, *spec):
    """with_sharding_constraint against the installed mesh; no-op in
    single-device tests. Spec entries naming axes absent from the mesh
    (e.g. 'pod' on a single-pod mesh) degrade to replication. §Perf lever:
    pins activation layouts so GSPMD does one planned collective instead of
    per-op reshards."""
    import os
    mesh = _CONSTRAINT_MESH[0]
    if mesh is None or os.environ.get("REPRO_NO_CONSTRAIN"):
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in names else None
        kept = tuple(a for a in entry if a in names)
        return kept if kept else None

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*(keep(e) for e in spec))))


# --- init helpers -------------------------------------------------------------

def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --- RMSNorm -------------------------------------------------------------------

def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --- RoPE ---------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)          # [D/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs       # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                                # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- SwiGLU MLP ------------------------------------------------------------------

def mlp_init(key, d, ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, (d, ff), dtype),
            "w_up": dense_init(k2, (d, ff), dtype),
            "w_down": dense_init(k3, (ff, d), dtype)}


def mlp(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# --- stacking utilities -------------------------------------------------------

def stack_layers(key, n_layers, init_fn):
    """Stacked per-layer params with leading [L] dim (scan-ready)."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_fn)(keys)


def layer_slice(params, i):
    return jax.tree.map(lambda x: x[i], params)
