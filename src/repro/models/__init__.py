from . import attention, encdec, layers, moe, ssm, transformer, zoo
from .zoo import Model, build

__all__ = ["attention", "encdec", "layers", "moe", "ssm", "transformer",
           "zoo", "Model", "build"]
