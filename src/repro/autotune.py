"""Schedule autotuner: search the `Schedule` space per (program, graph).

The algorithm/schedule split (``repro.schedule``) makes execution strategy
an explicit, hashable value — but until now someone still had to *pick*
the bucket layout, push/pull threshold, batch width, and kernel block
sizes per graph, and the winning choice is graph-dependent (GraphIt's
observation, reproduced in ``BENCH_frontier.json``: power-law graphs love
deep bucket layouts and direction switching, road graphs don't care).
This module closes the loop:

  1. **Search space** — `search_space(stats)` derives candidate schedules
     from `Schedule`'s own fields (bucket layouts, `push_threshold_frac`,
     `direction`, `batch_sources`, per-bucket `block_rows`), pruned by the
     graph statistics a `GraphContext` computes (`ctx.stats()`: degree
     skew/CV + a frontier-growth BFS probe), so a power-law and a road
     graph start from different candidate sets.
  2. **Measure loop** — each trial recompiles the program under a
     candidate schedule through the PR-3 compile cache (a repeated trial
     is a cache hit — across tuning runs too) and times `prog.bind(g)`
     executions with warm-up, taking the min over repetitions.
  3. **Persistence** — results land in a `TuningRecord` keyed by
     ``(source digest, backend, graph fingerprint)`` that round-trips
     through JSON via `TuningStore`, so a server process tunes once and
     reloads thereafter; a stored record whose digest or fingerprint no
     longer matches (the program or the graph changed) is rejected and
     re-tuned rather than silently replayed.

Entry point::

    from repro.autotune import autotune
    result = autotune(prog, g, budget=16)        # result.schedule is best
    tuned  = result.program.bind(g)              # compiled under it

Determinism: given the same graph, seed, and budget, the candidate list,
trial order, and tie-breaking are all deterministic; with a deterministic
``measure=`` hook the chosen schedule is exactly reproducible (tested).
The default (wall-clock) measurement keeps the guarantee that the chosen
schedule is never *measured-worse* than the baseline, because the
program's own schedule is always trial #0 and ties break toward the
earliest trial.

See ``docs/schedule.md`` for the knob reference and perf guidance, and
``docs/architecture.md`` for where tuning sits in the pipeline.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Callable, List, Optional, Union

import numpy as np

from .core.analysis import ERROR, check_schedule, program_analysis
from .core.api import CompiledProgram
from .core.context import get_context
from .schedule import LANE_MULTIPLE, Schedule

RECORD_VERSION = 1

# stats thresholds the pruning branches on (see GraphContext.stats())
_SKEWED_CV = 0.5          # degree CV above this = power-law-like
_SKEWED_MAX_RATIO = 4.0   # max_degree / avg_degree above this = hubby
_FLAT_FRONTIER = 1.0 / 16.0  # peak frontier frac below this = always-sparse
_DEEP_PROBE = 32          # BFS probe depth at/over this = high-diameter
#                           (road-like) graph: delta-stepping candidates on


def source_digest(source: str) -> str:
    """Stable 16-hex-char digest of a DSL source text (TuningRecord key)."""
    return hashlib.sha256(source.encode()).hexdigest()[:16]


def schedule_to_dict(s: Schedule) -> dict:
    return dataclasses.asdict(s)


def schedule_from_dict(d: dict) -> Schedule:
    """Inverse of `schedule_to_dict`, tolerant of JSON round-trips (list →
    tuple normalization happens in `Schedule.__post_init__`)."""
    fields = {f.name for f in dataclasses.fields(Schedule)}
    unknown = set(d) - fields
    if unknown:
        raise ValueError(
            f"unknown Schedule fields in stored record: {sorted(unknown)} "
            "(the record predates or postdates this Schedule version)")
    return Schedule(**d)


# --------------------------------------------------------------------------
# search space
# --------------------------------------------------------------------------

def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _with_layout(base: Schedule, num_buckets: int, min_width: int,
                 growth: int) -> Schedule:
    # a per-bucket block_rows tuple is tied to the old bucket count —
    # collapse it to a uniform cap before changing the layout
    br = base.block_rows if isinstance(base.block_rows, int) \
        else max(base.block_rows)
    return base.replace(num_buckets=num_buckets, min_width=min_width,
                        growth=growth, block_rows=br)


def search_space(stats: dict, base: Optional[Schedule] = None, *,
                 tune_batch: bool = False,
                 backend: str = "local") -> List[Schedule]:
    """Candidate schedules for a graph with these statistics.

    Deterministic and pruned: the base schedule is always candidate #0
    (so the tuner can never return something measured-worse than it), and
    the variants explored depend on what `stats` say about the graph —
    one knob dimension is varied at a time around the base rather than a
    full cross product, keeping the list measurable within a small budget.

    `tune_batch=True` adds `batch_sources` variants (only meaningful for
    programs with a source-set loop; the caller knows from the IR).

    `backend="distributed"` explores the distributed knob plane instead of
    the single-device layout/kernel knobs: the frontier-exchange policy
    (`dist_frontier` x `dist_gather_frac`), the relax/BFS `direction`, and
    the source-batch width. The base (by default the dense-gather paper
    schedule) stays candidate #0 there too.
    """
    base = Schedule() if base is None else base
    if backend == "distributed":
        return _dist_search_space(stats, base, tune_batch=tune_batch)
    cands: List[Schedule] = [base]

    skewed = (stats.get("deg_cv", 0.0) >= _SKEWED_CV
              or stats.get("skew", 1.0) >= _SKEWED_MAX_RATIO)
    flat = stats.get("probe_max_frontier_frac", 1.0) <= _FLAT_FRONTIER
    max_deg = max(stats.get("max_in_degree", 1),
                  stats.get("max_out_degree", 1))

    # ---- bucket layout: skewed graphs explore depth, uniform graphs
    # collapse to one bucket sized to the (narrow) degree range ----------
    if skewed:
        layouts = [(4, 8, 4), (5, 8, 4), (3, 8, 8), (4, 16, 4)]
    else:
        w = max(_round_up(max_deg, LANE_MULTIPLE), LANE_MULTIPLE)
        layouts = [(1, min(w, 512), 2), (2, 8, 4)]
    for nb, mw, gr in layouts:
        cands.append(_with_layout(base, nb, mw, gr))

    # ---- direction policy + push threshold -----------------------------
    if flat:
        # the frontier never grows past the default threshold: every auto
        # step would push anyway — pin it and drop the occupancy test
        cands.append(base.replace(direction="push"))
        cands.append(base.replace(direction="auto",
                                  push_threshold_frac=1.0 / 4.0))
    else:
        cands.append(base.replace(direction="pull"))
        for frac in (1.0 / 64.0, 1.0 / 4.0):
            cands.append(base.replace(direction="auto",
                                      push_threshold_frac=frac))

    # ---- priority policy (delta-stepping) ------------------------------
    # only worth measuring on high-diameter weighted graphs (road/grid):
    # there the monotonic relax runs hundreds of near-empty sweeps that a
    # bucketed frontier turns into a handful of compact-relax phases. The
    # candidate bucket widths are multiples of the mean edge weight — a
    # bucket then spans roughly that many relaxed hops.
    avg_w = stats.get("avg_weight", 0.0)
    if stats.get("probe_depth", 0) >= _DEEP_PROBE and avg_w > 0:
        for mult in (16, 64):
            cands.append(base.replace(priority="delta",
                                      delta_bucket=max(int(avg_w * mult), 1)))

    # ---- kernel row-block caps (pallas buckets) ------------------------
    for br in (64, 1024):
        if br != base.block_rows:
            cands.append(base.replace(block_rows=br))

    # ---- source-batch width (programs with a set loop only) ------------
    if tune_batch:
        for bs in (8, 16, 64):
            if bs != base.batch_sources:
                cands.append(base.replace(batch_sources=bs))

    # dedup, order-preserving (Schedule is hashable by design)
    return _dedup(cands)


def _dist_search_space(stats: dict, base: Schedule, *,
                       tune_batch: bool = False) -> List[Schedule]:
    """Distributed candidates: gather policy x direction x batch width.

    The dense full-gather base comes first (nothing can measure worse than
    the paper's scheme); the compact/auto exchange variants pay off when
    frontiers stay small relative to `dist_gather_frac` x block, so the
    always-sparse graphs also try a tighter buffer."""
    cands: List[Schedule] = [base]
    flat = stats.get("probe_max_frontier_frac", 1.0) <= _FLAT_FRONTIER

    # ---- frontier-exchange policy ---------------------------------------
    for pol in ("auto", "compact"):
        cands.append(base.replace(dist_frontier=pol))
    if flat:
        # frontiers never grow: a tighter compact buffer still fits and
        # halves the per-superstep volume again
        cands.append(base.replace(dist_frontier="auto",
                                  dist_gather_frac=1.0 / 16.0))
    else:
        cands.append(base.replace(dist_frontier="auto",
                                  dist_gather_frac=3.0 / 8.0))

    # ---- relax/BFS direction --------------------------------------------
    for d in ("pull", "push"):
        cands.append(base.replace(direction=d))
    # the combination the volume model predicts: compressed exchange plus
    # the combine-free pull superstep
    cands.append(base.replace(dist_frontier="auto", direction="pull"))

    # ---- priority policy (delta-stepping + priority-sliced exchange) ----
    avg_w = stats.get("avg_weight", 0.0)
    if stats.get("probe_depth", 0) >= _DEEP_PROBE and avg_w > 0:
        cands.append(base.replace(priority="delta",
                                  delta_bucket=max(int(avg_w * 16), 1),
                                  dist_frontier="auto"))

    # ---- source-batch width (programs with a set loop only) --------------
    if tune_batch:
        for bs in (0, 8, 64):
            if bs != base.batch_sources:
                cands.append(base.replace(batch_sources=bs))
    return _dedup(cands)


def _dedup(cands: List[Schedule]) -> List[Schedule]:
    seen, out = set(), []
    for c in cands:
        if c not in seen:
            seen.add(c)
            out.append(c)
    return out


def _has_set_param(prog: CompiledProgram) -> bool:
    return any(p.kind == "set_n" for p in prog.ir.params)


# well-known scalar names across the bundled programs (PR's damping etc.);
# anything unknown gets a safe small positive value
_SCALAR_DEFAULTS = {"beta": 1e-4, "delta": 0.85, "maxiter": 20}


def default_params(prog: CompiledProgram, g, *, seed: int = 0,
                   num_sources: int = 16) -> dict:
    """Representative call parameters derived from the program's IR params:
    node params get vertex 0, source sets a seeded random batch, scalars a
    named default (`beta`/`delta`/`maxIter`) or 1. Property params stay
    unset (the generated code initializes them)."""
    rng = np.random.default_rng(seed)
    params: dict = {}
    for p in prog.ir.params[1:]:
        if p.kind == "node_param":
            params[p.name] = 0
        elif p.kind == "set_n":
            # without replacement: a duplicated source would fill two batch
            # lanes with the same query (and break set-semantics programs
            # like BC that accumulate one contribution per distinct source)
            params[p.name] = rng.choice(
                g.num_nodes, size=min(num_sources, g.num_nodes),
                replace=False).astype(np.int32)
        elif p.kind == "scalar":
            v = _SCALAR_DEFAULTS.get(p.name.lower(), 1)
            params[p.name] = int(v) if p.dtype == "int32" else float(v)
    return params


# --------------------------------------------------------------------------
# measurement
# --------------------------------------------------------------------------

def _block_on(out):
    """Force completion of whatever the program returned (dict of arrays)."""
    import jax
    jax.block_until_ready(out)
    return out


def measure_wallclock(bound, params: dict, *, warmup: int = 1,
                      reps: int = 3) -> float:
    """min-of-`reps` wall-clock seconds for one `bound(**params)` call,
    after `warmup` untimed calls (the first pays the jit trace)."""
    for _ in range(max(warmup, 0)):
        _block_on(bound(**params))
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        _block_on(bound(**params))
        best = min(best, time.perf_counter() - t0)
    return best


# --------------------------------------------------------------------------
# records + store
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TuningRecord:
    """One finished tuning run, JSON-serializable.

    Keyed by ``(source_digest, backend, graph_fingerprint)``: the digest
    pins the *algorithm text*, the fingerprint pins the *graph contents*
    — if either changed since the record was written, replaying the
    stored schedule would be tuning for a different problem, so lookups
    reject the record and the caller re-tunes."""

    source_digest: str
    backend: str
    graph_fingerprint: str
    fn_name: str
    schedule: dict             # the chosen schedule, as a plain dict
    best_ms: float
    default_ms: float          # trial #0 = the program's own schedule
    trials: list               # [{"schedule": dict, "ms": float}, ...]
    budget: int
    seed: int
    graph_stats: dict = dataclasses.field(default_factory=dict)
    pruned_candidates: int = 0  # statically illegal schedules skipped unmeasured
    # cost-model provenance: fingerprint of the stats-nearest neighbor graph
    # whose best schedule seeded trial #0 ("" = unseeded run). Each trial
    # dict also carries "source": "seeded" | "search".
    seeded_from: str = ""
    version: int = RECORD_VERSION

    def key(self) -> tuple:
        return (self.source_digest, self.backend, self.graph_fingerprint)

    def best_schedule(self) -> Schedule:
        return schedule_from_dict(self.schedule)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TuningRecord":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "TuningRecord":
        return cls.from_dict(json.loads(text))


def _read_records(path: Optional[str]) -> dict:
    """Parse a store file into a {key: TuningRecord} dict. Malformed files
    or records read as empty/skipped (a miss, never a crash)."""
    records: dict = {}
    if not path or not os.path.exists(path):
        return records
    try:
        with open(path) as f:
            data = json.load(f)
        raw = data.get("records", [])
    except (json.JSONDecodeError, AttributeError, OSError):
        return records
    for d in raw:
        try:
            rec = TuningRecord.from_dict(d)
            records[rec.key()] = rec
        except (TypeError, ValueError):
            continue   # skip the damaged record, keep the rest
    return records


class TuningStore:
    """JSON-file-backed map of `TuningRecord`s.

    A server process points this at a path, calls `autotune(..., store=...)`
    once per (program, graph), and every later process start is a lookup
    instead of a measurement sweep. Lookups are strict: a record is
    returned only when its stored digest/fingerprint/version equal the
    requested key — anything else (edited source, regenerated graph,
    tampered or stale file) is a miss, so the caller re-tunes."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._records: dict = {}
        if path and os.path.exists(path):
            self.load()

    def load(self) -> None:
        """Read the store file; malformed content is a miss, not a crash —
        an unparseable file or record means "never tuned", so the caller
        re-measures and the next `save()` rewrites a clean file."""
        self._records = _read_records(self.path)

    def save(self, *, merge: bool = True) -> None:
        """Persist the store, safely under concurrent writers.

        Two protections (two servers sharing one store file must not
        truncate each other's records):

        * **reload-merge** — the on-disk records are re-read and merged
          under this store's records (memory wins on key conflicts; both
          stores' disjoint records survive an interleaved save-save), so a
          writer that loaded an older file never blindly overwrites what a
          peer tuned since. `merge=False` restores the overwrite semantics
          (explicitly pruning a store).
        * **atomic write** — the merged file is written to a
          writer-unique temp name and `os.replace`d into place, so a
          reader (or a crashed writer) can never observe a torn file.
        """
        if not self.path:
            return
        if merge:
            disk = _read_records(self.path)
            disk.update(self._records)
            self._records = disk
        data = {"version": RECORD_VERSION,
                "records": [r.to_dict() for r in self._records.values()]}
        tmp = f"{self.path}.{os.getpid()}.{id(self):x}.tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(data, f, indent=2)
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def lookup(self, digest: str, backend: str,
               fingerprint: str) -> Optional[TuningRecord]:
        rec = self._records.get((digest, backend, fingerprint))
        if rec is None:
            return None
        # strict validation: a record is only trusted if its own fields
        # restate the key it is filed under and its version is current
        if (rec.source_digest != digest or rec.backend != backend
                or rec.graph_fingerprint != fingerprint
                or rec.version != RECORD_VERSION):
            return None
        return rec

    def put(self, rec: TuningRecord) -> None:
        self._records[rec.key()] = rec

    def records(self) -> List[TuningRecord]:
        """All records, in deterministic (sorted-key) order."""
        return [self._records[k] for k in sorted(self._records)]

    def __len__(self) -> int:
        return len(self._records)


# --------------------------------------------------------------------------
# cost-model seeding (nearest-stats-neighbor warm starts)
# --------------------------------------------------------------------------

# size-like stats compare on a log scale (a 1k- and a 2k-node graph are
# "close"; a 1k- and a 1M-node graph are not, whatever the linear gap says);
# ratio/fraction stats are already scale-free and compare linearly
_SEED_LOG_FEATURES = ("num_nodes", "num_edges", "avg_degree",
                      "max_out_degree", "max_in_degree", "skew",
                      "avg_weight", "probe_depth")
_SEED_LIN_FEATURES = ("deg_cv", "probe_max_frontier_frac",
                      "probe_growth", "probe_reach_frac")


def stats_distance(a: dict, b: dict) -> float:
    """Normalized distance between two `GraphContext.stats()` dicts —
    the cost model's notion of "graphs this schedule should transfer to"."""
    import math
    d = 0.0
    for k in _SEED_LOG_FEATURES:
        fa = math.log1p(abs(float(a.get(k, 0.0))))
        fb = math.log1p(abs(float(b.get(k, 0.0))))
        d += (fa - fb) ** 2
    for k in _SEED_LIN_FEATURES:
        d += (float(a.get(k, 0.0)) - float(b.get(k, 0.0))) ** 2
    return math.sqrt(d)


def nearest_record(store: TuningStore, digest: str, backend: str,
                   stats: dict) -> Optional[TuningRecord]:
    """The store record for the same (program, backend) whose graph stats
    are nearest to `stats`, or None when the store has nothing comparable.
    Deterministic: ties break toward the smaller fingerprint (store order
    is sorted)."""
    best, best_d = None, float("inf")
    for rec in store.records():
        if rec.source_digest != digest or rec.backend != backend \
                or not rec.graph_stats:
            continue
        d = stats_distance(stats, rec.graph_stats)
        if d < best_d:
            best, best_d = rec, d
    return best


# --------------------------------------------------------------------------
# the tuner
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TuningResult:
    """What `autotune` returns: the winning schedule, the program compiled
    under it (a compile-cache resident), and the full record (also in the
    store, if one was given). `from_store` is True when no measurement ran
    because a valid persisted record answered the query."""

    schedule: Schedule
    program: CompiledProgram
    record: TuningRecord
    from_store: bool = False

    @property
    def speedup(self) -> float:
        """default-schedule time / best time (>= 1.0 by construction when
        measured; whatever the stored record says on a store hit)."""
        return (self.record.default_ms / self.record.best_ms
                if self.record.best_ms else 1.0)


def autotune(prog: CompiledProgram, g, *, budget: int = 16, seed: int = 0,
             params: Optional[dict] = None,
             warmup: int = 1, reps: int = 3,
             measure: Optional[Callable] = None,
             store: Union[TuningStore, str, None] = None,
             verbose: bool = False) -> TuningResult:
    """Search the `Schedule` space for `prog` on `g`; return the best.

    * `budget` caps the number of measured candidates (trial #0 is always
      the program's own schedule, so the result is never measured-worse
      than the baseline).
    * `params` are the call parameters to time with; omitted, they are
      derived from the program's IR (`default_params`).
    * `measure(bound, params) -> seconds` replaces the wall-clock timer
      (tests inject a deterministic cost model here).
    * `store` (a `TuningStore` or a path) persists the result; a valid
      stored record for (source digest, backend, graph fingerprint) skips
      measurement entirely, and a record whose digest or fingerprint no
      longer matches is ignored and re-tuned. On a miss, records for the
      same (program, backend) on OTHER graphs act as a cost model: the
      stats-nearest neighbor's winning schedule is measured first as a
      seeded trial #0 (`TuningRecord.seeded_from` + per-trial "source"
      record the provenance), with the program's own schedule still
      measured right behind it.

    Deterministic given (graph, seed, budget) and a deterministic
    `measure`: candidate order, truncation, and tie-breaking (earliest
    trial wins) contain no randomness beyond the seeded param draw.
    """
    if not prog.dsl_source:
        raise ValueError(
            "program has no dsl_source to recompile under candidate "
            "schedules (compile it via compile_program/compile_bundled)")
    ctx = get_context(g)
    digest = source_digest(prog.dsl_source)
    fingerprint = ctx.fingerprint()

    if isinstance(store, str):
        store = TuningStore(store)
    if store is not None:
        rec = store.lookup(digest, prog.backend, fingerprint)
        if rec is not None:
            try:
                sched = rec.best_schedule()
            except ValueError:
                sched = None   # stored schedule invalid here -> re-tune
            if sched is not None:
                return TuningResult(schedule=sched,
                                    program=prog.recompile(sched),
                                    record=rec, from_store=True)

    stats = ctx.stats()
    fx = program_analysis(prog.dsl_source).functions.get(prog.name)

    # ---- cost-model seeding: on a store *miss*, the record for the
    # stats-nearest graph tuned under the same (program, backend) proposes
    # its winning schedule as trial #0 — a warm start for unseen graphs.
    # The program's own schedule is still always measured (it follows the
    # seed in the candidate list), so seeding can propose but never force:
    # the result is never measured-worse than the unseeded path.
    seeded_from = ""
    seeds: List[Schedule] = []
    if store is not None and budget >= 2:
        neighbor = nearest_record(store, digest, prog.backend, stats)
        if neighbor is not None:
            try:
                ssched = neighbor.best_schedule()
            except ValueError:
                ssched = None      # foreign Schedule version -> no seed
            if ssched is not None and not (fx is not None and any(
                    d.severity == ERROR
                    for d in check_schedule(fx, ssched, prog.backend))):
                seeds = [ssched]
                seeded_from = neighbor.graph_fingerprint
                if verbose:
                    print(f"  seeding trial 0 from neighbor "
                          f"{seeded_from}: {ssched}")

    cands = _dedup(seeds + search_space(
        stats, base=prog.schedule, tune_batch=_has_set_param(prog),
        backend=prog.backend))
    # static legality pruning: candidates the analysis layer can reject
    # (e.g. priority="delta" on a program with no monotone Min relax) are
    # dropped before any trial budget is spent measuring them. Trial #0 —
    # the program's own schedule — already passed the compile gate, so the
    # baseline is never pruned (and the seed, if any, was vetted above).
    pruned = 0
    if fx is not None:
        legal = []
        for cand in cands:
            if any(d.severity == ERROR
                   for d in check_schedule(fx, cand, prog.backend)):
                pruned += 1
            else:
                legal.append(cand)
        cands = legal
    if verbose and pruned:
        print(f"  pruned {pruned} statically illegal candidate(s)")
    if budget < 1:
        raise ValueError(f"budget must be >= 1, got {budget}")
    cands = cands[:budget]
    if params is None:
        params = default_params(prog, g, seed=seed)
    if measure is None:
        def measure(bound, p, _w=warmup, _r=reps):
            return measure_wallclock(bound, p, warmup=_w, reps=_r)

    trials = []
    best_i, best_s = 0, float("inf")
    for i, cand in enumerate(cands):
        trial = prog.recompile(cand)       # compile-cache hit when seen
        secs = float(measure(trial.bind(g), params))
        trials.append({"schedule": schedule_to_dict(cand),
                       "ms": round(1e3 * secs, 4),
                       "source": ("seeded" if seeded_from and i == 0
                                  else "search")})
        if secs < best_s:                  # strict <: earliest trial wins ties
            best_i, best_s = i, secs
        if verbose:
            mark = " <-- best" if best_i == i else ""
            print(f"  trial {i:2d}: {1e3 * secs:9.2f} ms  {cand}{mark}")

    best = cands[best_i]
    # default_ms keys off the program's OWN schedule (trial #0 when
    # unseeded; trial #1 behind the seed otherwise)
    base_i = cands.index(prog.schedule) if prog.schedule in cands else 0
    record = TuningRecord(
        source_digest=digest, backend=prog.backend,
        graph_fingerprint=fingerprint, fn_name=prog.name,
        schedule=schedule_to_dict(best),
        best_ms=trials[best_i]["ms"], default_ms=trials[base_i]["ms"],
        trials=trials, budget=budget, seed=seed, graph_stats=dict(stats),
        pruned_candidates=pruned, seeded_from=seeded_from)
    if store is not None:
        store.put(record)
        store.save()
    return TuningResult(schedule=best, program=prog.recompile(best),
                        record=record)
