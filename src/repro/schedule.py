"""Schedule: the explicit, per-compile tuning surface of the engine.

StarPlat's premise is one algorithmic specification lowered to multiple
backends; GraphIt showed that the *schedule* — how that specification is
executed — must be a first-class object separate from the algorithm for
per-program tuning (and autotuning) to work. A `Schedule` captures every
knob of the frontier-aware, degree-bucketed execution engine as a frozen,
hashable value:

  * it threads through ``compile_program(source, backend, schedule=...)``
    into code generation, where the knobs are baked into the generated
    source as literals (same ``Schedule`` => byte-identical source);
  * it keys the compile cache, so two programs compiled under different
    schedules coexist in one process;
  * its layout fields key the per-graph derived structures owned by
    ``repro.core.context.GraphContext``.

The old module-level ``repro.graph.ENGINE`` singleton is a deprecated shim
that materializes a ``Schedule`` via ``ENGINE.snapshot()`` at compile /
prepare time; mutating it after compile never changes a compiled program.

This module is intentionally dependency-free (no jax, no repro imports) so
every layer — graph views, runtime, codegen, kernels — can use it.

Knob-by-knob reference (type, default, valid range, consuming backend,
measured perf guidance): ``docs/schedule.md`` — its table is asserted
against ``dataclasses.fields(Schedule)`` by tests/test_docs.py, so the
two cannot drift. ``repro.autotune`` searches this space per graph.
"""
from __future__ import annotations

import dataclasses
import numbers

# TPU VPU lanes are 8x128; bucket widths (and row padding) must stay a
# multiple of the sublane count so every bucket tile stays vector-aligned.
LANE_MULTIPLE = 8

_DIRECTIONS = ("auto", "push", "pull")
_DIST_FRONTIERS = ("dense", "compact", "auto")
_PRIORITIES = ("none", "delta")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Frozen engine configuration for one compiled program.

    Fields
    ------
    num_buckets:
        Degree buckets in the sliced-ELL view (>= 1).
    min_width:
        Width of the narrowest bucket; a positive multiple of
        ``LANE_MULTIPLE`` (8) so tiles stay VPU-aligned.
    growth:
        Geometric width growth between buckets; an integer > 1.
    push_threshold_frac:
        Frontier occupancy (as a fraction of N, in [0, 1]) below which a
        relax/BFS step runs push-style (scatter from the few active
        sources) instead of pull (gather/kernel over in-edges). Only
        consulted when ``direction == "auto"``.
    batch_sources:
        Sources traversed per batched chunk in ``forall(src in sourceSet)``
        (>= 0; 0 or 1 disables batching — sequential per-source loop).
    direction:
        Traversal direction policy: ``"auto"`` switches push/pull on-device
        by frontier occupancy; ``"push"`` / ``"pull"`` pin one direction.
        Both directions compute the identical relaxation, so pinning never
        changes results — only the execution schedule.
    block_rows:
        Row-block (grid tile height) cap for the per-bucket ELL kernels on
        the pallas backend: either one int (uniform cap for every bucket)
        or a tuple of per-bucket caps of length ``num_buckets``. Each cap
        must be a positive multiple of ``LANE_MULTIPLE`` (8); the kernel
        launcher picks the largest power-of-two block <= the cap that
        divides the bucket's (8-aligned) row count. Narrow buckets amortize
        grid-step overhead with tall blocks; wide buckets may need short
        blocks to fit their ``block * width`` tile in VMEM.
    dist_frontier:
        BSP property-exchange policy of the distributed backend.
        ``"dense"`` all-gathers the full property arrays every superstep
        (the paper's scheme, and the conservative baseline the autotuner
        starts from). ``"compact"`` exchanges only the entries that changed
        since the last superstep through fixed-size per-shard buffers,
        falling back to a full gather whenever any shard's change count
        overflows its buffer. ``"auto"`` is ``"compact"`` plus an
        empty-frontier fast path: when no entry changed anywhere, the
        collective is skipped entirely. All three policies exchange the
        same values, so the choice never changes results — only
        communication volume.
    dist_gather_frac:
        Per-shard capacity of the compact exchange buffer, as a fraction of
        the shard's vertex block (in [0, 1]). A compact superstep moves
        ``2 * cap * num_shards`` elements (ids + values) instead of the
        dense ``N_pad``, so fractions >= 0.5 cannot beat the dense gather
        and the exchange statically degrades to ``"dense"`` there.
    priority:
        Ordering policy for monotonic Min-relax fixedPoint loops (SSSP-
        style). ``"none"`` relaxes the whole modified frontier every sweep
        (the paper's scheme). ``"delta"`` lowers the loop to delta-stepping:
        each sweep relaxes only the vertices whose tentative value falls
        below the current bucket boundary ``(k + 1) * delta_bucket``,
        iterating until the bucket settles, then advances ``k`` straight to
        the bucket of the smallest pending value. Min relaxation is
        monotone, so restricting the frontier never changes the fixed
        point — only the work per sweep. Loops without a Min relax
        (PageRank, TC) ignore the knob.
    delta_bucket:
        Bucket width Δ for ``priority="delta"`` (a positive integer, in
        units of edge weight). Small Δ approaches Dijkstra ordering (less
        wasted relaxation work per sweep, more bucket phases); large Δ
        approaches the monotonic relax. ``autotune()`` derives candidates
        from the graph's weight scale.
    refresh_threshold_frac:
        Incremental-recompute cutoff for ``BoundProgram.refresh`` (a
        fraction of N, in [0, 1]). After ``g.update(adds, dels)`` the
        refresh path seeds the iterative loop from the vertices affected
        by the batch; when the affected set exceeds this fraction of the
        graph, warm-starting saves too little over a cold sweep and
        refresh falls back to a dense full recompute. ``0.0`` always
        recomputes from scratch; ``1.0`` always takes the incremental
        path. Programs without an iterative construct have nothing to
        warm-start (SP208).
    """

    num_buckets: int = 4
    min_width: int = 8
    growth: int = 4
    push_threshold_frac: float = 1.0 / 16.0
    batch_sources: int = 32
    direction: str = "auto"
    block_rows: object = 256   # int (uniform) or tuple of per-bucket caps
    dist_frontier: str = "dense"
    dist_gather_frac: float = 0.25
    priority: str = "none"
    delta_bucket: int = 64
    refresh_threshold_frac: float = 0.25

    def __post_init__(self):
        set_ = lambda k, v: object.__setattr__(self, k, v)  # noqa: E731 (frozen)
        for name in ("num_buckets", "min_width", "growth", "batch_sources",
                     "delta_bucket"):
            v = getattr(self, name)
            # accept anything integer-valued (numpy ints from autotuning
            # sweeps, integral floats) but normalize to python int so
            # equality/hashing — the compile-cache key — stay canonical
            if isinstance(v, bool):
                raise ValueError(
                    f"Schedule.{name} must be an integer, got {v!r}")
            if isinstance(v, numbers.Integral):
                set_(name, int(v))
            elif isinstance(v, float) and v.is_integer():
                set_(name, int(v))
            else:
                raise ValueError(
                    f"Schedule.{name} must be an integer, got {v!r}")
        if self.num_buckets < 1:
            raise ValueError(
                f"Schedule.num_buckets must be >= 1, got {self.num_buckets} "
                "(the sliced-ELL view needs at least one degree bucket)")
        if self.min_width <= 0 or self.min_width % LANE_MULTIPLE:
            raise ValueError(
                f"Schedule.min_width must be a positive multiple of "
                f"{LANE_MULTIPLE} (VPU sublane count), got {self.min_width}")
        if self.growth <= 1:
            raise ValueError(
                f"Schedule.growth must be > 1, got {self.growth} "
                "(bucket widths grow geometrically; growth 1 would make "
                "every bucket the same width)")
        frac = self.push_threshold_frac
        if isinstance(frac, numbers.Real) and not isinstance(frac, bool):
            set_("push_threshold_frac", float(frac))
        if not isinstance(self.push_threshold_frac, float) or \
                not 0.0 <= self.push_threshold_frac <= 1.0:
            raise ValueError(
                "Schedule.push_threshold_frac must be a fraction of N in "
                f"[0, 1], got {self.push_threshold_frac!r}")
        if self.batch_sources < 0:
            raise ValueError(
                f"Schedule.batch_sources must be >= 0, got "
                f"{self.batch_sources} (0 or 1 disables source batching)")
        # normalize str subclasses (np.str_ from sweep code) to plain str:
        # these values are baked into generated source via repr()
        if isinstance(self.direction, str):
            set_("direction", str(self.direction))
        if self.direction not in _DIRECTIONS:
            raise ValueError(
                f"Schedule.direction must be one of {_DIRECTIONS}, got "
                f"{self.direction!r}")
        if isinstance(self.dist_frontier, str):
            set_("dist_frontier", str(self.dist_frontier))
        if self.dist_frontier not in _DIST_FRONTIERS:
            raise ValueError(
                f"Schedule.dist_frontier must be one of {_DIST_FRONTIERS}, "
                f"got {self.dist_frontier!r}")
        if isinstance(self.priority, str):
            set_("priority", str(self.priority))
        if self.priority not in _PRIORITIES:
            raise ValueError(
                f"Schedule.priority must be one of {_PRIORITIES}, got "
                f"{self.priority!r}")
        if self.delta_bucket <= 0:
            raise ValueError(
                f"Schedule.delta_bucket must be a positive bucket width "
                f"(in edge-weight units), got {self.delta_bucket}")
        gfrac = self.dist_gather_frac
        if isinstance(gfrac, numbers.Real) and not isinstance(gfrac, bool):
            set_("dist_gather_frac", float(gfrac))
        if not isinstance(self.dist_gather_frac, float) or \
                not 0.0 <= self.dist_gather_frac <= 1.0:
            raise ValueError(
                "Schedule.dist_gather_frac must be a fraction of the shard "
                f"block in [0, 1], got {self.dist_gather_frac!r}")
        rfrac = self.refresh_threshold_frac
        if isinstance(rfrac, numbers.Real) and not isinstance(rfrac, bool):
            set_("refresh_threshold_frac", float(rfrac))
        if not isinstance(self.refresh_threshold_frac, float) or \
                not 0.0 <= self.refresh_threshold_frac <= 1.0:
            raise ValueError(
                "Schedule.refresh_threshold_frac must be a fraction of N in "
                f"[0, 1], got {self.refresh_threshold_frac!r}")
        br = self.block_rows
        if isinstance(br, (list, tuple)):
            br = tuple(br)
            if len(br) != self.num_buckets:
                raise ValueError(
                    f"Schedule.block_rows tuple must have one cap per bucket "
                    f"(num_buckets={self.num_buckets}), got {len(br)} entries "
                    f"— or pass a single int for a uniform cap")
        else:
            br = (br,)
        norm = []
        for v in br:
            if isinstance(v, bool) or not isinstance(v, numbers.Integral):
                if not (isinstance(v, float) and v.is_integer()):
                    raise ValueError(
                        f"Schedule.block_rows entries must be integers, got "
                        f"{v!r}")
            v = int(v)
            if v <= 0 or v % LANE_MULTIPLE:
                raise ValueError(
                    f"Schedule.block_rows caps must be positive multiples of "
                    f"{LANE_MULTIPLE} (VPU sublane count), got {v}")
            norm.append(v)
        set_("block_rows",
             tuple(norm) if isinstance(self.block_rows, (list, tuple))
             else norm[0])

    # ------------------------------------------------------------------
    def layout_key(self) -> tuple:
        """The fields that determine per-graph *data layout* (the sliced-ELL
        bucket structure). Two schedules sharing a layout_key share the same
        derived graph views in a GraphContext."""
        return (self.num_buckets, self.min_width, self.growth)

    def bucket_widths(self) -> tuple:
        return tuple(self.min_width * self.growth ** i
                     for i in range(self.num_buckets))

    def bucket_block_rows(self) -> tuple:
        """Per-bucket kernel row-block caps, always of length ``num_buckets``
        (a uniform int cap is broadcast). This is the form the pallas
        codegen bakes into generated source."""
        if isinstance(self.block_rows, tuple):
            return self.block_rows
        return (self.block_rows,) * self.num_buckets

    def replace(self, **changes) -> "Schedule":
        """Functional update (alias for ``dataclasses.replace``)."""
        return dataclasses.replace(self, **changes)


DEFAULT_SCHEDULE = Schedule()
