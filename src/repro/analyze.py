"""``python -m repro.analyze`` — entry point shim for the analysis CLI.

The implementation lives in :mod:`repro.core.analysis.cli`; this module
only exists so the tool is reachable at the short, documented module path.
"""
from .core.analysis.cli import main

if __name__ == "__main__":
    raise SystemExit(main())
