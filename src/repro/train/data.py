"""Synthetic, deterministic, shard-aware token pipeline.

Stateless-by-step: `batch_at(step)` is a pure function of (seed, step,
shard), so resume-after-failure needs no iterator checkpoints — the
restored step number IS the data position (skip-ahead for free), and every
data-parallel shard draws a disjoint slice.

The synthetic stream is a mixture of repeated n-grams over a small alphabet
so a real model can actually reduce loss on it (used by examples/train_lm).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard: int = 0
    structure: int = 16      # n-gram period; lower = easier to learn


def batch_at(dc: DataConfig, step: int) -> dict:
    """Deterministic batch for `step` on this shard: tokens + next-token labels."""
    per_shard = dc.global_batch // dc.num_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([dc.seed, step, dc.shard]))
    base = rng.integers(0, dc.vocab, size=(per_shard, dc.structure))
    reps = -(-(dc.seq_len + 1) // dc.structure)
    seq = np.tile(base, (1, reps))[:, : dc.seq_len + 1]
    noise = rng.random((per_shard, dc.seq_len + 1)) < 0.05
    seq = np.where(noise, rng.integers(0, dc.vocab, size=seq.shape), seq)
    tokens = jnp.asarray(seq[:, :-1], jnp.int32)
    labels = jnp.asarray(seq[:, 1:], jnp.int32)
    return {"tokens": tokens, "labels": labels}


def embeds_batch_at(dc: DataConfig, step: int, d_model: int) -> dict:
    """Stub-frontend batch (audio/vision archs): precomputed embeddings."""
    tok = batch_at(dc, step)
    rng = np.random.default_rng(np.random.SeedSequence([dc.seed + 1, step, dc.shard]))
    per_shard = dc.global_batch // dc.num_shards
    emb = rng.normal(size=(per_shard, dc.seq_len, d_model)).astype(np.float32)
    return {"embeds": jnp.asarray(emb), "tokens": tok["tokens"],
            "labels": tok["labels"]}
