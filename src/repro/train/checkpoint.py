"""Checkpoint save/restore with fault-tolerance semantics.

  * atomic: write to <dir>/tmp-<step>, fsync, rename to <dir>/step-<step>
    (a crash mid-save never corrupts the latest checkpoint);
  * manifest: step, pytree structure, per-leaf dtype/shape;
  * retention: keep the newest `keep` checkpoints;
  * elastic restore: leaves are loaded as host numpy and re-placed with the
    *target* sharding — restoring onto a different mesh/device count is the
    same code path (tests save on mesh A and restore on mesh B);
  * resume: `latest_step(dir)` + the stateless data pipeline (train/data.py)
    make restart = load + continue.
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":        # ml_dtypes (bfloat16): store as f32
            arr = np.asarray(jax.numpy.asarray(leaf).astype(jax.numpy.float32))
        out[key] = arr
    return out


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp-{step}")
    final = os.path.join(ckpt_dir, f"step-{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"step": step,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in flat.items()}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step-{s}"), ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    return [int(m.group(1)) for d in os.listdir(ckpt_dir)
            if (m := re.fullmatch(r"step-(\d+)", d))]


def latest_step(ckpt_dir: str):
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of `like`. If `shardings` (a pytree of
    jax.sharding.Sharding matching `like`) is given, leaves are placed with
    those shardings — this is the elastic re-mesh path."""
    path = os.path.join(ckpt_dir, f"step-{step}")
    arrays = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat_like))
    leaves = []
    for (pth, leaf), sh in zip(flat_like, flat_sh):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        arr = arrays[key]
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"checkpoint/model shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        val = jax.numpy.asarray(arr).astype(leaf.dtype)
        leaves.append(jax.device_put(val, sh) if sh is not None else val)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
