"""Train step: loss, gradients (with remat + microbatch accumulation),
optimizer update.

Microbatching serves two masters: activation memory on real hardware and
MoE dispatch-tensor size everywhere (see models/moe.py) — gradients are
accumulated over `microbatches` sequential slices via lax.scan, so one
compiled step handles any global batch. Straggler note: the step is
shape-static and data-independent — a slow host delays only its own psum,
never causes retraces.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .optimizer import OptimizerConfig, adamw_update, init_opt_state


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: dict
    opt: dict

    @property
    def step(self):
        return self.opt["step"]


def init_state(model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=init_opt_state(params))


def cross_entropy(logits, labels):
    """logits [B,S,V] f32; labels [B,S] int32. Mean NLL."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_loss_fn(model, *, impl="ref", remat=True):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch, impl=impl, remat=remat)
        ce = cross_entropy(logits, batch["labels"])
        return ce + aux, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(model, oc: OptimizerConfig, *, microbatches: int = 1,
                    impl="ref", remat=True) -> Callable:
    """Returns train_step(state, batch) → (state, metrics). The batch's
    leading dim must divide by `microbatches`."""
    loss_fn = make_loss_fn(model, impl=impl, remat=remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        if microbatches == 1:
            (loss, parts), grads = grad_fn(state.params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc(carry, mbatch):
                g_acc, l_acc, ce_acc = carry
                (l, parts), g = grad_fn(state.params, mbatch)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l, ce_acc + parts["ce"]), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            (grads, loss, ce), _ = jax.lax.scan(
                acc, (zeros, jnp.float32(0), jnp.float32(0)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            parts = {"ce": ce / microbatches, "aux": loss - ce / microbatches}
        new_params, new_opt, om = adamw_update(oc, state.params, grads, state.opt)
        metrics = {"loss": loss, **parts, **om}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step
