"""AdamW with WSD (warmup-stable-decay) and cosine schedules.

WSD is minicpm-2b's paper-of-record trick (arXiv:2404.06395): LR warms up,
holds at peak for most of training, then decays sharply in the final
fraction — implemented natively so the minicpm config trains as published.

Optimizer state is a pytree shaped like params (m, v in f32) so it inherits
the params' NamedSharding — ZeRO-style sharded optimizer state for free.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | wsd | constant
    wsd_decay_frac: float = 0.1       # last 10% decays (minicpm)
    min_lr_frac: float = 0.1


def lr_at(oc: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    if oc.schedule == "constant":
        return oc.lr * warm
    if oc.schedule == "wsd":
        decay_start = oc.total_steps * (1.0 - oc.wsd_decay_frac)
        frac = jnp.clip((step - decay_start)
                        / jnp.maximum(oc.total_steps - decay_start, 1), 0, 1)
        decay = 1.0 - (1.0 - oc.min_lr_frac) * frac
        return oc.lr * warm * decay
    # cosine
    frac = jnp.clip(step / oc.total_steps, 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return oc.lr * warm * (oc.min_lr_frac + (1 - oc.min_lr_frac) * cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(oc: OptimizerConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if oc.grad_clip else 1.0
    lr = lr_at(oc, step)
    b1, b2 = oc.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps)
        if oc.weight_decay and p.ndim >= 2:   # decay matrices only
            delta = delta + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
