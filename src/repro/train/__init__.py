from . import checkpoint, data, optimizer, train_step
from .optimizer import OptimizerConfig, adamw_update, init_opt_state, lr_at
from .train_step import TrainState, init_state, make_loss_fn, make_train_step

__all__ = ["checkpoint", "data", "optimizer", "train_step",
           "OptimizerConfig", "adamw_update", "init_opt_state", "lr_at",
           "TrainState", "init_state", "make_loss_fn", "make_train_step"]
