"""Loop-aware HLO cost model.

XLA's `compiled.cost_analysis()` counts while/scan bodies ONCE (verified in
tests/test_roofline.py), which under-counts a scanned 94-layer stack by
~94×. This module parses the post-optimization HLO text instead and walks
the call graph (entry → while bodies ×trip-count → fusions), accumulating:

  * dot FLOPs        (2 · prod(result) · prod(contracting dims))
  * dot HBM bytes    (operands + result — matmul traffic incl. remat replays)
  * collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
                      collective-permute output shapes)

Trip counts come from the while condition's `compare(iv, constant)` (the
canonical jax.lax.scan/fori_loop lowering; the compare may sit behind a
fusion). Unrecognized conditions (e.g. data-dependent fixed points) count
as ONE iteration and are flagged — the honest static answer.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field


def xla_cost_dict(compiled) -> dict:
    """`compiled.cost_analysis()` normalized across jax versions: 0.4.x
    returns a one-element list of dicts, newer jax returns the dict."""
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return c

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|f8e4m3fn"
    r"|f8e5m2|c64|c128)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->\s*.+\{\s*$")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*(\(?[^,()]+(?:\([^)]*\))?\)?)")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"\b(?:calls|to_apply|branch_computations=\{)[=]?%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s*constant\((\d+)\)")
_DIR_RE = re.compile(r"direction=(LT|LE|GT|GE|NE)")
_COLLECTIVE = ("all-gather(", "all-reduce(", "reduce-scatter(", "all-to-all(",
               "collective-permute(", "all-gather-start(", "all-reduce-start(",
               "collective-permute-start(")


def _bytes_of(shape_txt: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_txt):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims_of(shape_txt: str):
    m = _SHAPE_RE.search(shape_txt)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class Comp:
    name: str
    lines: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)   # var name -> result type text


def _split(txt: str):
    comps = {}
    cur = None
    for line in txt.splitlines():
        h = _HEADER_RE.match(line)
        if h and ("->" in line):
            cur = Comp(name=h.group(1))
            comps[cur.name] = cur
            for pm in _PARAM_RE.finditer(h.group(2)):
                cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        cur.lines.append(line)
        im = _INSTR_RE.match(line)
        if im:
            name, rhs = im.groups()
            # result type = leading shape text of rhs (may be a tuple)
            cur.symbols[name] = rhs.split(" ")[0] if rhs else ""
            # parameters defined inline: "%p = f32[..] parameter(0)"
    return comps


def _operand_names(rhs: str):
    """Operand variable names of the top-level op in an instruction rhs."""
    op = rhs.find("(")
    if op < 0:
        return []
    depth = 0
    end = op
    for i in range(op, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = rhs[op + 1:end]
    return re.findall(r"%([\w.\-]+)", inner)


@dataclass
class CompCost:
    flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: float = 0.0
    whiles: list = field(default_factory=list)    # (cond, body)
    calls: list = field(default_factory=list)     # names


def _analyze_comp(comp: Comp) -> CompCost:
    c = CompCost()
    for line in comp.lines:
        im = _INSTR_RE.match(line)
        if not im:
            wm = _WHILE_RE.search(line)
            if wm:
                c.whiles.append(wm.groups())
            continue
        _, rhs = im.groups()
        head = rhs.split("metadata")[0]
        wm = _WHILE_RE.search(head)
        if wm:
            c.whiles.append(wm.groups())
            continue
        if " dot(" in head or head.startswith("dot("):
            result_type = head.split(" ")[0]
            ops = _operand_names(head[head.find("dot("):])
            lhs_type = comp.symbols.get(ops[0], "") if ops else ""
            lhs_dims = _dims_of(lhs_type)
            contract = 1
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", head)
            if cm and cm.group(1) and lhs_dims:
                for ci in cm.group(1).split(","):
                    if int(ci) < len(lhs_dims):
                        contract *= lhs_dims[int(ci)]
            relems = 1
            for d in _dims_of(result_type):
                relems *= d
            c.flops += 2.0 * relems * contract
            rhs_type = comp.symbols.get(ops[1], "") if len(ops) > 1 else ""
            c.dot_bytes += (_bytes_of(result_type) + _bytes_of(lhs_type)
                            + _bytes_of(rhs_type))
            continue
        if any(k in head for k in _COLLECTIVE):
            c.coll_bytes += _bytes_of(head.split(" ")[0])
        for cn in _CALLS_RE.findall(head):
            c.calls.append(cn)
    return c


def _trip_count(comps, costs, cond_name):
    comp = comps.get(cond_name)
    if comp is None:
        return None
    consts = []
    for line in comp.lines:
        consts += [int(x) for x in _CONST_RE.findall(line)]
    texts = [l for l in comp.lines]
    for cn in costs[cond_name].calls:
        if cn in comps:
            texts += comps[cn].lines
    direction = None
    for l in texts:
        dm = _DIR_RE.search(l)
        if dm:
            direction = dm.group(1)
            break
    if direction in ("LT", "NE") and consts:
        return max(consts)
    if direction == "LE" and consts:
        return max(consts) + 1
    return None


def collective_breakdown(hlo_text: str, top: int = 12):
    """Per-(op, shape) collective bytes with loop multipliers — the §Perf
    profiling view ('which all-gather is eating the step')."""
    comps = _split(hlo_text)
    costs = {name: _analyze_comp(c) for name, c in comps.items()}
    detail = {}

    def visit(name, mult, depth=0):
        if name not in comps or depth > 64:
            return
        comp = comps[name]
        for line in comp.lines:
            im = _INSTR_RE.match(line)
            if not im:
                continue
            head = im.group(2).split("metadata")[0]
            for kind in _COLLECTIVE:
                if kind in head:
                    shape = head.split(" ")[0]
                    key = (kind.rstrip("("), shape)
                    b = _bytes_of(shape) * mult
                    cnt, tot = detail.get(key, (0, 0.0))
                    detail[key] = (cnt + mult, tot + b)
                    break
        c = costs[name]
        for cond, body in c.whiles:
            trips = _trip_count(comps, costs, cond) or 1
            visit(body, mult * trips, depth + 1)
        for cn in c.calls:
            visit(cn, mult, depth + 1)

    entry = None
    for name in comps:
        if name.startswith("main"):
            entry = name
    visit(entry, 1)
    rows = sorted(((tot, cnt, kind, shape)
                   for (kind, shape), (cnt, tot) in detail.items()),
                  reverse=True)
    return rows[:top]


def analyze(hlo_text: str) -> dict:
    comps = _split(hlo_text)
    costs = {name: _analyze_comp(c) for name, c in comps.items()}
    unknown = []

    memo = {}

    def total(name, depth=0):
        if name in memo:
            return memo[name]
        if name not in costs or depth > 64:
            return (0.0, 0.0, 0.0)
        c = costs[name]
        f, db, cb = c.flops, c.dot_bytes, c.coll_bytes
        for cond, body in c.whiles:
            trips = _trip_count(comps, costs, cond)
            if trips is None:
                trips = 1
                unknown.append(body)
            bf, bdb, bcb = total(body, depth + 1)
            cf, cdb, ccb = total(cond, depth + 1)
            f += trips * (bf + cf)
            db += trips * (bdb + cdb)
            cb += trips * (bcb + ccb)
        for cn in c.calls:
            bf, bdb, bcb = total(cn, depth + 1)
            f += bf
            db += bdb
            cb += bcb
        memo[name] = (f, db, cb)
        return memo[name]

    entry = None
    for name in comps:
        if name.startswith("main"):
            entry = name
    if entry is None and comps:
        entry = list(comps)[-1]
    f, db, cb = total(entry)
    return {"flops": f, "dot_bytes": db, "collective_bytes": cb,
            "entry": entry, "unknown_trip_bodies": sorted(set(unknown)),
            "num_computations": len(comps)}
