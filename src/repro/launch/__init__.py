"""Launch layer: mesh construction, multi-pod dry-run, roofline analysis,
training driver. NOTE: do not import .dryrun from here — it pins
XLA_FLAGS device count at import and must only run as __main__."""
from . import mesh, roofline, sharding

__all__ = ["mesh", "roofline", "sharding"]
