"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs / (chips × 197e12)          # bf16 peak, v5e
    memory     = HLO_bytes / (chips × 819e9)           # HBM bandwidth
    collective = collective_bytes / (chips × 50e9 × 3) # ~3 usable ICI links

cost_analysis() reports whole-program totals (all devices); collective
bytes are NOT in cost_analysis — `collective_bytes()` parses the
post-optimization HLO and sums operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

MODEL_FLOPS (6·N·D dense, 6·N_active·D MoE) / HLO_FLOPs measures how much
compiled compute is "useful" (catches remat recompute and dispatch waste).
"""
from __future__ import annotations

import re

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW_PER_LINK = 50e9       # bytes/s/link (~3 usable links per chip on a 2D torus)
ICI_LINKS = 3.0

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred)"
                       r"\[([\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(compiled) -> int:
    """Sum of output-shape bytes of every collective op in the optimized HLO.
    (Output shape ≈ operand volume for AG/AR/A2A; a consistent census for
    comparing schedules, not an exact wire-byte count.)"""
    try:
        txt = compiled.as_text()
    except Exception:
        return 0
    total = 0
    for line in txt.splitlines():
        s = line.strip()
        # match "<shape> <name> = collective-op(...)" instruction lines
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?)\s*"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)", s)
        if m:
            total += _shape_bytes(m.group(1))
    return total


def model_flops(cfg, cell) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE); D = tokens processed."""
    n = param_count(cfg, active_only=True)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens          # forward only
    tokens = cell.global_batch           # one token per sequence
    return 2.0 * n * tokens


def param_count(cfg, active_only=False) -> float:
    """Analytic parameter count from the config."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_padded
    hd = cfg.hd
    attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
    if cfg.family in ("dense",):
        per_layer = attn + 3 * d * ff
        layers = cfg.n_layers * per_layer
    elif cfg.family == "moe":
        e_used = cfg.moe_top_k if active_only else cfg.n_experts
        shared = 3 * d * ff * cfg.n_shared_experts
        per_layer = attn + 3 * d * ff * e_used + shared + d * cfg.n_experts
        layers = cfg.n_layers * per_layer
    elif cfg.family == "hybrid":
        n, p = cfg.ssm_state, cfg.ssm_head_dim
        mamba = d * (2 * d + 2 * n + d // p) + d * d
        layers = cfg.n_layers * mamba + (attn + 3 * d * ff)   # + shared attn block
    elif cfg.family == "ssm":
        mlstm = 3 * d * cfg.n_heads * hd + 2 * d * cfg.n_heads + \
            d * cfg.n_heads * hd + cfg.n_heads * hd * d
        layers = cfg.n_layers * mlstm
    elif cfg.family == "encdec":
        enc = cfg.n_enc_layers * (attn + 3 * d * ff)
        dec = cfg.n_dec_layers * (2 * attn + 3 * d * ff)
        layers = enc + dec
    else:
        layers = 0
    embed = v * d * (1 if cfg.tie_embeddings else 2)
    return float(layers + embed)


def terms(rec: dict) -> dict:
    """rec carries PER-DEVICE census numbers (the SPMD module is the
    per-device program), so no further division by chip count."""
    compute = rec["flops"] / PEAK_FLOPS
    memory = rec.get("dot_bytes", 0.0) / HBM_BW
    coll = rec["collective_bytes"] / (ICI_BW_PER_LINK * ICI_LINKS)
    dom = max((compute, "compute"), (memory, "memory"), (coll, "collective"))
    out = {
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "bottleneck": dom[1],
        "step_lower_bound_s": max(compute, memory, coll),
    }
    return out


def summarize(rec: dict, cfg=None, cell=None) -> dict:
    t = terms(rec)
    if cfg is not None and cell is not None:
        mf = model_flops(cfg, cell)
        t["model_flops"] = mf
        t["useful_fraction"] = mf / rec["flops"] if rec["flops"] else 0.0
    return t
