import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds abstract (ShapeDtypeStruct) parameters, optimizer
state, and inputs with production NamedShardings — no allocation — and runs

    jax.jit(step, in_shardings=..., out_shardings=...).lower(...).compile()

then records memory_analysis() (fits-per-device proof) and cost_analysis()
(FLOPs/bytes for the roofline) plus the collective-byte census parsed from
the optimized HLO. Output: one JSON per cell under launch_out/.

Usage:
    python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--arch ... --shape ...]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS
from ..configs.base import ShapeCell, shape_cells_for
from ..models import build
from ..train import OptimizerConfig, make_train_step
from ..train.train_step import init_state
from .mesh import effective_batch_axes, make_production_mesh
from . import hlo_cost, roofline
from . import sharding as sh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "launch_out")

# Microbatch count per shape cell: keeps per-µbatch tokens ≈ one sequence
# per data-shard (activation + MoE dispatch memory; see DESIGN.md).
def _microbatches(cell: ShapeCell, data_shards: int) -> int:
    if os.environ.get("REPRO_MICROBATCHES"):        # §Perf H1 knob
        return int(os.environ["REPRO_MICROBATCHES"])
    per_shard = max(cell.global_batch // data_shards, 1)
    return per_shard      # 1 sequence per microbatch per data shard


def _abstract(fn, *args, **kwargs):
    return jax.eval_shape(fn, *args, **kwargs)


def input_specs(cfg, cell: ShapeCell, model):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = cell.global_batch, cell.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cell.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        if cfg.input_kind == "embeddings":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        return batch
    if cell.kind == "prefill":
        batch = {"tokens": tok}
        if cfg.input_kind == "embeddings":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        return batch
    # decode / long_decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def build_cell(cfg, cell: ShapeCell, mesh):
    """Returns (jitted_fn, example_args_as_SDS) for one cell."""
    model = build(cfg)
    baxes = effective_batch_axes(mesh, cell.global_batch)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_shards = 1
    for a in baxes:
        data_shards *= mesh.shape[a]
    key = jax.random.PRNGKey(0)

    if cell.kind == "train":
        state_shapes = _abstract(lambda: init_state(model, key))
        state_specs = sh.state_specs(state_shapes, axis_sizes)
        state_sds = sh.with_shardings(mesh, state_shapes, state_specs)
        batch_shapes = input_specs(cfg, cell, model)
        bspecs = sh.batch_specs(batch_shapes, baxes)
        batch_sds = sh.with_shardings(mesh, batch_shapes, bspecs)
        oc = OptimizerConfig(total_steps=10_000)
        mb = _microbatches(cell, data_shards)
        step = make_train_step(model, oc, microbatches=mb, impl="chunked",
                               remat=True)
        fn = jax.jit(step, donate_argnums=(0,))
        return fn, (state_sds, batch_sds)

    params_shapes = _abstract(model.init, key)
    pspecs = sh.param_specs(params_shapes, axis_sizes)
    params_sds = sh.with_shardings(mesh, params_shapes, pspecs)

    if cell.kind == "prefill":
        batch_shapes = input_specs(cfg, cell, model)
        bspecs = sh.batch_specs(batch_shapes, baxes)
        batch_sds = sh.with_shardings(mesh, batch_shapes, bspecs)

        def prefill(params, batch):
            logits, _ = model.forward(params, batch, impl="chunked",
                                      remat=True, last_only=True)
            return logits
        return jax.jit(prefill), (params_sds, batch_sds)

    # decode / long_decode: serve_step(params, tok, cache, pos)
    b, s = cell.global_batch, cell.seq_len
    if cfg.family == "encdec":
        cache_shapes = _abstract(lambda: model.init_cache(b, s, s))
    else:
        cache_shapes = _abstract(lambda: model.init_cache(b, s))
    cspecs = sh.cache_specs(cache_shapes, baxes, axis_sizes)
    cache_sds = sh.with_shardings(mesh, cache_shapes, cspecs)
    tok_sds = sh.with_shardings(
        mesh, {"t": jax.ShapeDtypeStruct((b, 1), jnp.int32)},
        {"t": jax.sharding.PartitionSpec(baxes if baxes else None, None)})["t"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, tok, cache, pos):
        return model.decode_step(params, tok, cache, pos)
    return jax.jit(serve_step, donate_argnums=(2,)), \
        (params_sds, tok_sds, cache_sds, pos)


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str = OUT_DIR):
    cfg = ARCHS[arch]
    cell = next(c for c in shape_cells_for(cfg) if c.name == shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    from ..models.layers import set_constraint_mesh
    set_constraint_mesh(mesh)
    fn, args = build_cell(cfg, cell, mesh)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = hlo_cost.xla_cost_dict(compiled)
    # loop-aware per-device census from the optimized HLO (hlo_cost.py):
    # cost_analysis() counts while bodies once and is kept as a cross-check.
    census = hlo_cost.analyze(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "kind": cell.kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": census["flops"],                  # per device, loop-aware
        "dot_bytes": census["dot_bytes"],
        "collective_bytes": census["collective_bytes"],
        "unknown_trip_bodies": census["unknown_trip_bodies"],
        "xla_cost_flops_bodies_once": cost.get("flops", 0.0),
        "xla_bytes_accessed_bodies_once": cost.get("bytes accessed", 0.0),
        "memory": {
            k: getattr(mem, k, None) for k in
            ("temp_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "generated_code_size_in_bytes")
        },
        "num_devices": mesh.devices.size,
    }
    rec["roofline"] = roofline.terms(rec)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    r = rec["roofline"]
    print(f"[dryrun] {arch} × {shape} × {mesh_name}: compile {t_compile:.0f}s | "
          f"flops/dev {rec['flops']:.3e} | "
          f"args/dev {(rec['memory']['argument_size_in_bytes'] or 0)/2**30:.2f} GiB | "
          f"temp/dev {(rec['memory']['temp_size_in_bytes'] or 0)/2**30:.2f} GiB | "
          f"coll/dev {rec['collective_bytes']/2**30:.3f} GiB | "
          f"bottleneck {r['bottleneck']} ({r['step_lower_bound_s']*1e3:.1f} ms)")
    print(f"  memory_analysis: {mem}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args(argv)

    cells = []
    for arch, cfg in ARCHS.items():
        if args.arch and arch != args.arch:
            continue
        for cell in shape_cells_for(cfg):
            if args.shape and cell.name != args.shape:
                continue
            cells.append((arch, cell.name))
    if not args.all and len(cells) > 1 and not (args.arch and args.shape):
        pass  # allow suites via --all or filters
    ok = fail = 0
    for arch, shape in cells:
        try:
            run_cell(arch, shape, args.multi_pod, args.out)
            ok += 1
        except Exception:
            fail += 1
            print(f"[dryrun] FAIL {arch} × {shape}", file=sys.stderr)
            traceback.print_exc()
    print(f"[dryrun] done: {ok} ok, {fail} failed")
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main())
