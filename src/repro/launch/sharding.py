"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Strategy (16×16 single pod; +pure-DP 'pod' axis multi-pod):
  * FSDP: every matrix shards its d_model-sided dim over 'data' (ZeRO —
    optimizer state inherits it since m/v mirror the params);
  * TP: head/ff/expert/vocab dims shard over 'model';
  * layer-stacked params ([L, ...]) keep L unsharded (scan axis);
  * KV caches shard batch over 'data' and SEQUENCE over 'model' (kv-head
    counts like 2 or 8 don't divide 16; sequence always does — GSPMD turns
    the softmax into a partial-reduce, i.e. ring attention for free);
  * batches shard over ('pod','data').
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# param dims that shard over ('data' side, 'model' side)
_IN_OUT = {"wq", "wk", "wv", "wz", "wi", "wf", "wo_gate", "in_proj",
           "w_gate", "w_up"}            # [d, X] → P(data, model)
_OUT_IN = {"wo", "out", "out_proj", "w_down"}   # [X, d] → P(model, data)
_STACKED = {"layers", "mlstm", "slstm", "enc_layers", "dec_layers"}


def _axis_size(axes, axis_sizes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return axis_sizes.get(axes, 1)
    n = 1
    for a in axes:
        n *= axis_sizes.get(a, 1)
    return n


def _guard(spec_entries, shape, axis_sizes):
    """Keep an axis only when its size divides the dim (e.g. 4 gate heads on
    a 16-way model axis → replicate instead of failing to tile)."""
    return [ax if _axis_size(ax, axis_sizes) <= 1
            or dim % _axis_size(ax, axis_sizes) == 0 else None
            for dim, ax in zip(shape, spec_entries)]


def _param_rule(path_keys, shape, axis_sizes):
    name = path_keys[-1]
    rank = len(shape)
    stacked = path_keys[0] in _STACKED
    base = rank - 1 if stacked else rank

    def wrap(*spec):
        spec = tuple(spec) + (None,) * (base - len(spec))
        spec = (((None,) if stacked else ()) + spec)
        return P(*_guard(spec, shape, axis_sizes))

    if name == "embed":
        return wrap("model", "data")
    if name == "unembed":
        return wrap("data", "model")
    if name == "router":
        return wrap("data", None)
    if name == "conv_w":
        return wrap(None, "model")
    if base == 3 and name in ("w_gate", "w_up"):    # MoE experts [E, d, ff]
        return wrap("model", "data", None)
    if base == 3 and name == "w_down":              # [E, ff, d]
        return wrap("model", None, "data")
    if base == 2 and name in _IN_OUT:
        return wrap("data", "model")
    if base == 2 and name in _OUT_IN:
        return wrap("model", "data")
    return wrap()          # biases, norms, gates: replicated


DEFAULT_AXES = {"pod": 2, "data": 16, "model": 16}


def param_specs(params_or_shapes, axis_sizes=None):
    """PartitionSpec pytree matching a params tree (works on arrays or
    ShapeDtypeStructs)."""
    axis_sizes = axis_sizes or DEFAULT_AXES

    def leaf_spec(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        return _param_rule(keys, leaf.shape, axis_sizes)
    return jax.tree_util.tree_map_with_path(leaf_spec, params_or_shapes)


def opt_specs(opt_shapes, pspecs):
    """Optimizer m/v mirror params; step is replicated."""
    return {"m": pspecs, "v": pspecs, "step": P()}


def state_specs(state_shapes, axis_sizes=None):
    pspecs = param_specs(state_shapes.params, axis_sizes)
    return type(state_shapes)(params=pspecs, opt=opt_specs(state_shapes.opt, pspecs))


def batch_specs(batch_shapes, baxes):
    """Token batches shard the leading (batch) dim over pod+data."""
    b = baxes if baxes else None
    return jax.tree_util.tree_map(
        lambda x: P(b) if x.ndim == 1 else P(b, *([None] * (x.ndim - 1))),
        batch_shapes)


def cache_specs(cache_shapes, baxes, axis_sizes=None):
    """KV caches [L, B, S, H, D] → P(None, batch, 'model', None, None);
    SSM states [L, B, H, N, Pd] → P(None, batch, 'model', None, None);
    scalars replicated. Non-dividing axes degrade to replication."""
    axis_sizes = axis_sizes or DEFAULT_AXES
    b = baxes if baxes else None

    def leaf(path, x):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        if name in ("k", "v") and x.ndim == 5:        # stacked kv cache
            spec = (None, b, "model", None, None)
        elif name in ("k", "v") and x.ndim == 4:
            spec = (b, "model", None, None)
        elif name == "enc_out":
            spec = (b, "model", None)
        elif name == "h" and x.ndim == 5:             # stacked ssm state
            spec = (None, b, "model", None, None)
        elif name == "conv" and x.ndim == 4:
            spec = (None, b, None, "model")
        elif name in ("m", "n") and x.ndim >= 3:
            spec = (None, b) + (None,) * (x.ndim - 2)
        else:
            spec = (None,) * x.ndim
        return P(*_guard(spec, x.shape, axis_sizes))
    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def named(mesh, specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def with_shardings(mesh, shapes, specs):
    """Attach NamedShardings to a ShapeDtypeStruct tree (dry-run inputs)."""
    return jax.tree_util.tree_map(
        lambda sds, sp: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
