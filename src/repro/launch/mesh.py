"""Production mesh construction.

A FUNCTION, not a module constant — importing this module must never touch
jax device state (the dry-run pins the device count before first jax use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch shards over (pure DP on 'pod' + FSDP 'data')."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def effective_batch_axes(mesh, global_batch: int) -> tuple:
    """Largest prefix of the batch axes whose product divides the batch —
    batch=1 long-context decode replicates instead of failing to tile."""
    axes = []
    prod = 1
    for a in batch_axes(mesh):
        if global_batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes)
