"""Production training driver: sharded end-to-end loop with checkpointing.

Assembles mesh → sharded state → jitted train step (the same build path the
dry-run lowers) and actually RUNS it, with:
  * resume-from-latest on start (crash ⇒ relaunch ⇒ identical trajectory,
    because the data pipeline is stateless in the step number);
  * periodic atomic checkpoints;
  * elastic re-mesh: --devices different from the checkpoint's device count
    re-shards on restore (train/checkpoint.py restores through host numpy).

Smoke-scale usage (any host, fake devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --mesh 4,2 --steps 20 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from ..configs import ARCHS
from ..models import build
from ..train import OptimizerConfig, checkpoint as ckpt, init_state, make_train_step
from ..train.data import DataConfig, batch_at, embeds_batch_at
from . import sharding as sh
from .mesh import effective_batch_axes


def make_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split(","))
    names = ("pod", "data", "model")[-len(dims):]
    return jax.make_mesh(dims, names)


def run(arch: str, mesh_spec: str, steps: int, *, smoke: bool = True,
        seq: int = 64, global_batch: int = 8, microbatches: int = 2,
        ckpt_dir: str | None = None, ckpt_every: int = 50, lr: float = 1e-3,
        log_every: int = 10):
    cfg = ARCHS[arch]
    if smoke:
        cfg = dataclasses.replace(cfg.smoke(), n_layers=2)
    mesh = make_mesh(mesh_spec)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = build(cfg)

    from ..models.layers import set_constraint_mesh
    set_constraint_mesh(mesh)

    state = init_state(model, jax.random.PRNGKey(0))
    specs = sh.state_specs(jax.eval_shape(lambda: state), axis_sizes)
    shardings = sh.named(mesh, specs)
    state = jax.device_put(state, shardings)

    start = 0
    if ckpt_dir and (latest := ckpt.latest_step(ckpt_dir)) is not None:
        state = ckpt.restore(ckpt_dir, latest, state, shardings=shardings)
        start = latest
        print(f"[train] resumed from step {start} (re-sharded onto {mesh_spec})")

    oc = OptimizerConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                         total_steps=steps,
                         schedule="wsd" if cfg.wsd_schedule else "cosine")
    step_fn = jax.jit(
        make_train_step(model, oc, microbatches=microbatches, impl="ref"),
        donate_argnums=(0,))

    baxes = effective_batch_axes(mesh, global_batch)
    dc = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=global_batch,
                    structure=8)
    bspec_fn = lambda b: jax.device_put(
        b, sh.named(mesh, sh.batch_specs(jax.eval_shape(lambda: b), baxes)))

    t0 = time.time()
    metrics = {}
    with mesh:
        for i in range(start, steps):
            if cfg.input_kind == "embeddings" or cfg.family == "encdec":
                batch = embeds_batch_at(dc, i, cfg.d_model)
            else:
                batch = batch_at(dc, i)
            state, metrics = step_fn(state, bspec_fn(batch))
            if i % log_every == 0 or i == steps - 1:
                print(f"[train] step {i:5d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e}")
            if ckpt_dir and (i + 1) % ckpt_every == 0:
                ckpt.save(ckpt_dir, i + 1, state)
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, state)
    dt = time.time() - t0
    print(f"[train] {steps - start} steps in {dt:.1f}s on mesh {mesh_spec} "
          f"({mesh.devices.size} devices); final loss "
          f"{float(metrics['loss']):.4f}")
    return float(metrics["loss"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--mesh", default="4,2")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()
    run(args.arch, args.mesh, args.steps, smoke=args.smoke, seq=args.seq,
        global_batch=args.batch, microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)


if __name__ == "__main__":
    main()
