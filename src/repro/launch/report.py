"""Render the §Roofline table (EXPERIMENTS.md) from launch_out/*.json."""
from __future__ import annotations

import glob
import json
import os

from ..configs import ARCHS
from ..configs.base import shape_cells_for
from . import roofline


def load_cells(out_dir: str, mesh: str = "16x16"):
    cells = {}
    for path in glob.glob(os.path.join(out_dir, f"*__{mesh}.json")):
        rec = json.load(open(path))
        cells[(rec["arch"], rec["shape"])] = rec
    return cells


def render_table(out_dir: str, mesh: str = "16x16") -> str:
    cells = load_cells(out_dir, mesh)
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck |"
        " MODEL_FLOPS | useful frac | fits/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch, cfg in ARCHS.items():
        for cell in shape_cells_for(cfg):
            rec = cells.get((arch, cell.name))
            if rec is None:
                lines.append(f"| {arch} | {cell.name} | — | — | — | MISSING | | | |")
                continue
            t = rec["roofline"]
            mf = roofline.model_flops(cfg, cell) / rec["num_devices"]
            useful = mf / rec["flops"] if rec["flops"] else 0.0
            temp_gib = (rec["memory"]["temp_size_in_bytes"] or 0) / 2**30
            args_gib = (rec["memory"]["argument_size_in_bytes"] or 0) / 2**30
            fits = "Y" if temp_gib + args_gib < 16 else f"N({temp_gib+args_gib:.0f}G)"
            lines.append(
                f"| {arch} | {cell.name} | {t['compute_s']:.3f} | "
                f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
                f"{t['bottleneck']} | {mf:.2e} | {useful:.2f} | {fits} |")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="launch_out")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    print(render_table(args.out, args.mesh))


if __name__ == "__main__":
    main()
