"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package has:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jitted wrapper with padding/layout handling
  ref.py    — pure-jnp oracle (tests assert_allclose against it)

Kernels are validated in interpret=True mode on CPU (the kernel body runs
under the Pallas interpreter); on a real TPU the same pallas_call lowers to
Mosaic.

Hardware adaptation note (see DESIGN.md §2): the paper's CUDA kernels use
thread-per-vertex + atomics. TPU has neither; these kernels restructure the
same computations as *blocked dense* operators:
  ell_spmv        — SSSP relax / PR gather as block-ELL semiring SpMV
  tc_matmul       — triangle counting as masked lower-triangular A·A (MXU)
  flash_attention — blocked attention for the LM substrate (prefill shapes)
"""
