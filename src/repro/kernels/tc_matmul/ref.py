"""Pure-jnp oracle for masked lower-triangular A·A triangle counting."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tc_matmul_ref(lower: jax.Array) -> jax.Array:
    c = lower @ lower
    return jnp.sum(c * lower)
