"""Triangle counting as masked blocked matmul — the MXU-native rewrite of the
paper's Fig. 20 doubly-nested loop.

GraphBLAS identity: with L = strict lower-triangular adjacency of the
undirected closure, triangles = sum( (L @ L) ⊙ L ). The paper's CUDA
backend walks neighbor lists per thread; the TPU has a 128×128 systolic
array instead of independent threads, so we feed it dense tiles:

  grid (I, J, K) over [N/B]³ tiles; A_ik @ A_kj accumulates into a VMEM
  scratch; on the last K step the tile of C is masked by A_ij and reduced
  into a per-(I,J) partial count.

Dense N² is the price of MXU regularity — viable for the per-device vertex
blocks the distributed layer produces (B_block ≤ a few thousand), which is
exactly how CombBLAS-style systems do it at scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tc_body(a_ik_ref, a_kj_ref, a_ij_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ik_ref[...], a_kj_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _final():
        out_ref[0, 0] = jnp.sum(acc_ref[...] * a_ij_ref[...])


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def tc_matmul(lower: jax.Array, *, block: int = 128,
              interpret: bool = True) -> jax.Array:
    """lower: [N, N] float32 strict lower-triangular adjacency (N % block == 0).
    Returns the triangle count as a float32 scalar."""
    n = lower.shape[0]
    assert n % block == 0 and lower.shape == (n, n)
    nb = n // block
    partials = pl.pallas_call(
        functools.partial(_tc_body, n_k=nb),
        grid=(nb, nb, nb),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, k: (i, k)),   # A_ik
            pl.BlockSpec((block, block), lambda i, j, k: (k, j)),   # A_kj
            pl.BlockSpec((block, block), lambda i, j, k: (i, j)),   # mask A_ij
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, nb), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
        interpret=interpret,
    )(lower, lower, lower)
    return jnp.sum(partials)
