"""Graph-level wrapper: CSR → strict-lower dense tiles → MXU triangle count.

Counts each triangle once: L[i,j] = 1 iff (i,j) ∈ E∪Eᵀ and i > j (undirected
closure, strict lower triangle); triangles = Σ (L·L)⊙L.

NOTE: the paper's Fig. 20 counts *directed* wedge closures (u < v < w with
edges v→u, v→w, u→w), which equals the undirected triangle count only for
symmetric graphs. This op computes the undirected count; the DSL's Pallas
backend uses it only after symmetrizing — tests pin both against oracles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ...graph.csr import CSRGraph
from .kernel import tc_matmul

_INTERPRET = jax.default_backend() != "tpu"


def prepare_lower(g: CSRGraph, block: int = 128) -> jax.Array:
    """Dense strict-lower adjacency of the undirected closure, block-padded."""
    n = g.num_nodes
    n_pad = -(-n // block) * block
    a = np.zeros((n_pad, n_pad), np.float32)
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.indices)
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    keep = lo != hi
    a[hi[keep], lo[keep]] = 1.0
    return jnp.asarray(a)


@partial(jax.jit, static_argnames=("block",))
def count_triangles_dense(lower: jax.Array, *, block: int = 128) -> jax.Array:
    block = min(block, lower.shape[0])
    return tc_matmul(lower, block=block, interpret=_INTERPRET).astype(jnp.int32)
