"""Model-facing attention op: GQA head handling + (B, H, S, D) layout glue."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref

_INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "use_kernel"))
def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, use_kernel: bool = True) -> jax.Array:
    """q: [B, Hq, S, D]; k/v: [B, Hkv, Skv, D] with Hq % Hkv == 0."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hq, -1, d)
    vf = v.reshape(b * hq, -1, d)
    if use_kernel and sq >= 8:
        o = flash_attention(qf, kf, vf, causal=causal, interpret=_INTERPRET)
    else:
        o = attention_ref(qf, kf, vf, causal=causal)
    return o.reshape(b, hq, sq, d)
