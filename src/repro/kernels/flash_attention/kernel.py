"""Blocked (flash) attention Pallas kernel for the LM substrate.

Online-softmax attention tiled for VMEM: grid (batch*heads, Q blocks,
KV blocks) with KV innermost; running max/denominator/accumulator live in
VMEM scratch across the KV sweep (initialized at kv==0, written back at the
last block). Causal masking skips fully-masked tiles via the index map and
applies the triangle mask on the diagonal tile.

Target tiling: BQ=BK=128 aligns Q·Kᵀ and P·V with the 128×128 MXU; head_dim
is the contraction minor dim (128 for all assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_body(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               bq: int, bk: int, scale: float, causal: bool, n_kv: int,
               offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0].astype(jnp.float32)            # [BQ, D]
        k = k_ref[0].astype(jnp.float32)            # [BK, D]
        v = v_ref[0].astype(jnp.float32)            # [BK, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            # query i attends to kv position j iff j <= i + offset
            # (offset = skv - sq aligns the query block at the cache end)
            rows = qi * bq + offset + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                         # [BQ, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                      # [BQ, BK]
        alpha = jnp.exp(m_prev - m_new)             # [BQ, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip tiles strictly above the (offset) diagonal
        pl.when(ki * bk <= qi * bq + offset + (bq - 1))(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv - 1)
    def _final():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [BH, SQ, D], k/v: [BH, SKV, D] (same head count — repeat KV heads
    for GQA before calling). Returns [BH, SQ, D] in q.dtype."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0
    scale = 1.0 / (d ** 0.5)
    grid = (bh, sq // bq, skv // bk)
    return pl.pallas_call(
        functools.partial(_attn_body, bq=bq, bk=bk, scale=scale,
                          causal=causal, n_kv=skv // bk, offset=skv - sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
