"""Pure-jnp oracle for blocked attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) * scale
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
