"""Jitted wrappers: graph-level relax/gather ops on the ELL kernel.

These are what the DSL's Pallas backend emits calls to. They own the
padding/layout glue (sentinel slot, row-block padding) so the kernel itself
stays rectangular.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ...graph.csr import CSRGraph, EllGraph, INF_I32, to_ell
from .kernel import ell_spmv

_INTERPRET = jax.default_backend() != "tpu"


def _pad_rows(a, block):
    n = a.shape[0]
    pad = (-n) % block
    if pad == 0:
        return a
    fill = jnp.full((pad,) + a.shape[1:], a.dtype.type(0) if a.ndim == 1 else 0, a.dtype)
    return jnp.concatenate([a, fill], axis=0)


def prepare_ell(g: CSRGraph, *, reverse: bool = False, block_rows: int = 256):
    """Host-side: build the padded ELL arrays once per graph.

    Returns (cols, wts, n_rows_padded). cols pad slots point at the sentinel
    row (index n); wts pad slots are INF (masked out by the semiring)."""
    ell = to_ell(g, reverse=reverse)
    n = g.num_nodes
    cols = np.asarray(ell.cols).copy()
    wts = np.asarray(ell.wts)
    block = min(block_rows, -(-n // 8) * 8)   # 8-aligned, capped at block_rows
    pad = (-n) % block
    n_pad = n + pad
    cols[cols == n] = n_pad                   # sentinel = last slot of padded x
    if pad:
        cols = np.concatenate([cols, np.full((pad, cols.shape[1]), n_pad, np.int32)])
        wts = np.concatenate([wts, np.full((pad, wts.shape[1]), int(INF_I32), np.int32)])
    return jnp.asarray(cols), jnp.asarray(wts), block


@partial(jax.jit, static_argnames=("block_rows",))
def relax_minplus(cols, wts, dist, *, block_rows: int = 256):
    """One SSSP relax sweep: dist'[v] = min(dist[v], min_in-nbr dist[u]+w).
    `cols/wts` must be the REVERSE (in-edge) ELL view; sentinel slot added
    here (x[n] = INF so pad contributions never win... pad wts are INF and
    INF+INF would overflow, so the sentinel x is 0 and pad wts carry INF)."""
    n = dist.shape[0]
    n_pad = cols.shape[0]
    block_rows = min(block_rows, n_pad)   # prepare_ell guarantees divisibility
    # padded slots + the sentinel hold 0 — never read as real neighbors,
    # and 0 keeps INF(pad weight) + x from overflowing int32.
    x = jnp.zeros((n_pad + 1,), dist.dtype).at[:n].set(dist)
    y = ell_spmv(cols, wts, x, semiring="minplus",
                 block_rows=block_rows, interpret=_INTERPRET)
    return jnp.minimum(dist, y[:n])


@partial(jax.jit, static_argnames=("block_rows",))
def gather_plustimes(cols, contrib, n_out: int = None, *, block_rows: int = 256):
    """PR gather: y[v] = sum_{u in-nbr} contrib[u]; `contrib` already divided
    by out-degree. cols = reverse ELL; pad slots hit the 0 sentinel."""
    n = contrib.shape[0]
    n_pad = cols.shape[0]
    block_rows = min(block_rows, n_pad)
    ones = jnp.where(cols == n_pad, 0.0, 1.0).astype(contrib.dtype)
    x = jnp.zeros((n_pad + 1,), contrib.dtype).at[:n].set(contrib)
    y = ell_spmv(cols, ones, x, semiring="plustimes",
                 block_rows=block_rows, interpret=_INTERPRET)
    return y
