"""Jitted wrappers: graph-level relax/gather ops on the ELL kernels.

These are what the DSL's Pallas backend emits calls to. They own the
padding/layout glue (sentinel slot, row-block padding, degree buckets) so
the kernels themselves stay rectangular.

Two layouts coexist:

  * dense ELL (`prepare_ell` → cols/wts arrays): the original single
    `[N, max_deg]` view — kept for the kernel unit tests and as the
    benchmark baseline;
  * sliced ELL (`prepare_sliced_ell` → `SlicedEllGraph`): degree-bucketed
    tiles + a COO hub fallback — the frontier-aware engine's layout.
    `relax_minplus` / `gather_plustimes` dispatch on the first argument.

On non-TPU hosts the sliced ops run an equivalent pure-jnp path instead of
interpret-mode Pallas: identical math, without the interpreter overhead
(the kernels proper are still exercised by tests/test_kernels.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ...graph.csr import (CSRGraph, INF_I32, SlicedEllGraph, to_ell,
                          to_sliced_ell)
from .kernel import _best_block, ell_spmv

_INTERPRET = jax.default_backend() != "tpu"
_USE_KERNEL = not _INTERPRET   # pure-jnp fallback off-TPU (same semantics)

INF = jnp.int32(INF_I32)


def _pad_rows(a, block):
    n = a.shape[0]
    pad = (-n) % block
    if pad == 0:
        return a
    fill = jnp.full((pad,) + a.shape[1:], a.dtype.type(0) if a.ndim == 1 else 0, a.dtype)
    return jnp.concatenate([a, fill], axis=0)


def prepare_ell(g: CSRGraph, *, reverse: bool = False, block_rows: int = 256):
    """Host-side: build the padded dense-ELL arrays once per graph.

    Returns (cols, wts, n_rows_padded). cols pad slots point at the sentinel
    row (index n); wts pad slots are INF (masked out by the semiring)."""
    ell = to_ell(g, reverse=reverse)
    n = g.num_nodes
    cols = np.asarray(ell.cols).copy()
    wts = np.asarray(ell.wts)
    block = min(block_rows, -(-n // 8) * 8)   # 8-aligned, capped at block_rows
    pad = (-n) % block
    n_pad = n + pad
    cols[cols == n] = n_pad                   # sentinel = last slot of padded x
    if pad:
        cols = np.concatenate([cols, np.full((pad, cols.shape[1]), n_pad, np.int32)])
        wts = np.concatenate([wts, np.full((pad, wts.shape[1]), int(INF_I32), np.int32)])
    return jnp.asarray(cols), jnp.asarray(wts), block


def prepare_sliced_ell(g: CSRGraph, *, reverse: bool = True, schedule=None,
                       **knobs) -> SlicedEllGraph:
    """Host-side: degree-bucketed view for the frontier-aware engine.
    Default orientation is reverse (in-edges) — the pull layout. The bucket
    layout comes from `schedule` (a `repro.schedule.Schedule`). Prefer
    `repro.core.context.GraphContext.sliced_ell`, which memoizes this per
    (graph, layout)."""
    return to_sliced_ell(g, reverse=reverse, schedule=schedule, **knobs)


# --------------------------------------------------------------------------
# dense-ELL ops (baseline layout)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("block_rows",))
def _relax_dense(cols, wts, dist, *, block_rows: int = 256):
    """One dense SSSP relax sweep over the single-width ELL view."""
    n = dist.shape[0]
    n_pad = cols.shape[0]
    block_rows = min(block_rows, n_pad)   # prepare_ell guarantees divisibility
    # padded slots + the sentinel hold 0 — never read as real neighbors,
    # and 0 keeps INF(pad weight) + x from overflowing int32.
    x = jnp.zeros((n_pad + 1,), dist.dtype).at[:n].set(dist)
    y = ell_spmv(cols, wts, x, semiring="minplus",
                 block_rows=block_rows, interpret=_INTERPRET)
    return jnp.minimum(dist, y[:n])


@partial(jax.jit, static_argnames=("block_rows",))
def _gather_dense(cols, contrib, *, block_rows: int = 256):
    n = contrib.shape[0]
    n_pad = cols.shape[0]
    block_rows = min(block_rows, n_pad)
    ones = jnp.where(cols == n_pad, 0.0, 1.0).astype(contrib.dtype)
    x = jnp.zeros((n_pad + 1,), contrib.dtype).at[:n].set(contrib)
    y = ell_spmv(cols, ones, x, semiring="plustimes",
                 block_rows=block_rows, interpret=_INTERPRET)
    return y


# --------------------------------------------------------------------------
# sliced-ELL ops (frontier-aware engine)
# --------------------------------------------------------------------------

def _bucket_caps(ell: SlicedEllGraph, block_rows):
    """Per-kept-bucket kernel row-block caps from `Schedule.block_rows`.

    `block_rows` is an int (uniform cap), a {bucket_width: cap} mapping
    (the pallas codegen's literal form — keyed by width because empty
    buckets are dropped from the sliced view, so positional indexing would
    drift), or None (default cap)."""
    if block_rows is None:
        return [256] * len(ell.cols)
    if isinstance(block_rows, dict):
        return [int(block_rows.get(w, 256)) for w in ell.widths]
    return [int(block_rows)] * len(ell.cols)


def _bucket_minplus(cols, wts, x, cap: int = 256):
    """x: [M] (SpMV) or [M, B] (SpMM, lanes = source batch)."""
    if _USE_KERNEL:
        return ell_spmv(cols, wts, x, semiring="minplus",
                        block_rows=_best_block(cols.shape[0], cap),
                        interpret=_INTERPRET)
    if x.ndim == 2:
        wts = wts[..., None]
    return jnp.min(jnp.take(x, cols, axis=0) + wts, axis=1)


def _bucket_plustimes(cols, x, cap: int = 256):
    if _USE_KERNEL:
        ones = jnp.ones(cols.shape, x.dtype)   # pads hit the 0 sentinel
        return ell_spmv(cols, ones, x, semiring="plustimes",
                        block_rows=_best_block(cols.shape[0], cap),
                        interpret=_INTERPRET)
    return jnp.sum(jnp.take(x, cols, axis=0), axis=1)


def _relax_sliced_pull(ell: SlicedEllGraph, dist, frontier=None,
                       block_rows=None):
    """Masked-pull sweep: per-bucket min-plus kernels + COO hub fallback.
    Frontier masking happens on the gather source (x), so the kernels stay
    unmasked and rectangular. dist may be [N] (one traversal) or [B, N]
    (batched: the gathered operand becomes the [N+1, B] matrix the SpMM
    kernel consumes — batch lanes minor, so every bucket tile is reused
    across all B sources in one pass). This and `_relax_push` are the
    kernel-layer copies of the push/pull relaxation — keep in sync with
    runtime.relax_minplus_hybrid (see the NOTE there)."""
    n = ell.num_nodes
    x = dist if frontier is None else jnp.where(frontier, dist, INF)
    batched = dist.ndim == 2
    if batched:
        # sentinel slot (index n) holds 0 so INF pad weights never overflow
        x_ext = jnp.zeros((n + 1, dist.shape[0]), dist.dtype).at[:n].set(x.T)
        y = jnp.full((n, dist.shape[0]), INF, dist.dtype)
    else:
        x_ext = jnp.zeros((n + 1,), dist.dtype).at[:n].set(x)
        y = jnp.full((n,), INF, dist.dtype)
    for cols, wts, rows, cap in zip(ell.cols, ell.wts, ell.rows,
                                    _bucket_caps(ell, block_rows)):
        y = y.at[rows].min(_bucket_minplus(cols, wts, x_ext, cap), mode="drop")
    if ell.hub_rows.shape[0]:
        hub_w = ell.hub_wts[:, None] if batched else ell.hub_wts
        y = y.at[ell.hub_rows].min(x_ext[ell.hub_cols] + hub_w, mode="drop")
    return jnp.minimum(dist, y.T if batched else y)


def _relax_push(g: CSRGraph, dist, frontier):
    """Scatter-push from the (sparse) frontier over out-edges.
    dist/frontier: [N] or [B, N] (row-wise scatter-min)."""
    if dist.ndim == 2:
        cand = dist[:, g.edge_src] + g.weights[None, :]
        cand = jnp.where(frontier[:, g.edge_src], cand, INF)
        return dist.at[:, g.indices].min(cand)
    cand = dist[g.edge_src] + g.weights
    cand = jnp.where(frontier[g.edge_src], cand, INF)
    return dist.at[g.indices].min(cand)


def relax_minplus(cols_or_ell, wts_or_dist, dist=None, *, frontier=None,
                  csr: CSRGraph | None = None, block_rows=256,
                  threshold_frac: float | None = None,
                  direction: str = "auto"):
    """One SSSP relax step.

    Dense form (baseline): `relax_minplus(cols, wts, dist)` — full pull
    sweep over the `[N, max_deg]` reverse-ELL view.

    Sliced form (engine): `relax_minplus(ell, dist, frontier=fr, csr=g)` —
    frontier-masked, direction-optimized: when the frontier occupancy is
    under `threshold_frac · N` (the compiled `Schedule`'s knob; `None`
    falls back to the deprecated `ENGINE` shim) the relax runs push-style
    over the CSR out-edges (scatter-min), otherwise as per-bucket pull
    kernels. `direction="push"|"pull"` pins one branch. Both directions
    compute the identical relaxation, so neither the on-device `lax.cond`
    switch nor a pinned direction ever changes results.

    Batched sliced form: dist/frontier [B, N] — the pull sweep becomes a
    per-bucket min-plus SpMM over the [N+1, B] operand, and the push/pull
    choice is made per batch ROW (homogeneous batches take a single-
    direction fast path; mixed batches run each direction masked to its
    rows, which partition the frontier, so the result is exact).

    `block_rows` caps the kernel row-block per bucket: an int (uniform
    cap), or — sliced form only — a {bucket_width: cap} mapping, the
    literal form `Schedule.block_rows` reaches generated code in."""
    if not isinstance(cols_or_ell, SlicedEllGraph):
        return _relax_dense(cols_or_ell, wts_or_dist, dist,
                            block_rows=int(block_rows))
    if dist is not None:
        raise TypeError(
            "sliced form takes (ell, dist) positionally; pass the frontier "
            "as relax_minplus(ell, dist, frontier=fr, csr=g)")
    ell, dist = cols_or_ell, wts_or_dist
    if frontier is None or csr is None:
        # dense sweep (or no CSR for push): pull is the only orientation
        return _relax_sliced_pull(ell, dist, frontier, block_rows)
    if direction == "push":
        return _relax_push(csr, dist, frontier)
    if direction == "pull":
        return _relax_sliced_pull(ell, dist, frontier, block_rows)
    from ...core.runtime import (_cond_by_rows, frontier_rows_should_push,
                                 frontier_should_push)
    if dist.ndim == 2:
        rows_push = frontier_rows_should_push(frontier, ell.num_nodes,
                                              threshold_frac)
        return _cond_by_rows(
            rows_push,
            lambda d: _relax_push(csr, d, frontier),
            lambda d: _relax_sliced_pull(ell, d, frontier, block_rows),
            lambda d: _relax_sliced_pull(
                ell, _relax_push(csr, d, frontier & rows_push[:, None]),
                frontier & ~rows_push[:, None], block_rows),
            dist)
    return jax.lax.cond(
        frontier_should_push(frontier, ell.num_nodes, threshold_frac),
        lambda d: _relax_push(csr, d, frontier),
        lambda d: _relax_sliced_pull(ell, d, frontier, block_rows),
        dist)


def gather_plustimes(cols_or_ell, contrib, n_out: int = None, *,
                     block_rows=256):
    """PR gather: y[v] = sum_{u in-nbr} contrib[u]; `contrib` already divided
    by out-degree.

    Dense form: `gather_plustimes(cols, contrib)` (returns padded rows).
    Sliced form: `gather_plustimes(ell, contrib)` (returns exactly [N]).
    Batched sliced form: contrib [B, N] → [B, N] (plus-times SpMM, one
    bucket pass shared by all B lanes). `block_rows` caps the per-bucket
    kernel row-block (int, or {bucket_width: cap} in the sliced form)."""
    if not isinstance(cols_or_ell, SlicedEllGraph):
        return _gather_dense(cols_or_ell, contrib, block_rows=int(block_rows))
    ell = cols_or_ell
    n = ell.num_nodes
    batched = contrib.ndim == 2
    if batched:
        x_ext = jnp.zeros((n + 1, contrib.shape[0]),
                          contrib.dtype).at[:n].set(contrib.T)
        y = jnp.zeros((n, contrib.shape[0]), contrib.dtype)
    else:
        x_ext = jnp.zeros((n + 1,), contrib.dtype).at[:n].set(contrib)
        y = jnp.zeros((n,), contrib.dtype)
    for cols, rows, cap in zip(ell.cols, ell.rows,
                               _bucket_caps(ell, block_rows)):
        y = y.at[rows].add(_bucket_plustimes(cols, x_ext, cap), mode="drop")
    if ell.hub_rows.shape[0]:
        y = y.at[ell.hub_rows].add(x_ext[ell.hub_cols], mode="drop")
    return y.T if batched else y
