"""Pure-jnp oracle for the block-ELL semiring SpMV."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_spmv_ref(cols: jax.Array, vals: jax.Array, x: jax.Array,
                 semiring: str = "minplus") -> jax.Array:
    gathered = x[cols]                      # [N, D]
    if semiring == "minplus":
        return jnp.min(gathered + vals, axis=1)
    if semiring == "plustimes":
        return jnp.sum(gathered * vals, axis=1)
    raise ValueError(semiring)
