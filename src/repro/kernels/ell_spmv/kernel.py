"""Block-ELL semiring SpMV/SpMM Pallas kernel.

The paper's CUDA relax kernel (Fig. 9) is thread-per-vertex with atomicMin
into the neighbor. TPU restructuring: the CSR is padded to a rectangular
ELL neighbor matrix (cols/vals [N, D]); one grid step processes a row block
of BR vertices, gathering x[cols] from a VMEM-resident x and reducing along
the degree axis — a *pull* formulation, so no atomics/scatter exist at all.

  minplus   : y[i] = min_k ( x[cols[i,k]] + vals[i,k] )     (SSSP relax)
  plustimes : y[i] = sum_k ( x[cols[i,k]] * vals[i,k] )     (PR gather)

The operand generalizes over a batch of sources: x may be a [N+1] vector
(SpMV, single traversal) or a [N+1, B] matrix (SpMM — B batch lanes, one
per source of a multi-source traversal). The gather then pulls whole
B-lane rows of x, and the degree-axis reduction is elementwise across
lanes, which is exactly the layout a vector/matrix unit wants: lanes =
batch, sublanes = degree.

VMEM budget per grid step: BR*D*(4+4) bytes for the tile + (N+1)*B*4 for x.
For graphs whose x exceeds VMEM, shard rows across devices first (the
distributed backend does exactly that) — each shard's x block then fits.
Padding protocol: cols pad = N (sentinel row of x, holding the semiring
annihilator-safe value 0), vals pad = INF (minplus) / 0 (plustimes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _minplus_body(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]                    # [BR, D] int32
    vals = vals_ref[...]                    # [BR, D] int32
    x = x_ref[...]                          # [N+1] or [N+1, B] int32
    gathered = jnp.take(x, cols, axis=0)    # [BR, D] or [BR, D, B]
    if x.ndim == 2:
        vals = vals[..., None]              # broadcast weights across lanes
    y_ref[...] = jnp.min(gathered + vals, axis=1)


def _plustimes_body(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]
    vals = vals_ref[...]
    x = x_ref[...]
    gathered = jnp.take(x, cols, axis=0)
    if x.ndim == 2:
        vals = vals[..., None]
    y_ref[...] = jnp.sum(gathered * vals, axis=1)


@functools.partial(jax.jit, static_argnames=("semiring", "block_rows", "interpret"))
def ell_spmv(cols: jax.Array, vals: jax.Array, x: jax.Array, *,
             semiring: str = "minplus", block_rows: int = 256,
             interpret: bool = True) -> jax.Array:
    """cols/vals: [R, D] (R divisible by block_rows); x: the gather source,
    VMEM-resident, with the sentinel slot last (so any length ≥ max(cols)+1 —
    sliced-ELL buckets have R ≪ len(x)). x may be [M] (SpMV → y [R]) or
    [M, B] (SpMM over B batch lanes → y [R, B])."""
    n, d = cols.shape
    assert n % block_rows == 0, (n, block_rows)
    m = x.shape[0]
    body = _minplus_body if semiring == "minplus" else _plustimes_body
    grid = (n // block_rows,)
    if x.ndim == 1:
        x_spec = pl.BlockSpec((m,), lambda i: (0,))
        out_spec = pl.BlockSpec((block_rows,), lambda i: (i,))
        out_shape = jax.ShapeDtypeStruct((n,), x.dtype)
    else:
        b = x.shape[1]
        x_spec = pl.BlockSpec((m, b), lambda i: (0, 0))
        out_spec = pl.BlockSpec((block_rows, b), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((n, b), x.dtype)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),   # cols tile
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),   # vals tile
            x_spec,                                            # x resident
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(cols, vals, x)


def _best_block(rows: int, cap: int = 256) -> int:
    """Largest power-of-two row block ≤ cap dividing `rows` (rows % 8 == 0).
    Sliced-ELL buckets (ops.py) pick their grid with this; `cap` is the
    per-bucket `Schedule.block_rows` knob (a tall block amortizes grid-step
    overhead, a short one keeps the block×width tile inside VMEM)."""
    b = 8
    while b * 2 <= cap and rows % (b * 2) == 0:
        b *= 2
    return b
