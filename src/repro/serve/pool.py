"""GraphPool: the multi-graph GraphContext pool behind `GraphService`.

A long-lived server holds many registered graphs, each with derived
execution views (sliced-ELL buckets, delta-ELL, padded ELL) living in its
`GraphContext`. Those views are pure caches — every consumer resolves them
through the context per call — so under memory pressure the pool can drop
the least-recently-used graph's views and let the next query transparently
re-prepare them. What the pool never does:

* drop the *graph* itself (a registered graph stays resident until
  `remove()`; only derived views are evicted);
* drop the metadata views (`fingerprint`, `stats`) that key persisted
  tuning records (`GraphContext.drop_derived_views` keeps them);
* evict a graph that is **pinned** — `GraphService` pins a graph for the
  duration of every sweep over it, so eviction can never race a running
  computation's view resolution.

Accounting uses `GraphContext.total_view_nbytes()` (approximate: array
buffers reachable from each view). `enforce_budget()` walks graphs in LRU
order and drops views until the pool fits `view_budget_bytes`.
"""
from __future__ import annotations

import contextlib
from typing import Optional

from ..core.context import GraphContext, get_context


class _Entry:
    __slots__ = ("name", "graph", "ctx", "seq", "pins", "deferred")

    def __init__(self, name: str, graph, ctx: GraphContext, seq: int):
        self.name = name
        self.graph = graph     # strong: a registered graph stays resident
        self.ctx = ctx
        self.seq = seq         # LRU clock: larger = more recently used
        self.pins = 0          # >0 while a sweep over this graph runs
        self.deferred = []     # mutations queued while pinned (see defer())


class GraphPool:
    """Named registry of (graph, GraphContext) pairs with memory-bounded
    LRU eviction of derived views."""

    def __init__(self, view_budget_bytes: Optional[int] = None):
        if view_budget_bytes is not None and view_budget_bytes <= 0:
            raise ValueError(
                f"view_budget_bytes must be positive (or None for "
                f"unbounded), got {view_budget_bytes}")
        self.view_budget_bytes = view_budget_bytes
        self._entries: dict = {}
        self._clock = 0
        self.evictions: list = []      # (name, freed_bytes) log, oldest first

    # ---- registry --------------------------------------------------------
    def add(self, name: str, graph) -> GraphContext:
        if name in self._entries:
            raise ValueError(f"graph {name!r} is already registered")
        self._clock += 1
        self._entries[name] = _Entry(name, graph, get_context(graph),
                                     self._clock)
        return self._entries[name].ctx

    def remove(self, name: str) -> None:
        self._entries.pop(name, None)

    def get(self, name: str, *, touch: bool = True) -> _Entry:
        entry = self._entries.get(name)
        if entry is None:
            raise KeyError(f"no graph named {name!r} in the pool "
                           f"(registered: {sorted(self._entries) or '<none>'})")
        if touch:
            self._clock += 1
            entry.seq = self._clock
        return entry

    def names(self) -> list:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    # ---- pinning (sweep-in-progress protection) --------------------------
    @contextlib.contextmanager
    def pin(self, name: str):
        """Hold the graph un-evictable for the duration of a sweep. Pins
        nest (two lanes of the same graph may sweep concurrently). When the
        last pin drops, mutations deferred while pinned run (in order) —
        this is how a write batch waits out in-flight sweeps."""
        entry = self.get(name)
        entry.pins += 1
        try:
            yield entry
        finally:
            entry.pins -= 1
            if entry.pins == 0 and entry.deferred:
                pending, entry.deferred = entry.deferred, []
                for fn in pending:
                    fn(entry)

    def defer(self, name: str, fn) -> bool:
        """Run `fn(entry)` now if the graph is unpinned, else queue it to
        run when the last pin drops. Pin/unpin and defer all happen on the
        service's event-loop thread, so no locking is needed; a sweep that
        pins after the mutation ran sees the new state, one already pinned
        finishes against the old. Returns True when `fn` ran immediately."""
        entry = self.get(name, touch=False)
        if entry.pins == 0:
            fn(entry)
            return True
        entry.deferred.append(fn)
        return False

    # ---- memory accounting + eviction ------------------------------------
    def view_nbytes(self) -> int:
        return sum(e.ctx.total_view_nbytes() for e in self._entries.values())

    def enforce_budget(self) -> list:
        """Evict LRU graphs' derived views until the pool fits the budget.
        Pinned graphs are skipped (never drop views mid-sweep); with no
        budget this is a no-op. Returns the names evicted this call."""
        if self.view_budget_bytes is None:
            return []
        evicted = []
        over = self.view_nbytes() - self.view_budget_bytes
        if over <= 0:
            return evicted
        for entry in sorted(self._entries.values(), key=lambda e: e.seq):
            if over <= 0:
                break
            if entry.pins > 0:
                continue
            freed = entry.ctx.drop_derived_views()
            if freed:
                over -= freed
                evicted.append(entry.name)
                self.evictions.append((entry.name, freed))
        return evicted
