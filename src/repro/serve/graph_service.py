"""GraphService: the async multi-tenant graph-analytics serving layer.

The engine underneath (Schedule / GraphContext / compile cache / batched
[N, B] SpMM lanes) makes one *sweep* cheap and lets one sweep answer
``Schedule.batch_sources`` source queries at once — but something has to
*fill* those lanes from real concurrent traffic. That is this module's
job, and it is a scheduling decision in the GraphIt sense: which requests
share a sweep never changes any answer, only how fast the answers arrive.

    service = GraphService(ServiceConfig(max_wait_ms=5.0))
    service.register_graph("social", g)          # tuned + prepared + bound
    dist = await service.query("social", "sssp", src=17)

How a query is served:

1.  **Admission** — a request is accepted only while fewer than
    ``max_pending`` requests are in flight; past that the service sheds
    load with `ServiceOverloaded` instead of queueing unboundedly.
2.  **Coalescing** — accepted requests land in a lane keyed by
    (graph, query kind). The lane dispatcher dequeues up to the kind's
    lane width (``Schedule.batch_sources`` for per-source kinds) of
    compatible requests, waiting at most ``max_wait_ms`` for lane-mates so
    a lone query is never starved, then runs ONE batched sweep and
    scatters the per-source rows back to each awaiting future.
3.  **Deadlines** — each request carries a timeout (default
    ``default_timeout_s``); a request that times out while queued is
    dropped before the sweep forms, and one that times out mid-sweep
    simply never receives its (still computed) row.

Registration is where all the one-time cost goes, so a registered graph's
first query already hits a tuned, pre-prepared, pre-compiled path:
`register_graph` fingerprints the graph, warm-reloads any persisted
`TuningStore` record for (program digest, backend, fingerprint), compiles
the bundled programs under the tuned (or configured) schedule through the
compile cache, prepares the graph's derived views, and binds the programs
(`CompiledProgram.bind` is memoized per (program, graph)).

Graphs are held in a `GraphPool` with memory-bounded LRU eviction of
derived views: under view-memory pressure the least-recently-used graph's
views are dropped (never the graph itself, and never while a sweep over it
is pinned) and the next query transparently re-prepares.

Query kinds (`QueryKind`) define what a lane computes. Built-ins:

* ``sssp`` — per-source weighted distances; coalesced via the batched
  delta-capable multi-query engine (`rt.sssp_multi`); ``src=`` required.
* ``bfs``  — per-source hop levels (`rt.bfs_levels_batch`).
* ``bc``   — Brandes betweenness over the request's own ``sourceSet=``.
  BC is an *aggregate* over its source set, so requests are not per-source
  separable across users; each request runs as its own sweep, with the
  set's sources batched into the program's internal [N, B] lanes.
* ``ppr``  — per-user personalized PageRank (`rt.ppr_multi`): each user's
  restart vector is one lane of a batched SpMM operand, so B users'
  personalization queries share a single sweep; ``src=`` required.

Other personalization kinds slot in the same way: subclass `QueryKind`
and `register_kind` it.

See ``docs/serving.md`` for the architecture and the `ServiceConfig` knob
table (lint-checked against the dataclass by tests/test_docs.py).
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..autotune import TuningStore, source_digest
from ..core import compile_bundled, load_program_source, prepare
from ..core import runtime as rt
from ..core.analysis import ERROR as ANALYSIS_ERROR
from ..core.analysis import check_schedule, program_analysis
from ..schedule import Schedule
from .pool import GraphPool


# --------------------------------------------------------------------------
# errors
# --------------------------------------------------------------------------

class ServiceError(RuntimeError):
    """Base class for serving-layer failures."""


class ServiceOverloaded(ServiceError):
    """Admission control rejected the request (max_pending in flight)."""


class ServiceTimeout(ServiceError):
    """The request's deadline expired before its sweep completed."""


class ServiceClosed(ServiceError):
    """The service is shut down; no further queries are accepted."""


class UnknownGraph(ServiceError, LookupError):
    pass


class UnknownQueryKind(ServiceError, LookupError):
    pass


# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Frozen serving knobs (the Schedule analogue one layer up).

    Documented knob-by-knob in ``docs/serving.md``; that table is asserted
    against ``dataclasses.fields(ServiceConfig)`` by the docs lint."""

    backend: str = "local"             # codegen backend: local | pallas
    schedule: Optional[Schedule] = None  # default Schedule (None = Schedule())
    coalesce: bool = True              # False: one query per sweep (baseline)
    max_wait_ms: float = 5.0           # lane-mate wait before a partial sweep
    max_pending: int = 1024            # admission bound on in-flight requests
    default_timeout_s: Optional[float] = 30.0   # per-request deadline
    max_concurrent_sweeps: int = 1     # sweeps running at once (threads)
    view_budget_bytes: Optional[int] = None     # GraphPool eviction bound

    def __post_init__(self):
        if self.backend not in ("local", "pallas"):
            raise ValueError(
                f"ServiceConfig.backend must be 'local' or 'pallas' (the "
                f"single-process serving backends), got {self.backend!r}")
        if self.max_wait_ms < 0:
            raise ValueError(
                f"ServiceConfig.max_wait_ms must be >= 0, got "
                f"{self.max_wait_ms}")
        if self.max_pending < 1:
            raise ValueError(
                f"ServiceConfig.max_pending must be >= 1, got "
                f"{self.max_pending}")
        if self.default_timeout_s is not None and self.default_timeout_s <= 0:
            raise ValueError(
                f"ServiceConfig.default_timeout_s must be positive or None "
                f"(no deadline), got {self.default_timeout_s}")
        if self.max_concurrent_sweeps < 1:
            raise ValueError(
                f"ServiceConfig.max_concurrent_sweeps must be >= 1, got "
                f"{self.max_concurrent_sweeps}")
        if self.view_budget_bytes is not None and self.view_budget_bytes <= 0:
            raise ValueError(
                f"ServiceConfig.view_budget_bytes must be positive or None "
                f"(unbounded), got {self.view_budget_bytes}")


# --------------------------------------------------------------------------
# query kinds
# --------------------------------------------------------------------------

def _pad_width(k: int, width: int) -> int:
    """Lane count a k-request batch runs at: the next power of two, capped
    at the lane width — so the jitted batched sweep retraces O(log width)
    times total instead of once per distinct batch size."""
    b = 1
    while b < k:
        b *= 2
    return max(1, min(b, max(width, k)))


class QueryKind:
    """One servable query type: how to validate a request's params and how
    to run a batch of them as one sweep.

    ``per_source=True`` kinds take ``src=<vertex>`` and are coalescable:
    many users' sources pack into one [N, B]-lane sweep whose row b is
    exactly request b's answer. ``per_source=False`` kinds (aggregates
    like BC) run one request per sweep."""

    name: str = ""
    per_source: bool = True
    program: Optional[str] = None    # bundled DSL program to compile + bind

    def check_params(self, params: dict) -> None:
        if self.per_source:
            if set(params) != {"src"}:
                raise ValueError(
                    f"{self.name!r} queries take exactly src=<vertex>, got "
                    f"{sorted(params) or 'nothing'}")
        elif "sourceSet" not in params:
            raise ValueError(f"{self.name!r} queries require sourceSet=")

    def make_runner(self, handle, sched: Schedule, width: int):
        """Return ``run(params_list) -> [result, ...]`` (called off-loop)."""
        raise NotImplementedError


class SsspKind(QueryKind):
    """Per-source weighted distances (int32[N] per request)."""

    name = "sssp"
    program = "sssp"

    def make_runner(self, handle, sched: Schedule, width: int):
        batched = jax.jit(functools.partial(
            rt.sssp_multi, threshold_frac=sched.push_threshold_frac,
            direction=sched.direction, priority=sched.priority,
            delta_bucket=sched.delta_bucket))
        bound = handle.bounds.get("sssp")

        def run(params_list):
            srcs = [int(p["src"]) for p in params_list]
            if len(srcs) == 1 and bound is not None:
                # the one-query-per-sweep path IS the compiled program
                return [np.asarray(bound(src=srcs[0])["dist"])]
            b = _pad_width(len(srcs), width)
            arr = np.full(b, srcs[0], np.int32)
            arr[:len(srcs)] = srcs
            dist = jax.block_until_ready(
                batched(handle.graph, jnp.asarray(arr)))
            dist = np.asarray(dist)
            return [dist[i] for i in range(len(srcs))]

        return run


class BfsKind(QueryKind):
    """Per-source hop levels (int32[N] per request; -1 = unreached)."""

    name = "bfs"

    def make_runner(self, handle, sched: Schedule, width: int):
        batched = jax.jit(functools.partial(
            rt.bfs_levels_batch, threshold_frac=sched.push_threshold_frac,
            direction=sched.direction))

        def run(params_list):
            srcs = [int(p["src"]) for p in params_list]
            b = _pad_width(len(srcs), width)
            arr = np.full(b, srcs[0], np.int32)
            arr[:len(srcs)] = srcs
            level, _depth = batched(handle.graph, jnp.asarray(arr))
            level = np.asarray(jax.block_until_ready(level))
            return [level[i] for i in range(len(srcs))]

        return run


class BcKind(QueryKind):
    """Betweenness centrality over the request's own source set
    (float[N] per request — an aggregate, so never coalesced across
    requests; the set's sources still fill the program's internal lanes)."""

    name = "bc"
    per_source = False
    program = "bc"

    def make_runner(self, handle, sched: Schedule, width: int):
        bound = handle.bounds["bc"]

        def run(params_list):
            out = []
            for p in params_list:
                srcs = np.asarray(p["sourceSet"], np.int32)
                out.append(np.asarray(bound(sourceSet=srcs)["BC"]))
            return out

        return run


class PprKind(QueryKind):
    """Per-user personalized PageRank (float32[N] per request): the user's
    restart vector is the indicator on their ``src=`` vertex, and B users'
    vectors pack into one batched sweep (`rt.ppr_multi`)."""

    name = "ppr"
    program = "ppr"

    def make_runner(self, handle, sched: Schedule, width: int):
        batched = jax.jit(functools.partial(rt.ppr_multi))
        bound = handle.bounds.get("ppr")

        def run(params_list):
            srcs = [int(p["src"]) for p in params_list]
            if len(srcs) == 1 and bound is not None:
                # a singleton seed set's aggregate PPR IS the user's row
                out = bound(beta=1e-4, delta=0.85, maxIter=100,
                            sourceSet=np.asarray(srcs, np.int32))
                return [np.asarray(out["ppr"], np.float32)]
            b = _pad_width(len(srcs), width)
            arr = np.full(b, srcs[0], np.int32)
            arr[:len(srcs)] = srcs
            rank = jax.block_until_ready(
                batched(handle.graph, jnp.asarray(arr)))
            rank = np.asarray(rank)
            return [rank[i] for i in range(len(srcs))]

        return run


BUILTIN_KINDS = (SsspKind(), BfsKind(), BcKind(), PprKind())


# --------------------------------------------------------------------------
# the service
# --------------------------------------------------------------------------

class _Request:
    __slots__ = ("params", "future", "arrival")

    def __init__(self, params, future, arrival):
        self.params = params
        self.future = future
        self.arrival = arrival


class _Lane:
    """One coalescing queue: (graph, kind) → pending requests + dispatcher."""

    __slots__ = ("graph", "kind", "runner", "width", "items", "event", "task")

    def __init__(self, graph: str, kind: QueryKind, runner, width: int):
        self.graph = graph
        self.kind = kind
        self.runner = runner
        self.width = width
        self.items: collections.deque = collections.deque()
        self.event: Optional[asyncio.Event] = None   # created on the loop
        self.task: Optional[asyncio.Task] = None


class _GraphHandle:
    __slots__ = ("name", "graph", "ctx", "schedules", "programs", "bounds",
                 "tuned")

    def __init__(self, name, graph, ctx):
        self.name = name
        self.graph = graph
        self.ctx = ctx
        self.schedules: dict = {}   # kind name -> Schedule served under
        self.programs: dict = {}    # program name -> CompiledProgram
        self.bounds: dict = {}      # program name -> BoundProgram
        self.tuned: list = []       # kind names warm-loaded from the store


class GraphService:
    """Async multi-tenant serving front end over the batched graph engine.

    Construct, `register_graph` each graph (expensive: tune/compile/
    prepare/bind happen here), then `await query(...)` from any number of
    concurrent clients. `await close()` (or ``async with``) shuts down."""

    def __init__(self, config: Optional[ServiceConfig] = None, *,
                 tune_store=None):
        self.config = config or ServiceConfig()
        if isinstance(tune_store, str):
            tune_store = TuningStore(tune_store)
        self.tune_store: Optional[TuningStore] = tune_store
        self._pool = GraphPool(self.config.view_budget_bytes)
        self._kinds: dict = {k.name: k for k in BUILTIN_KINDS}
        self._graphs: dict = {}
        self._lanes: dict = {}
        self._pending = 0
        self._closed = False
        self._sweep_sem: Optional[asyncio.Semaphore] = None
        self._stats = collections.Counter()

    # ---- registration ----------------------------------------------------
    def register_kind(self, kind: QueryKind) -> None:
        """Add a custom `QueryKind` (PPR-style workloads); must happen
        before the graphs that should serve it are registered."""
        if not kind.name:
            raise ValueError("QueryKind needs a non-empty name")
        self._kinds[kind.name] = kind

    def register_graph(self, name: str, g, *, schedule: Optional[Schedule]
                       = None, kinds=None) -> _GraphHandle:
        """Register a graph for serving; all one-time cost happens here.

        Per query kind: resolve the schedule (explicit `schedule=` beats a
        warm-reloaded `TuningStore` record, which beats the config
        default), compile the kind's bundled program under it (compile-
        cache resident), prepare the graph's derived views, and memoize the
        bound runner — so the first query is pure execution."""
        if self._closed:
            raise ServiceClosed("service is closed")
        if name in self._graphs:
            raise ValueError(f"graph {name!r} is already registered")
        ctx = self._pool.add(name, g)
        handle = _GraphHandle(name, g, ctx)
        kind_names = list(kinds) if kinds is not None else list(self._kinds)
        for kname in kind_names:
            kind = self._kinds.get(kname)
            if kind is None:
                self._pool.remove(name)
                raise UnknownQueryKind(
                    f"no query kind named {kname!r} (registered: "
                    f"{sorted(self._kinds)})")
            sched = schedule or self._warm_schedule(kind, ctx, handle) \
                or self.config.schedule or Schedule()
            handle.schedules[kname] = sched
            if kind.program:
                prog = compile_bundled(kind.program,
                                       backend=self.config.backend,
                                       schedule=sched)
                prepare(g, program=prog)
                handle.programs[kind.program] = prog
                handle.bounds[kind.program] = prog.bind(g)   # memoized
            width = sched.batch_sources \
                if (self.config.coalesce and kind.per_source) else 1
            self._lanes[(name, kname)] = _Lane(
                name, kind, kind.make_runner(handle, sched, max(1, width)),
                max(1, width))
        self._graphs[name] = handle
        with self._pool.pin(name):      # never evict what we just warmed
            self._pool.enforce_budget()
        return handle

    def _warm_schedule(self, kind: QueryKind, ctx,
                       handle) -> Optional[Schedule]:
        """TuningStore warm-reload: a persisted record for (program digest,
        backend, graph fingerprint) supplies the serving schedule, so a
        registered graph's first query hits the tuned path without a
        measurement sweep."""
        if self.tune_store is None or not kind.program:
            return None
        digest = source_digest(load_program_source(kind.program))
        rec = self.tune_store.lookup(digest, self.config.backend,
                                     ctx.fingerprint())
        if rec is None:
            return None
        try:
            sched = rec.best_schedule()
        except ValueError:
            return None          # stored schedule not valid here -> default
        # legality gate on the reloaded schedule: a record tuned under an
        # older analysis (or hand-edited on disk) may combine knobs the
        # compile gate now rejects — fall back to the default rather than
        # fail registration with a DiagnosticError
        fx = program_analysis(
            load_program_source(kind.program)).functions.get(rec.fn_name)
        if fx is not None and any(
                d.severity == ANALYSIS_ERROR
                for d in check_schedule(fx, sched, self.config.backend)):
            return None
        handle.tuned.append(kind.name)
        return sched

    # ---- write batches ---------------------------------------------------
    async def update_graph(self, name: str, *, adds=None, dels=None,
                           weights=None):
        """Apply an edge write batch to a registered graph; returns the
        `GraphDelta` once applied.

        The swap is atomic with respect to sweeps: if the graph is pinned
        by an in-flight sweep the mutation defers until the last pin drops
        (`GraphPool.defer`), so a sweep always runs against one consistent
        version. Applying swaps the pool entry and handle to the new graph
        version (its sliced-ELL views delta-patched by `update()` itself),
        re-binds the handle's compiled programs, and rebuilds the kind
        runners — queued queries dispatched after the swap see the new
        version. Runs host-side on the event-loop thread: updates are
        assumed rare relative to queries (a write batch is an O(E) CSR
        rebuild, roughly one sweep's worth of work)."""
        if self._closed:
            raise ServiceClosed("service is closed")
        handle = self._graphs.get(name)
        if handle is None:
            raise UnknownGraph(
                f"no graph named {name!r} (registered: "
                f"{self._pool.names() or '<none>'})")
        loop = asyncio.get_running_loop()
        fut = loop.create_future()

        def apply(entry):
            try:
                delta = handle.graph.update(adds=adds, dels=dels,
                                            weights=weights)
                self._install_update(handle, entry, delta)
            except Exception as exc:
                if not fut.done():
                    fut.set_exception(exc)
                return
            if not fut.done():
                fut.set_result(delta)

        self._pool.defer(name, apply)
        return await fut

    def _install_update(self, handle, entry, delta) -> None:
        """Swap handle + pool entry to `delta.graph` and rebuild everything
        that closed over the old version (bound programs, kind runners)."""
        from ..core.context import get_context
        new_g = delta.graph
        ctx = get_context(new_g)       # registered (and patched) by update()
        entry.graph, entry.ctx = new_g, ctx
        handle.graph, handle.ctx = new_g, ctx
        for pname, prog in handle.programs.items():
            prepare(new_g, program=prog)
            handle.bounds[pname] = prog.bind(new_g)
        for (gname, kname), lane in self._lanes.items():
            if gname == handle.name:
                lane.runner = lane.kind.make_runner(
                    handle, handle.schedules[kname], lane.width)
        self._stats["updates"] += 1

    def unregister_graph(self, name: str) -> None:
        for key in [k for k in self._lanes if k[0] == name]:
            lane = self._lanes.pop(key)
            if lane.task is not None:
                lane.task.cancel()
            self._fail_lane(lane, ServiceClosed(f"graph {name!r} removed"))
        self._graphs.pop(name, None)
        self._pool.remove(name)

    # ---- the query path --------------------------------------------------
    async def query(self, graph: str, kind: str, *, timeout=-1.0, **params):
        """Serve one query; returns the kind's per-request result (e.g. the
        int32[N] distance row for ``sssp``). Raises `ServiceOverloaded`
        when admission sheds the request, `ServiceTimeout` past the
        deadline (``timeout=`` overrides the config default; None = no
        deadline)."""
        if self._closed:
            raise ServiceClosed("service is closed")
        lane = self._lanes.get((graph, kind))
        if lane is None:
            if graph not in self._graphs:
                raise UnknownGraph(
                    f"no graph named {graph!r} (registered: "
                    f"{self._pool.names() or '<none>'})")
            raise UnknownQueryKind(
                f"graph {graph!r} serves {sorted(k for g, k in self._lanes if g == graph)}, "
                f"not {kind!r}")
        lane.kind.check_params(params)
        if self._pending >= self.config.max_pending:
            self._stats["rejected"] += 1
            raise ServiceOverloaded(
                f"{self._pending} requests in flight >= max_pending="
                f"{self.config.max_pending}")

        loop = asyncio.get_running_loop()
        self._ensure_running(lane, loop)
        fut = loop.create_future()
        self._pending += 1
        fut.add_done_callback(self._on_done)
        lane.items.append(_Request(params, fut, loop.time()))
        lane.event.set()
        if timeout == -1.0:
            timeout = self.config.default_timeout_s
        try:
            return await asyncio.wait_for(fut, timeout)
        except asyncio.TimeoutError:
            self._stats["timeouts"] += 1
            raise ServiceTimeout(
                f"{kind} query on {graph!r} missed its {timeout}s deadline "
                "(the service is overloaded or the sweep is large)") from None

    def _on_done(self, fut):
        self._pending -= 1

    def _ensure_running(self, lane: _Lane, loop) -> None:
        if lane.task is None or lane.task.done():
            if self._sweep_sem is None:
                self._sweep_sem = asyncio.Semaphore(
                    self.config.max_concurrent_sweeps)
            if lane.event is None:
                lane.event = asyncio.Event()
            lane.task = loop.create_task(
                self._lane_loop(lane),
                name=f"lane:{lane.graph}:{lane.kind.name}")

    # ---- coalescing dispatcher -------------------------------------------
    async def _gather(self, lane: _Lane) -> list:
        """Dequeue up to `lane.width` compatible requests: block for the
        first, then wait at most `max_wait_ms` for lane-mates (a partial
        lane flushes at the deadline — a lone query is never starved)."""
        loop = asyncio.get_running_loop()
        while not lane.items:
            lane.event.clear()
            await lane.event.wait()
        batch = [lane.items.popleft()]
        if lane.width > 1:
            deadline = loop.time() + self.config.max_wait_ms / 1e3
            while len(batch) < lane.width:
                if lane.items:
                    batch.append(lane.items.popleft())
                    continue
                remaining = deadline - loop.time()
                if remaining <= 0:
                    break
                lane.event.clear()
                try:
                    await asyncio.wait_for(lane.event.wait(), remaining)
                except asyncio.TimeoutError:
                    break
        # a request whose deadline already fired (future cancelled) must
        # not occupy a lane
        return [r for r in batch if not r.future.done()]

    async def _lane_loop(self, lane: _Lane) -> None:
        while True:
            batch = await self._gather(lane)
            if not batch:
                continue
            async with self._sweep_sem:
                # pin: LRU eviction must never drop the views a running
                # sweep is resolving
                with self._pool.pin(lane.graph):
                    try:
                        results = await asyncio.to_thread(
                            lane.runner, [r.params for r in batch])
                    except asyncio.CancelledError:
                        raise
                    except Exception as exc:   # scatter the failure
                        err = ServiceError(
                            f"{lane.kind.name} sweep on {lane.graph!r} "
                            f"failed: {exc!r}")
                        for r in batch:
                            if not r.future.done():
                                r.future.set_exception(err)
                        continue
            self._stats["sweeps"] += 1
            self._stats["coalesced"] += len(batch)
            self._stats["max_batch"] = max(self._stats["max_batch"],
                                           len(batch))
            for r, res in zip(batch, results):
                if not r.future.done():
                    r.future.set_result(res)
                    self._stats["served"] += 1
            self._pool.enforce_budget()

    # ---- lifecycle + introspection ---------------------------------------
    def _fail_lane(self, lane: _Lane, exc: Exception) -> None:
        while lane.items:
            req = lane.items.popleft()
            if not req.future.done():
                req.future.set_exception(exc)

    async def close(self) -> None:
        """Stop dispatchers and fail queued requests with ServiceClosed."""
        self._closed = True
        tasks = [ln.task for ln in self._lanes.values() if ln.task is not None]
        for t in tasks:
            t.cancel()
        for t in tasks:
            try:
                await t
            except (asyncio.CancelledError, Exception):
                pass
        for lane in self._lanes.values():
            self._fail_lane(lane, ServiceClosed("service is closed"))

    async def __aenter__(self):
        return self

    async def __aexit__(self, *exc):
        await self.close()

    def graphs(self) -> list:
        return sorted(self._graphs)

    def handle(self, name: str) -> _GraphHandle:
        if name not in self._graphs:
            raise UnknownGraph(f"no graph named {name!r}")
        return self._graphs[name]

    def stats(self) -> dict:
        """Serving counters: queries served, sweeps run, mean/max coalesced
        lane occupancy, admission rejections, deadline misses, view-pool
        residency and evictions."""
        sweeps = self._stats["sweeps"]
        return {
            "served": self._stats["served"],
            "sweeps": sweeps,
            "mean_batch": (self._stats["coalesced"] / sweeps) if sweeps
            else 0.0,
            "max_batch": self._stats["max_batch"],
            "rejected": self._stats["rejected"],
            "timeouts": self._stats["timeouts"],
            "updates": self._stats["updates"],
            "pending": self._pending,
            "view_bytes": self._pool.view_nbytes(),
            "evictions": list(self._pool.evictions),
        }
