"""Minimal LM serving engine: batched greedy generation via the decode path.

This is the *language-model demo* half of `repro.serve` — the
graph-analytics serving entry point is `repro.serve.graph_service
.GraphService` (async query coalescing into the engine's SpMM lanes).

Production shape note: the dry-run's `serve_step` (launch/dryrun.py) is the
deployable unit — one decode step over a static KV cache at the assigned
(decode_32k / long_500k) shapes. This engine drives the same step for the
runnable examples: prefill fills the cache token-by-token (fine at demo
scale; at production scale prefill lowers the chunked-forward path), then
greedy decode continues the batch.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, prompt+new]
    steps: int


class ServeEngine:
    def __init__(self, model, params, *, max_len: int = 256, batch_size: int = 4):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self._decode = jax.jit(model.decode_step)

    def generate(self, prompts: np.ndarray, new_tokens: int) -> GenerationResult:
        """prompts: [B, S] int32 (right-aligned, no padding support needed
        for the demo). Greedy continuation of `new_tokens` tokens."""
        b, s = prompts.shape
        assert b <= self.batch_size and s + new_tokens <= self.max_len
        cache = self.model.init_cache(b, self.max_len)
        toks = jnp.asarray(prompts, jnp.int32)
        logits = None
        for i in range(s):   # prefill via the decode path
            logits, cache = self._decode(self.params, toks[:, i:i + 1], cache,
                                         jnp.int32(i))
        out = [toks]
        cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for j in range(new_tokens):
            out.append(cur)
            if j == new_tokens - 1:
                break
            logits, cache = self._decode(self.params, cur, cache,
                                         jnp.int32(s + j))
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return GenerationResult(
            tokens=np.asarray(jnp.concatenate(out, axis=1)),
            steps=s + new_tokens)
