"""repro.serve — the serving layer; two engines live here.

* `graph_service.GraphService` — **the graph-analytics serving entry
  point** (the repo's reason to exist): an async multi-tenant service
  that coalesces concurrent SSSP/BFS/BC queries across users and graphs
  into the engine's batched [N, B] SpMM lanes, with a `GraphPool` of
  per-graph contexts (memory-bounded LRU view eviction), `TuningStore`
  warm-reload on registration, and admission/deadline handling. See
  ``docs/serving.md``.
* `engine.ServeEngine` — the LM-demo serving engine for the transformer
  examples (`examples/serve_lm.py`): batched greedy generation against
  the decode path. Unrelated to graph queries.
"""
from .engine import GenerationResult, ServeEngine
from .graph_service import (BUILTIN_KINDS, GraphService, QueryKind,
                            ServiceClosed, ServiceConfig, ServiceError,
                            ServiceOverloaded, ServiceTimeout, UnknownGraph,
                            UnknownQueryKind)
from .pool import GraphPool

__all__ = [
    "BUILTIN_KINDS", "GenerationResult", "GraphPool", "GraphService",
    "QueryKind", "ServeEngine", "ServiceClosed", "ServiceConfig",
    "ServiceError", "ServiceOverloaded", "ServiceTimeout", "UnknownGraph",
    "UnknownQueryKind",
]
