"""CSR graph representation — the storage format the paper standardizes on (§3.1).

The paper chose CSR because it (a) works across all backends, (b) suits
vertex-centric algorithms, and (c) splits easily for distribution. All three
hold on TPU, with one adaptation: TPU kernels want *rectangular* tiles, so we
additionally materialize a block-ELL view (padded neighbor lists) for the
Pallas backend, and we keep an explicit per-edge source array (`edge_src`)
so edge-parallel ops are a gather, not a searchsorted.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..schedule import DEFAULT_SCHEDULE, Schedule

INF_I32 = np.int32(2**30)  # "infinity" that survives + weight without overflow

_ENGINE_DEPRECATION = (
    "mutating the module-level ENGINE is deprecated; construct an explicit "
    "repro.schedule.Schedule and pass it to compile_program(..., "
    "schedule=...) / prepare(g, schedule) instead. ENGINE is snapshotted "
    "into a Schedule at compile/prepare time, so mutating it afterwards "
    "never changes an already-compiled program."
)


@dataclasses.dataclass
class EngineConfig:
    """DEPRECATED mutable shim over the default `Schedule`.

    The engine knobs are a per-compile `repro.schedule.Schedule` now; this
    singleton only exists so pre-Schedule code keeps working. Reads are
    free; every mutation validates the would-be configuration (the same
    checks as `Schedule`), emits a `DeprecationWarning`, and only takes
    effect for *future* compiles/prepares via `snapshot()`. The shim will
    be removed once nothing in-tree mutates it (see README "Migration").
    """

    # field defaults come from DEFAULT_SCHEDULE — one source of truth, so
    # an unmutated shim always snapshots exactly the default Schedule
    num_buckets: int = DEFAULT_SCHEDULE.num_buckets
    min_width: int = DEFAULT_SCHEDULE.min_width
    growth: int = DEFAULT_SCHEDULE.growth
    push_threshold_frac: float = DEFAULT_SCHEDULE.push_threshold_frac
    batch_sources: int = DEFAULT_SCHEDULE.batch_sources

    def __post_init__(self):
        self.snapshot()           # validate the defaults once
        object.__setattr__(self, "_ready", True)

    def __setattr__(self, name, value):
        if getattr(self, "_ready", False) and not name.startswith("_"):
            knobs = {f.name: getattr(self, f.name)
                     for f in dataclasses.fields(self)}
            if name not in knobs:
                raise AttributeError(
                    f"ENGINE has no knob {name!r}; knobs: "
                    f"{', '.join(sorted(knobs))}")
            knobs[name] = value
            Schedule(**knobs)     # actionable ValueError before committing
            warnings.warn(_ENGINE_DEPRECATION, DeprecationWarning,
                          stacklevel=2)
        object.__setattr__(self, name, value)

    def snapshot(self, *, direction: str = "auto") -> Schedule:
        """Materialize the current knob values as a frozen `Schedule`."""
        return Schedule(num_buckets=self.num_buckets,
                        min_width=self.min_width, growth=self.growth,
                        push_threshold_frac=self.push_threshold_frac,
                        batch_sources=self.batch_sources,
                        direction=direction)


ENGINE = EngineConfig()


def resolve_schedule(schedule: Optional[Schedule] = None, *,
                     batch_sources: Optional[int] = None) -> Schedule:
    """The one place a default schedule is materialized.

    `schedule=None` snapshots the deprecated `ENGINE` shim (which, unless
    mutated, IS the default `Schedule`); the legacy per-compile
    `batch_sources=` override folds into the result."""
    sched = ENGINE.snapshot() if schedule is None else schedule
    if not isinstance(sched, Schedule):
        raise TypeError(
            f"schedule must be a repro.schedule.Schedule, got "
            f"{type(sched).__name__} — e.g. Schedule(batch_sources=16)")
    if batch_sources is not None:
        sched = dataclasses.replace(sched, batch_sources=int(batch_sources))
    return sched


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Static graph in CSR (out-edges) + CSC (in-edges) form.

    Matches the paper's Graph type: `indptr/indices` are
    `indexofNodes/edgeList`; `rev_*` is the transpose CSR the paper keeps
    for `nodesTo()` (needed by PR-pull and BC).
    """

    # --- out-CSR ---
    indptr: jax.Array      # int32[N+1]
    indices: jax.Array     # int32[E]   destination of each out-edge
    weights: jax.Array     # int32[E]   edge weights (SSSP); ones if unweighted
    edge_src: jax.Array    # int32[E]   source of each out-edge (expanded rows)
    # --- in-CSR (transpose) ---
    rev_indptr: jax.Array  # int32[N+1]
    rev_indices: jax.Array # int32[E]   source of each in-edge
    rev_weights: jax.Array # int32[E]
    rev_edge_dst: jax.Array# int32[E]   destination of each in-edge (expanded rows)
    # --- degrees ---
    out_degree: jax.Array  # int32[N]
    in_degree: jax.Array   # int32[N]
    # --- membership index ---
    # sorted (src*N + dst) key, built once so is_an_edge / wedge_count never
    # rebuild it per call; meaningful only while N*N fits int32 (the
    # consumers guard), but always present so the pytree shape is uniform.
    edge_key: jax.Array    # int32[E]
    # --- static metadata ---
    num_nodes: int = dataclasses.field(metadata=dict(static=True))
    num_edges: int = dataclasses.field(metadata=dict(static=True))
    max_out_degree: int = dataclasses.field(default=1, metadata=dict(static=True))
    max_in_degree: int = dataclasses.field(default=1, metadata=dict(static=True))
    # update generation: 0 for a freshly built graph, old.version + 1 for the
    # result of `update()`. Folded into the context fingerprint so a
    # post-update graph can never warm-reload a stale tuning record or
    # alias a pre-update memoized bind.
    version: int = dataclasses.field(default=0, metadata=dict(static=True))

    def num_nodes_(self) -> int:
        return self.num_nodes

    def update(self, adds=None, dels=None, weights=None):
        """Apply an edge write batch, returning a `repro.graph.dynamic.
        GraphDelta` whose `.graph` is the NEW graph version (this graph is
        immutable and untouched). `adds`/`dels` are (src, dst) pairs — a
        `[K, 2]` array or a pair of arrays; `weights` parallels `adds`
        (default 1; adding an existing edge replaces its weight). Deleting
        an absent edge is a no-op. Derived sliced-ELL views of this
        graph's `GraphContext` are delta-patched into the new graph's
        context rather than rebuilt."""
        from .dynamic import apply_update
        return apply_update(self, adds=adds, dels=dels, weights=weights)

    # Paper library functions -------------------------------------------------
    def count_outNbrs(self) -> jax.Array:
        return self.out_degree

    def minWt(self) -> jax.Array:
        return jnp.min(self.weights)

    def maxWt(self) -> jax.Array:
        return jnp.max(self.weights)


def _build_csr(n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray):
    order = np.lexsort((dst, src))
    src, dst, w = src[order], dst[order], w[order]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr.astype(np.int32), dst.astype(np.int32), w.astype(np.int32), src.astype(np.int32)


def from_edges(
    n: int,
    src: np.ndarray,
    dst: np.ndarray,
    weights: Optional[np.ndarray] = None,
    *,
    undirected: bool = False,
    dedup: bool = True,
    drop_self_loops: bool = False,
) -> CSRGraph:
    """Build a CSRGraph (host-side numpy; the result is a device pytree)."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    if weights is None:
        w = np.ones_like(src)
    else:
        w = np.asarray(weights, np.int64)
    if undirected:
        src, dst, w = np.concatenate([src, dst]), np.concatenate([dst, src]), np.concatenate([w, w])
    if drop_self_loops:
        keep = src != dst
        src, dst, w = src[keep], dst[keep], w[keep]
    if dedup and len(src):
        key = src * np.int64(n) + dst
        _, first = np.unique(key, return_index=True)
        src, dst, w = src[first], dst[first], w[first]
    e = len(src)
    indptr, indices, w_s, edge_src = _build_csr(n, src, dst, w)
    rev_indptr, rev_indices, rev_w, rev_edge_dst = _build_csr(n, dst, src, w)
    out_deg = np.diff(indptr).astype(np.int32)
    in_deg = np.diff(rev_indptr).astype(np.int32)
    # CSR order is lexsorted by (src, dst), so the key array is sorted by
    # construction; int64 intermediate avoids silent wrap while building.
    edge_key = (edge_src.astype(np.int64) * n + indices.astype(np.int64)).astype(np.int32)
    return CSRGraph(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(indices),
        weights=jnp.asarray(w_s),
        edge_src=jnp.asarray(edge_src),
        rev_indptr=jnp.asarray(rev_indptr),
        rev_indices=jnp.asarray(rev_indices),
        rev_weights=jnp.asarray(rev_w),
        rev_edge_dst=jnp.asarray(rev_edge_dst),
        out_degree=jnp.asarray(out_deg),
        in_degree=jnp.asarray(in_deg),
        edge_key=jnp.asarray(edge_key),
        num_nodes=int(n),
        num_edges=int(e),
        max_out_degree=int(out_deg.max(initial=1)),
        max_in_degree=int(in_deg.max(initial=1)),
    )


def to_dense(g: CSRGraph, dtype=jnp.float32) -> jax.Array:
    """Dense adjacency (small graphs only — tests + the TC matmul path)."""
    a = jnp.zeros((g.num_nodes, g.num_nodes), dtype)
    return a.at[g.edge_src, g.indices].set(1)


# --- block-ELL view (Pallas backend) ----------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllGraph:
    """Padded neighbor-list (ELL) view: rectangular, so a TPU kernel can tile it.

    cols[i, k] = k-th out-neighbor of i (or `n` for padding);
    wts [i, k] = its weight (or INF for padding).
    Rows are padded to `max_deg` rounded up to a multiple of 8 so the
    (row_block × deg_block) tiles line up with the 8×128 VPU lanes.
    """

    cols: jax.Array  # int32[N, D]
    wts: jax.Array   # int32[N, D]
    num_nodes: int = dataclasses.field(metadata=dict(static=True))
    max_deg: int = dataclasses.field(metadata=dict(static=True))


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def to_ell(g: CSRGraph, *, reverse: bool = False, pad_to: int = 8) -> EllGraph:
    indptr = np.asarray(g.rev_indptr if reverse else g.indptr)
    indices = np.asarray(g.rev_indices if reverse else g.indices)
    wts = np.asarray(g.rev_weights if reverse else g.weights)
    n = g.num_nodes
    deg = np.diff(indptr)
    d = max(int(deg.max()) if n else 0, 1)
    d = _round_up(d, pad_to)
    cols = np.full((n, d), n, np.int32)          # n == "no neighbor" sentinel
    w = np.full((n, d), int(INF_I32), np.int32)
    for i in range(n):
        s, e = indptr[i], indptr[i + 1]
        cols[i, : e - s] = indices[s:e]
        w[i, : e - s] = wts[s:e]
    return EllGraph(cols=jnp.asarray(cols), wts=jnp.asarray(w), num_nodes=n, max_deg=d)


# --- degree-bucketed sliced-ELL view (frontier-aware engine) ----------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SlicedEllGraph:
    """Degree-bucketed ELL: rows grouped by degree, each bucket padded only to
    its own width, hub rows (degree > the widest bucket) kept as flat COO.

    The single `[N, max_deg]` ELL view pads every row to the hub degree; on a
    power-law graph that is O(N·max_deg) work and memory for O(E) useful
    entries. Bucketing by degree (widths 8, 32, 128, 512 by default) brings
    padded work back to near O(E) while every bucket stays rectangular —
    still a TPU-tileable layout, just several small ones.

    Per bucket b: cols[b] is int32[Rb, Db] (sentinel `num_nodes` for padding,
    its x-slot holds 0), wts[b] is int32[Rb, Db] (INF padding), rows[b] is
    int32[Rb] (original row id; sentinel `num_nodes` for row padding —
    scatter-dropped). Hub edges: (hub_rows, hub_cols, hub_wts) int32[Eh].
    """

    cols: tuple      # tuple of int32[Rb, Db]
    wts: tuple       # tuple of int32[Rb, Db]
    rows: tuple      # tuple of int32[Rb]
    hub_rows: jax.Array  # int32[Eh]
    hub_cols: jax.Array  # int32[Eh]
    hub_wts: jax.Array   # int32[Eh]
    num_nodes: int = dataclasses.field(metadata=dict(static=True))
    widths: tuple = dataclasses.field(default=(), metadata=dict(static=True))

    def padded_cells(self) -> int:
        """Total padded (cols) slots — the memory/work proxy benchmarks track."""
        return sum(int(c.shape[0]) * int(c.shape[1]) for c in self.cols) \
            + int(self.hub_cols.shape[0])


def to_sliced_ell(
    g: CSRGraph,
    *,
    reverse: bool = False,
    schedule: Optional[Schedule] = None,
    num_buckets: Optional[int] = None,
    min_width: Optional[int] = None,
    growth: Optional[int] = None,
    row_pad: int = 8,
) -> SlicedEllGraph:
    """Build the degree-bucketed view (host side, once per graph).

    The bucket layout comes from `schedule` (default: the `ENGINE` shim's
    snapshot, i.e. the default `Schedule`); the explicit knob kwargs remain
    as per-call overrides. `reverse=True` buckets by in-degree with
    in-neighbor columns — the pull orientation both backends relax/gather
    over. Degree-0 rows are dropped entirely (they contribute the semiring
    identity).
    """
    cfg = resolve_schedule(schedule)
    num_buckets = cfg.num_buckets if num_buckets is None else num_buckets
    min_width = cfg.min_width if min_width is None else min_width
    growth = cfg.growth if growth is None else growth
    indptr = np.asarray(g.rev_indptr if reverse else g.indptr)
    indices = np.asarray(g.rev_indices if reverse else g.indices)
    wts = np.asarray(g.rev_weights if reverse else g.weights)
    n = g.num_nodes
    deg = np.diff(indptr)
    widths = [min_width * growth**i for i in range(max(num_buckets, 1))]
    hub_width = widths[-1]

    b_cols, b_wts, b_rows = [], [], []
    prev_w = 0
    for w_b in widths:
        sel = np.nonzero((deg > prev_w) & (deg <= w_b))[0]
        prev_w = w_b
        if len(sel) == 0:
            continue
        rb = _round_up(len(sel), row_pad)
        cols = np.full((rb, w_b), n, np.int32)
        vals = np.full((rb, w_b), int(INF_I32), np.int32)
        rows = np.full((rb,), n, np.int32)
        rows[: len(sel)] = sel
        for k, r in enumerate(sel):
            s, e = indptr[r], indptr[r + 1]
            cols[k, : e - s] = indices[s:e]
            vals[k, : e - s] = wts[s:e]
        b_cols.append(jnp.asarray(cols))
        b_wts.append(jnp.asarray(vals))
        b_rows.append(jnp.asarray(rows))

    hub_sel = np.nonzero(deg > hub_width)[0]
    hr, hc, hw = [], [], []
    for r in hub_sel:
        s, e = indptr[r], indptr[r + 1]
        hr.append(np.full(e - s, r, np.int32))
        hc.append(indices[s:e].astype(np.int32))
        hw.append(wts[s:e].astype(np.int32))
    cat = (lambda xs: np.concatenate(xs) if xs else np.zeros(0, np.int32))
    return SlicedEllGraph(
        cols=tuple(b_cols), wts=tuple(b_wts), rows=tuple(b_rows),
        hub_rows=jnp.asarray(cat(hr)), hub_cols=jnp.asarray(cat(hc)),
        hub_wts=jnp.asarray(cat(hw)),
        num_nodes=n, widths=tuple(int(c.shape[1]) for c in b_cols))


def pad_nodes(g: CSRGraph, multiple: int) -> CSRGraph:
    """Pad to a node-count multiple (the paper pads the last MPI shard; we pad
    so every device shard has identical extent)."""
    n = g.num_nodes
    n_pad = _round_up(max(n, 1), multiple)
    if n_pad == n:
        return g
    extra = n_pad - n
    def pad_ptr(p):
        p = np.asarray(p)
        return jnp.asarray(np.concatenate([p, np.full(extra, p[-1], p.dtype)]))
    return dataclasses.replace(
        g,
        indptr=pad_ptr(g.indptr),
        rev_indptr=pad_ptr(g.rev_indptr),
        out_degree=jnp.concatenate([g.out_degree, jnp.zeros(extra, jnp.int32)]),
        in_degree=jnp.concatenate([g.in_degree, jnp.zeros(extra, jnp.int32)]),
        # the key encodes num_nodes, so it must be rebuilt for the new N
        # (still sorted: CSR order is (src, dst)-lexicographic)
        edge_key=g.edge_src * jnp.int32(n_pad) + g.indices,
        num_nodes=n_pad,
    )
