"""CSR graph representation — the storage format the paper standardizes on (§3.1).

The paper chose CSR because it (a) works across all backends, (b) suits
vertex-centric algorithms, and (c) splits easily for distribution. All three
hold on TPU, with one adaptation: TPU kernels want *rectangular* tiles, so we
additionally materialize a block-ELL view (padded neighbor lists) for the
Pallas backend, and we keep an explicit per-edge source array (`edge_src`)
so edge-parallel ops are a gather, not a searchsorted.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

INF_I32 = np.int32(2**30)  # "infinity" that survives + weight without overflow


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Static graph in CSR (out-edges) + CSC (in-edges) form.

    Matches the paper's Graph type: `indptr/indices` are
    `indexofNodes/edgeList`; `rev_*` is the transpose CSR the paper keeps
    for `nodesTo()` (needed by PR-pull and BC).
    """

    # --- out-CSR ---
    indptr: jax.Array      # int32[N+1]
    indices: jax.Array     # int32[E]   destination of each out-edge
    weights: jax.Array     # int32[E]   edge weights (SSSP); ones if unweighted
    edge_src: jax.Array    # int32[E]   source of each out-edge (expanded rows)
    # --- in-CSR (transpose) ---
    rev_indptr: jax.Array  # int32[N+1]
    rev_indices: jax.Array # int32[E]   source of each in-edge
    rev_weights: jax.Array # int32[E]
    rev_edge_dst: jax.Array# int32[E]   destination of each in-edge (expanded rows)
    # --- degrees ---
    out_degree: jax.Array  # int32[N]
    in_degree: jax.Array   # int32[N]
    # --- static metadata ---
    num_nodes: int = dataclasses.field(metadata=dict(static=True))
    num_edges: int = dataclasses.field(metadata=dict(static=True))
    max_out_degree: int = dataclasses.field(default=1, metadata=dict(static=True))
    max_in_degree: int = dataclasses.field(default=1, metadata=dict(static=True))

    def num_nodes_(self) -> int:
        return self.num_nodes

    # Paper library functions -------------------------------------------------
    def count_outNbrs(self) -> jax.Array:
        return self.out_degree

    def minWt(self) -> jax.Array:
        return jnp.min(self.weights)

    def maxWt(self) -> jax.Array:
        return jnp.max(self.weights)


def _build_csr(n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray):
    order = np.lexsort((dst, src))
    src, dst, w = src[order], dst[order], w[order]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr.astype(np.int32), dst.astype(np.int32), w.astype(np.int32), src.astype(np.int32)


def from_edges(
    n: int,
    src: np.ndarray,
    dst: np.ndarray,
    weights: Optional[np.ndarray] = None,
    *,
    undirected: bool = False,
    dedup: bool = True,
    drop_self_loops: bool = False,
) -> CSRGraph:
    """Build a CSRGraph (host-side numpy; the result is a device pytree)."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    if weights is None:
        w = np.ones_like(src)
    else:
        w = np.asarray(weights, np.int64)
    if undirected:
        src, dst, w = np.concatenate([src, dst]), np.concatenate([dst, src]), np.concatenate([w, w])
    if drop_self_loops:
        keep = src != dst
        src, dst, w = src[keep], dst[keep], w[keep]
    if dedup and len(src):
        key = src * np.int64(n) + dst
        _, first = np.unique(key, return_index=True)
        src, dst, w = src[first], dst[first], w[first]
    e = len(src)
    indptr, indices, w_s, edge_src = _build_csr(n, src, dst, w)
    rev_indptr, rev_indices, rev_w, rev_edge_dst = _build_csr(n, dst, src, w)
    out_deg = np.diff(indptr).astype(np.int32)
    in_deg = np.diff(rev_indptr).astype(np.int32)
    return CSRGraph(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(indices),
        weights=jnp.asarray(w_s),
        edge_src=jnp.asarray(edge_src),
        rev_indptr=jnp.asarray(rev_indptr),
        rev_indices=jnp.asarray(rev_indices),
        rev_weights=jnp.asarray(rev_w),
        rev_edge_dst=jnp.asarray(rev_edge_dst),
        out_degree=jnp.asarray(out_deg),
        in_degree=jnp.asarray(in_deg),
        num_nodes=int(n),
        num_edges=int(e),
        max_out_degree=int(out_deg.max(initial=1)),
        max_in_degree=int(in_deg.max(initial=1)),
    )


def to_dense(g: CSRGraph, dtype=jnp.float32) -> jax.Array:
    """Dense adjacency (small graphs only — tests + the TC matmul path)."""
    a = jnp.zeros((g.num_nodes, g.num_nodes), dtype)
    return a.at[g.edge_src, g.indices].set(1)


# --- block-ELL view (Pallas backend) ----------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllGraph:
    """Padded neighbor-list (ELL) view: rectangular, so a TPU kernel can tile it.

    cols[i, k] = k-th out-neighbor of i (or `n` for padding);
    wts [i, k] = its weight (or INF for padding).
    Rows are padded to `max_deg` rounded up to a multiple of 8 so the
    (row_block × deg_block) tiles line up with the 8×128 VPU lanes.
    """

    cols: jax.Array  # int32[N, D]
    wts: jax.Array   # int32[N, D]
    num_nodes: int = dataclasses.field(metadata=dict(static=True))
    max_deg: int = dataclasses.field(metadata=dict(static=True))


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def to_ell(g: CSRGraph, *, reverse: bool = False, pad_to: int = 8) -> EllGraph:
    indptr = np.asarray(g.rev_indptr if reverse else g.indptr)
    indices = np.asarray(g.rev_indices if reverse else g.indices)
    wts = np.asarray(g.rev_weights if reverse else g.weights)
    n = g.num_nodes
    deg = np.diff(indptr)
    d = max(int(deg.max()) if n else 0, 1)
    d = _round_up(d, pad_to)
    cols = np.full((n, d), n, np.int32)          # n == "no neighbor" sentinel
    w = np.full((n, d), int(INF_I32), np.int32)
    for i in range(n):
        s, e = indptr[i], indptr[i + 1]
        cols[i, : e - s] = indices[s:e]
        w[i, : e - s] = wts[s:e]
    return EllGraph(cols=jnp.asarray(cols), wts=jnp.asarray(w), num_nodes=n, max_deg=d)


def pad_nodes(g: CSRGraph, multiple: int) -> CSRGraph:
    """Pad to a node-count multiple (the paper pads the last MPI shard; we pad
    so every device shard has identical extent)."""
    n = g.num_nodes
    n_pad = _round_up(max(n, 1), multiple)
    if n_pad == n:
        return g
    extra = n_pad - n
    def pad_ptr(p):
        p = np.asarray(p)
        return jnp.asarray(np.concatenate([p, np.full(extra, p[-1], p.dtype)]))
    return dataclasses.replace(
        g,
        indptr=pad_ptr(g.indptr),
        rev_indptr=pad_ptr(g.rev_indptr),
        out_degree=jnp.concatenate([g.out_degree, jnp.zeros(extra, jnp.int32)]),
        in_degree=jnp.concatenate([g.in_degree, jnp.zeros(extra, jnp.int32)]),
        num_nodes=n_pad,
    )
