"""Dynamic graphs: edge write batches over immutable CSR snapshots.

StarPlat's follow-up work extends the DSL from static snapshots to edge
insert/delete batches with incremental recompute. `CSRGraph` stays an
immutable pytree — `g.update(adds, dels)` builds the NEXT version of the
graph host-side and returns a `GraphDelta` tying the two versions together
with the *effective* edge changes (what actually appeared / disappeared,
with weight replacements showing up as a remove + an add of the same
endpoint pair).

The delta is what makes incrementality possible downstream:

* `repro.core.context.adopt_patched_views` uses the touched endpoints to
  delta-patch the old graph's sliced-ELL views into the new graph's
  `GraphContext` (in-place bucket row rewrites where the degree still fits
  the bucket; the COO hub tail absorbs degree-class migrations) instead of
  rebuilding them from scratch — `apply_update` does this eagerly;
* `GraphDelta.plan()` derives the refresh seeding `BoundProgram.refresh`
  warm-starts iterative programs with: inserted edges seed their source
  endpoints, deletions reset the forward-reachable *cone* of the deleted
  heads (every vertex whose converged value could have depended on a
  removed edge — the last removed edge on any stale dependence path makes
  its head an ancestor of the vertex) and seed the cone plus its in-edge
  boundary, whose values are still exact.

The number of nodes never changes across an update; only edges do.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .csr import CSRGraph, INF_I32, SlicedEllGraph, from_edges


def _normalize_pairs(pairs, n: int, what: str):
    """(src, dst) int64 arrays from a [K, 2] array / pair of arrays / list
    of (u, v) tuples; validates the vertex range."""
    if pairs is None:
        z = np.zeros(0, np.int64)
        return z, z
    if isinstance(pairs, tuple) and len(pairs) == 2 and \
            not np.isscalar(pairs[0]):
        src = np.asarray(pairs[0], np.int64).reshape(-1)
        dst = np.asarray(pairs[1], np.int64).reshape(-1)
        if src.shape != dst.shape:
            raise ValueError(
                f"{what}: src/dst arrays differ in length "
                f"({src.shape[0]} vs {dst.shape[0]})")
    else:
        arr = np.asarray(pairs, np.int64)
        if arr.size == 0:
            z = np.zeros(0, np.int64)
            return z, z
        arr = arr.reshape(-1, 2)
        src, dst = arr[:, 0], arr[:, 1]
    if src.size and (src.min() < 0 or src.max() >= n or
                     dst.min() < 0 or dst.max() >= n):
        raise ValueError(
            f"{what}: endpoints must be vertex ids in [0, {n}), got range "
            f"[{min(src.min(), dst.min())}, {max(src.max(), dst.max())}]")
    return src, dst


def _missing_from(keys_a, w_a, keys_b, w_b):
    """Mask over a's edges that are NOT present in b with the same weight
    (both key arrays sorted — CSR order is (src, dst)-lexicographic)."""
    out = np.ones(keys_a.shape[0], bool)
    if keys_b.shape[0] == 0:
        return out
    idx = np.searchsorted(keys_b, keys_a)
    valid = idx < keys_b.shape[0]
    iv = idx[valid]
    out[valid] = ~((keys_b[iv] == keys_a[valid]) & (w_b[iv] == w_a[valid]))
    return out


@dataclasses.dataclass(frozen=True)
class RefreshPlan:
    """Host-side seeding of one incremental refresh (see module docstring).

    ``reset`` marks the deletion cone: vertices whose previous converged
    value may be stale (too small, for a monotone Min fixed point) and must
    restart from the cold init. ``seed`` ⊇ ``reset`` adds the cone's
    in-edge boundary and the source endpoints of inserted edges — the
    vertices the first warm sweep relaxes from. ``affected_frac`` is
    ``|seed| / N``, the quantity `Schedule.refresh_threshold_frac` gates."""

    reset: np.ndarray        # bool[N]
    seed: np.ndarray         # bool[N]
    affected_frac: float
    cone_size: int


@dataclasses.dataclass(eq=False)
class GraphDelta:
    """One applied update batch: ``old`` → ``graph`` (= ``old.version + 1``).

    The add/del arrays hold the EFFECTIVE changes (CSR-order sorted):
    adding an already-present edge with its existing weight is dropped;
    replacing a weight appears as a removal of the old (src, dst, w) plus
    an addition of the new one; deleting an absent edge is a no-op."""

    old: CSRGraph
    graph: CSRGraph
    add_src: np.ndarray
    add_dst: np.ndarray
    add_wts: np.ndarray
    del_src: np.ndarray
    del_dst: np.ndarray
    del_wts: np.ndarray
    _plan: Optional[RefreshPlan] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def num_added(self) -> int:
        return int(self.add_src.shape[0])

    @property
    def num_removed(self) -> int:
        return int(self.del_src.shape[0])

    def touched_rows(self, *, reverse: bool) -> np.ndarray:
        """Rows whose adjacency changed in the given orientation: dst
        endpoints for the reverse (in-edge) view, src for the forward."""
        if reverse:
            return np.unique(np.concatenate([self.add_dst, self.del_dst]))
        return np.unique(np.concatenate([self.add_src, self.del_src]))

    def plan(self) -> RefreshPlan:
        """The refresh seeding for this delta (memoized)."""
        if self._plan is None:
            object.__setattr__(self, "_plan", _refresh_plan(self))
        return self._plan


def apply_update(g: CSRGraph, adds=None, dels=None, weights=None) -> GraphDelta:
    """`CSRGraph.update` implementation (host-side numpy).

    Deletions apply first, then additions (so delete-then-reinsert within
    one batch keeps the edge, and an add of an existing pair replaces its
    weight). The old graph's derived sliced-ELL views are eagerly
    delta-patched into the new graph's `GraphContext`."""
    n = g.num_nodes
    src = np.asarray(g.edge_src, np.int64)
    dst = np.asarray(g.indices, np.int64)
    w = np.asarray(g.weights, np.int64)
    key = src * n + dst          # sorted: CSR order is (src, dst)-lex

    a_src, a_dst = _normalize_pairs(adds, n, "adds")
    d_src, d_dst = _normalize_pairs(dels, n, "dels")
    if weights is None:
        a_w = np.ones_like(a_src)
    else:
        a_w = np.asarray(weights, np.int64).reshape(-1)
        if a_w.shape != a_src.shape:
            raise ValueError(
                f"weights must parallel adds ({a_src.shape[0]} edges), got "
                f"{a_w.shape[0]} values")
    if a_src.size:   # within-batch dedup: the LAST write to a pair wins
        a_key = a_src * n + a_dst
        _, first_rev = np.unique(a_key[::-1], return_index=True)
        sel = a_src.shape[0] - 1 - first_rev
        a_src, a_dst, a_w = a_src[sel], a_dst[sel], a_w[sel]

    drop = np.concatenate([d_src * n + d_dst, a_src * n + a_dst])
    keep = ~np.isin(key, drop) if drop.size else np.ones(key.shape[0], bool)
    new_src = np.concatenate([src[keep], a_src])
    new_dst = np.concatenate([dst[keep], a_dst])
    new_w = np.concatenate([w[keep], a_w])
    new_g = from_edges(n, new_src, new_dst, new_w)
    new_g = dataclasses.replace(new_g, version=g.version + 1)

    # effective changes: compare the (key, weight) sets of the two versions
    nk = np.asarray(new_g.edge_src, np.int64) * n \
        + np.asarray(new_g.indices, np.int64)
    nw = np.asarray(new_g.weights, np.int64)
    removed = _missing_from(key, w, nk, nw)
    added = _missing_from(nk, nw, key, w)
    delta = GraphDelta(
        old=g, graph=new_g,
        add_src=(nk[added] // n).astype(np.int32),
        add_dst=(nk[added] % n).astype(np.int32),
        add_wts=nw[added].astype(np.int32),
        del_src=(key[removed] // n).astype(np.int32),
        del_dst=(key[removed] % n).astype(np.int32),
        del_wts=w[removed].astype(np.int32),
    )
    from ..core.context import adopt_patched_views
    adopt_patched_views(delta)
    return delta


def _refresh_plan(delta: GraphDelta) -> RefreshPlan:
    g = delta.graph
    n = g.num_nodes
    indices = np.asarray(g.indices)
    edge_src = np.asarray(g.edge_src)
    reset = np.zeros(n, bool)
    roots = np.unique(delta.del_dst)
    if roots.size:
        # forward closure from the deleted heads over the NEW graph,
        # edge-parallel level sweeps (same shape as the stats BFS probe)
        reset[roots] = True
        front = reset.copy()
        while edge_src.size:
            hit = np.zeros(n, bool)
            hit[indices[front[edge_src]]] = True
            newly = hit & ~reset
            if not newly.any():
                break
            reset |= newly
            front = newly
    seed = reset.copy()
    if delta.add_src.size:
        seed[np.unique(delta.add_src)] = True
    if roots.size and edge_src.size:
        # the cone's in-edge boundary: still-exact values that re-supply it
        boundary = np.unique(edge_src[reset[indices]])
        seed[boundary] = True
    frac = float(seed.sum() / n) if n else 0.0
    return RefreshPlan(reset=reset, seed=seed, affected_frac=frac,
                       cone_size=int(reset.sum()))


def patch_sliced_ell(view: SlicedEllGraph, delta: GraphDelta, *,
                     reverse: bool) -> SlicedEllGraph:
    """Delta-patch one sliced-ELL view of ``delta.old`` into a view of
    ``delta.graph`` without a full rebuild.

    A touched row whose new degree still fits its bucket's width is
    rewritten in place (its slot may carry more padding than the bucket's
    degree class implies — the kernels never care, padding is semiring
    identity). Any degree-class migration — bucket overflow, an emptied
    row, an ex-hub row shrinking, a formerly degree-0 row appearing —
    evacuates the old slot (sentinel row) and appends the row's full new
    adjacency to the COO hub tail, which handles arbitrary degrees.
    Bucket shapes and ``widths`` are preserved, so the patched view stays
    layout-compatible with the schedule that built it."""
    g = delta.graph
    n = g.num_nodes
    indptr = np.asarray(g.rev_indptr if reverse else g.indptr)
    indices = np.asarray(g.rev_indices if reverse else g.indices)
    wts = np.asarray(g.rev_weights if reverse else g.weights)
    touched = delta.touched_rows(reverse=reverse)
    if touched.size == 0:
        return view      # empty delta: the old view is already exact

    rows_np = [np.asarray(r) for r in view.rows]
    loc = {}             # row id -> (bucket, slot)
    for b, rr in enumerate(rows_np):
        for slot, r in enumerate(rr.tolist()):
            if r != n:
                loc[r] = (b, slot)
    hub_rows = np.asarray(view.hub_rows)
    hub_cols = np.asarray(view.hub_cols)
    hub_wts = np.asarray(view.hub_wts)
    hub_members = set(np.unique(hub_rows).tolist())

    copied = {}          # bucket -> mutable (cols, wts, rows) numpy copies

    def bucket_arrays(b):
        if b not in copied:
            copied[b] = (np.asarray(view.cols[b]).copy(),
                         np.asarray(view.wts[b]).copy(),
                         rows_np[b].copy())
        return copied[b]

    hub_evict, hub_add = [], []
    for r in touched.tolist():
        s, e = int(indptr[r]), int(indptr[r + 1])
        d = e - s
        spot = loc.get(r)
        if spot is not None:
            b, slot = spot
            cols_b, wts_b, rows_b = bucket_arrays(b)
            if 0 < d <= cols_b.shape[1]:
                cols_b[slot, :] = n
                wts_b[slot, :] = int(INF_I32)
                cols_b[slot, :d] = indices[s:e]
                wts_b[slot, :d] = wts[s:e]
                continue
            # degree left the bucket: the slot becomes a padding row and
            # the hub tail absorbs the migration
            cols_b[slot, :] = n
            wts_b[slot, :] = int(INF_I32)
            rows_b[slot] = n
        elif r in hub_members:
            hub_evict.append(r)
        if d > 0:
            hub_add.append((r, indices[s:e], wts[s:e]))

    patched_cols = list(view.cols)
    patched_wts = list(view.wts)
    patched_rows = list(view.rows)
    for b, (cb, wb, rb) in copied.items():
        patched_cols[b] = jnp.asarray(cb)
        patched_wts[b] = jnp.asarray(wb)
        patched_rows[b] = jnp.asarray(rb)
    if hub_evict or hub_add:
        if hub_evict:
            keepers = ~np.isin(hub_rows, np.asarray(hub_evict, np.int32))
        else:
            keepers = np.ones(hub_rows.shape[0], bool)
        hr, hc, hw = [hub_rows[keepers]], [hub_cols[keepers]], [hub_wts[keepers]]
        for r, cs, ws in hub_add:
            hr.append(np.full(cs.shape[0], r, np.int32))
            hc.append(cs.astype(np.int32))
            hw.append(ws.astype(np.int32))
        hub_rows = np.concatenate(hr)
        hub_cols = np.concatenate(hc)
        hub_wts = np.concatenate(hw)
    return SlicedEllGraph(
        cols=tuple(patched_cols), wts=tuple(patched_wts),
        rows=tuple(patched_rows),
        hub_rows=jnp.asarray(hub_rows), hub_cols=jnp.asarray(hub_cols),
        hub_wts=jnp.asarray(hub_wts),
        num_nodes=n, widths=view.widths)


def sliced_ell_edges(view: SlicedEllGraph):
    """The (row, col, weight) multiset a sliced-ELL view encodes (host-side;
    tests compare a patched view against a rebuilt one through this)."""
    n = view.num_nodes
    out = []
    for cols, wts, rows in zip(view.cols, view.wts, view.rows):
        cols, wts, rows = np.asarray(cols), np.asarray(wts), np.asarray(rows)
        for slot in range(rows.shape[0]):
            r = int(rows[slot])
            if r == n:
                continue
            real = cols[slot] < n
            out.extend(zip([r] * int(real.sum()),
                           cols[slot][real].tolist(),
                           wts[slot][real].tolist()))
    out.extend(zip(np.asarray(view.hub_rows).tolist(),
                   np.asarray(view.hub_cols).tolist(),
                   np.asarray(view.hub_wts).tolist()))
    return sorted(out)
