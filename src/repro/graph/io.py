"""Edge-list I/O — the paper's graph loader (§3.1) reads edge lists into CSR."""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, from_edges


def load_edgelist(path: str, *, undirected: bool = False,
                  weighted: bool | None = None) -> CSRGraph:
    """Load `src dst [weight]` lines (comments with #/%%) into a CSRGraph."""
    src, dst, wts = [], [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line[0] in "#%":
                continue
            parts = line.split()
            src.append(int(parts[0]))
            dst.append(int(parts[1]))
            if len(parts) > 2:
                wts.append(int(float(parts[2])))
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    if weighted is None:
        weighted = len(wts) == len(src) and len(wts) > 0
    w = np.asarray(wts, np.int64) if weighted else None
    n = int(max(src.max(initial=-1), dst.max(initial=-1)) + 1)
    return from_edges(n, src, dst, w, undirected=undirected)


def save_edgelist(g: CSRGraph, path: str) -> None:
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    weights = np.asarray(g.weights)
    with open(path, "w") as f:
        f.write(f"# nodes={g.num_nodes} edges={g.num_edges}\n")
        for v in range(g.num_nodes):
            for e in range(indptr[v], indptr[v + 1]):
                f.write(f"{v} {indices[e]} {weights[e]}\n")
