"""Graph generators patterned on the paper's Table 2 suite.

The paper evaluates on social networks (small-world, skewed), road networks
(large diameter, degree ~2), an RMAT graph (a=0.57,b=0.19,c=0.19,d=0.05 —
SNAP's parameters, quoted in §5), and a uniform-random graph (Green-Marl's
generator). We generate scaled-down instances of each family; edge weights
are uniform in [1, 100] exactly as the paper assigns for SSSP.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, from_edges

WEIGHT_LO, WEIGHT_HI = 1, 100


def _weights(rng: np.random.Generator, e: int) -> np.ndarray:
    return rng.integers(WEIGHT_LO, WEIGHT_HI + 1, size=e)


def uniform_random(n: int, avg_degree: int = 8, seed: int = 0) -> CSRGraph:
    """Uniform-random directed graph (the paper's UR, via Green-Marl's generator)."""
    rng = np.random.default_rng(seed)
    e = n * avg_degree
    src = rng.integers(0, n, size=e)
    dst = rng.integers(0, n, size=e)
    return from_edges(n, src, dst, _weights(rng, e), drop_self_loops=True)


def rmat(scale: int, edge_factor: int = 8, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> CSRGraph:
    """RMAT with the paper's SNAP parameters (d = 1-a-b-c = 0.05): skewed degrees."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    e = n * edge_factor
    src = np.zeros(e, np.int64)
    dst = np.zeros(e, np.int64)
    for bit in range(scale):
        r = rng.random(e)
        # quadrant probabilities a, b, c, d
        go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
        go_down = r >= a + b
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    return from_edges(n, src, dst, _weights(rng, e), drop_self_loops=True)


def road(side: int, seed: int = 0) -> CSRGraph:
    """Grid 'road network': degree ≤ 4, large diameter (the paper's US/GR analogue)."""
    rng = np.random.default_rng(seed)
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    idx = (ii * side + jj).ravel()
    right = idx[(jj < side - 1).ravel()]
    down = idx[(ii < side - 1).ravel()]
    src = np.concatenate([right, down])
    dst = np.concatenate([right + 1, down + side])
    # drop a few edges so it is not perfectly regular
    keep = rng.random(len(src)) > 0.03
    src, dst = src[keep], dst[keep]
    return from_edges(n, src, dst, _weights(rng, len(src)), undirected=True)


def small_world(n: int, k: int = 8, p: float = 0.1, seed: int = 0) -> CSRGraph:
    """Watts-Strogatz-style social graph (the paper's OK/LJ/PK analogue)."""
    rng = np.random.default_rng(seed)
    base = np.arange(n)
    srcs, dsts = [], []
    for off in range(1, k // 2 + 1):
        srcs.append(base)
        dsts.append((base + off) % n)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    rewire = rng.random(len(src)) < p
    dst = np.where(rewire, rng.integers(0, n, size=len(dst)), dst)
    return from_edges(n, src, dst, _weights(rng, len(src)), undirected=True,
                      drop_self_loops=True)


def powerlaw_social(n: int, avg_degree: int = 12, seed: int = 0) -> CSRGraph:
    """Skewed-degree 'twitter-like' graph via preferential attachment sampling."""
    rng = np.random.default_rng(seed)
    e = n * avg_degree
    # Zipf-ish destination popularity
    ranks = np.arange(1, n + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    dst = rng.choice(n, size=e, p=probs)
    src = rng.integers(0, n, size=e)
    return from_edges(n, src, dst, _weights(rng, e), drop_self_loops=True)


def preferential_attachment(n: int, m: int = 8, seed: int = 0) -> CSRGraph:
    """Barabási-Albert preferential attachment: every new vertex attaches m
    edges to existing vertices chosen ∝ degree. True power-law degrees with
    a heavy hub tail (max degree ~ m·√n) — the adversarial input for the
    degree-bucketed engine, without the memory blow-up of a Zipf hub."""
    rng = np.random.default_rng(seed)
    src_l, dst_l = [], []
    repeated = [0]               # endpoint multiset: sampling it is ∝ degree
    for v in range(1, n):
        k = min(m, v)            # early vertices: fewer distinct targets exist
        chosen = set()
        while len(chosen) < k:
            chosen.add(repeated[rng.integers(len(repeated))])
        for u in chosen:
            src_l.append(v)
            dst_l.append(u)
            repeated.append(v)
            repeated.append(u)
    src = np.asarray(src_l, np.int64)
    dst = np.asarray(dst_l, np.int64)
    return from_edges(n, src, dst, _weights(rng, len(src)), undirected=True,
                      drop_self_loops=True)


SUITE = {
    # acronym -> (factory, kwargs)   — scaled-down Table 2
    "TW": (powerlaw_social, dict(n=4096, avg_degree=12, seed=1)),
    "SW": (uniform_random, dict(n=8192, avg_degree=4, seed=2)),
    "OK": (small_world, dict(n=2048, k=64, p=0.05, seed=3)),
    "WK": (powerlaw_social, dict(n=2048, avg_degree=48, seed=4)),
    "LJ": (small_world, dict(n=4096, k=24, p=0.1, seed=5)),
    "PK": (small_world, dict(n=2048, k=32, p=0.15, seed=6)),
    "US": (road, dict(side=96, seed=7)),
    "GR": (road, dict(side=64, seed=8)),
    "RM": (rmat, dict(scale=12, edge_factor=5, seed=9)),
    "UR": (uniform_random, dict(n=4096, avg_degree=8, seed=10)),
}


def load_suite(names=None) -> dict:
    names = names or list(SUITE)
    out = {}
    for name in names:
        fn, kw = SUITE[name]
        out[name] = fn(**kw)
    return out
