from .csr import CSRGraph, EllGraph, from_edges, to_dense, to_ell, pad_nodes, INF_I32
from .generators import uniform_random, rmat, road, small_world, powerlaw_social, load_suite, SUITE
from . import algorithms_ref, io, partition

__all__ = [
    "CSRGraph", "EllGraph", "from_edges", "to_dense", "to_ell", "pad_nodes",
    "INF_I32", "uniform_random", "rmat", "road", "small_world",
    "powerlaw_social", "load_suite", "SUITE", "algorithms_ref", "io",
    "partition",
]
