from ..schedule import Schedule
from .csr import (CSRGraph, EllGraph, ENGINE, EngineConfig, SlicedEllGraph,
                  from_edges, resolve_schedule, to_dense, to_ell,
                  to_sliced_ell, pad_nodes, INF_I32)
from .dynamic import (GraphDelta, RefreshPlan, apply_update, patch_sliced_ell,
                      sliced_ell_edges)
from .generators import (uniform_random, rmat, road, small_world,
                         powerlaw_social, preferential_attachment, load_suite,
                         SUITE)
from . import algorithms_ref, io, partition

__all__ = [
    "CSRGraph", "EllGraph", "ENGINE", "EngineConfig", "Schedule",
    "SlicedEllGraph", "from_edges", "resolve_schedule", "to_dense", "to_ell",
    "to_sliced_ell", "pad_nodes", "INF_I32", "GraphDelta", "RefreshPlan",
    "apply_update", "patch_sliced_ell", "sliced_ell_edges", "uniform_random",
    "rmat", "road", "small_world", "powerlaw_social",
    "preferential_attachment", "load_suite", "SUITE", "algorithms_ref", "io",
    "partition",
]
