"""Reference (oracle) implementations of the paper's four algorithms.

Pure numpy / networkx. These are the ground truth every backend's generated
code is tested against. Semantics follow the paper's DSL programs exactly:
  - SSSP: Bellman-Ford variant, integer weights, unreachable = INF.
  - PR:   damped PageRank with double buffering, convergence on L1 diff,
          dangling nodes contribute nothing (paper's formulation divides by
          out-degree of in-neighbors only).
  - TC:   directed triangle count per the paper's Fig. 20 (u < v < w wedge
          with closing edge (u, w)).
  - BC:   Brandes' algorithm on the *unweighted* BFS DAG (paper's Fig. 18),
          accumulated over a source set.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, INF_I32


def _np_csr(g: CSRGraph):
    return (np.asarray(g.indptr), np.asarray(g.indices), np.asarray(g.weights),
            np.asarray(g.rev_indptr), np.asarray(g.rev_indices), np.asarray(g.rev_weights))


def sssp_ref(g: CSRGraph, src: int) -> np.ndarray:
    indptr, indices, weights, *_ = _np_csr(g)
    n = g.num_nodes
    dist = np.full(n, int(INF_I32), np.int64)
    dist[src] = 0
    for _ in range(n):  # Bellman-Ford
        changed = False
        for v in range(n):
            if dist[v] >= INF_I32:
                continue
            s, e = indptr[v], indptr[v + 1]
            nd = dist[v] + weights[s:e]
            nbrs = indices[s:e]
            upd = nd < dist[nbrs]
            if upd.any():
                np.minimum.at(dist, nbrs, nd)
                changed = True
        if not changed:
            break
    return np.where(dist >= INF_I32, int(INF_I32), dist).astype(np.int64)


def pagerank_ref(g: CSRGraph, delta: float = 0.85, beta: float = 1e-4,
                 max_iter: int = 100) -> np.ndarray:
    """Paper Fig. 19: pull over nodes_to(v), val=(1-delta)/N + delta*sum,
    loop while (diff > beta) && (iter < maxIter); diff accumulates signed
    (val - pr) exactly as the DSL's `diff += val - v.pageRank`."""
    indptr, indices, _, rev_indptr, rev_indices, _ = _np_csr(g)
    n = g.num_nodes
    out_deg = np.diff(indptr).astype(np.float64)
    pr = np.full(n, 1.0 / n)
    for _ in range(max_iter):
        nxt = np.zeros(n)
        for v in range(n):
            s, e = rev_indptr[v], rev_indptr[v + 1]
            nbrs = rev_indices[s:e]
            d = out_deg[nbrs]
            contrib = np.where(d > 0, pr[nbrs] / np.maximum(d, 1), 0.0)
            nxt[v] = (1 - delta) / n + delta * contrib.sum()
        # The paper's Fig. 19 PDF shows `diff += val - v.pageRank`; the
        # Green-Marl original this is borrowed from uses |val - pr| (L1),
        # and signed diff telescopes to ~0 — we use L1 (see DESIGN.md).
        diff = np.sum(np.abs(nxt - pr))
        pr = nxt
        if not (diff > beta):
            break
    return pr


def ppr_matrix_ref(g: CSRGraph, sources, delta: float = 0.85,
                   beta: float = 1e-4, max_iter: int = 100) -> np.ndarray:
    """Per-source personalized PageRank rows, [B, N].  Mirrors ppr.sp: the
    restart vector is the indicator on the source, rank starts at restart,
    each sweep pulls rank/out_deg over in-neighbors, and the do-while runs
    per source while (L1 diff > beta) && (iter < maxIter)."""
    indptr, indices, _, rev_indptr, rev_indices, _ = _np_csr(g)
    n = g.num_nodes
    out_deg = np.diff(indptr).astype(np.float64)
    rows = np.zeros((len(sources), n))
    for i, src in enumerate(sources):
        restart = np.zeros(n)
        restart[int(src)] = 1.0
        rank = restart.copy()
        it = 0
        while True:   # do-while: always at least one sweep
            nxt = np.zeros(n)
            for v in range(n):
                s, e = rev_indptr[v], rev_indptr[v + 1]
                nbrs = rev_indices[s:e]
                contrib = rank[nbrs] / np.maximum(out_deg[nbrs], 1)
                nxt[v] = (1 - delta) * restart[v] + delta * contrib.sum()
            diff = np.sum(np.abs(nxt - rank))
            rank = nxt
            it += 1
            if not (diff > beta and it < max_iter):
                break
        rows[i] = rank
    return rows


def ppr_ref(g: CSRGraph, sources, delta: float = 0.85, beta: float = 1e-4,
            max_iter: int = 100) -> np.ndarray:
    """Aggregate PPR of a seed set — the sum of the per-source rows, which
    is exactly what ppr.sp's shared `ppr` property accumulates."""
    return ppr_matrix_ref(g, sources, delta, beta, max_iter).sum(axis=0)


def label_propagation_ref(g: CSRGraph) -> np.ndarray:
    """Min-label propagation along edge direction (lp.sp): every vertex
    converges to the smallest vertex id among its directed ancestors
    (itself included)."""
    indptr, indices, *_ = _np_csr(g)
    n = g.num_nodes
    label = np.arange(n, dtype=np.int64)
    changed = True
    while changed:
        changed = False
        for v in range(n):
            lv = label[v]
            for w in indices[indptr[v]:indptr[v + 1]]:
                if lv < label[w]:
                    label[w] = lv
                    changed = True
    return label


def kcore_ref(g: CSRGraph, k: int) -> np.ndarray:
    """Directed k-core by iterative peeling (kcore.sp): repeatedly drop
    every surviving vertex whose out-degree *within the survivors* is < k;
    the fixpoint is order-independent.  Returns 0/1 survivor flags."""
    indptr, indices, *_ = _np_csr(g)
    n = g.num_nodes
    core = np.ones(n, np.int64)
    while True:
        deg = np.zeros(n, np.int64)
        for v in range(n):
            if core[v]:
                nbrs = indices[indptr[v]:indptr[v + 1]]
                deg[v] = int(core[nbrs].sum())
        peel = (core == 1) & (deg < k)
        if not peel.any():
            return core
        core[peel] = 0


def triangle_count_ref(g: CSRGraph) -> int:
    """Paper Fig. 20: for v, for u in nbrs(v) u<v, for w in nbrs(v) w>v,
    count if (u, w) is an edge."""
    indptr, indices, *_ = _np_csr(g)
    n = g.num_nodes
    adj = [set(indices[indptr[v]:indptr[v + 1]].tolist()) for v in range(n)]
    count = 0
    for v in range(n):
        nbrs = indices[indptr[v]:indptr[v + 1]]
        us = nbrs[nbrs < v]
        ws = nbrs[nbrs > v]
        for u in us:
            au = adj[int(u)]
            count += sum(1 for w in ws if int(w) in au)
    return count


def bfs_levels_ref(g: CSRGraph, src: int) -> np.ndarray:
    indptr, indices, *_ = _np_csr(g)
    n = g.num_nodes
    level = np.full(n, -1, np.int64)
    level[src] = 0
    frontier = [src]
    cur = 0
    while frontier:
        nxt = []
        for v in frontier:
            for w in indices[indptr[v]:indptr[v + 1]]:
                if level[w] < 0:
                    level[w] = cur + 1
                    nxt.append(int(w))
        frontier, cur = nxt, cur + 1
    return level


def bc_ref(g: CSRGraph, sources) -> np.ndarray:
    """Brandes over the BFS DAG, per the paper's Fig. 18 semantics:
    delta(v) = sum_{w in succ_DAG(v)} sigma(v)/sigma(w) * (1 + delta(w)),
    BC(v) += delta(v) for v != src."""
    indptr, indices, *_ = _np_csr(g)
    n = g.num_nodes
    bc = np.zeros(n)
    for src in sources:
        level = bfs_levels_ref(g, src)
        sigma = np.zeros(n)
        sigma[src] = 1.0
        maxlev = int(level.max())
        # forward: accumulate path counts level by level
        for lev in range(maxlev):
            for v in np.nonzero(level == lev)[0]:
                for w in indices[indptr[v]:indptr[v + 1]]:
                    if level[w] == lev + 1:
                        sigma[w] += sigma[v]
        delta = np.zeros(n)
        for lev in range(maxlev - 1, -1, -1):
            for v in np.nonzero(level == lev)[0]:
                for w in indices[indptr[v]:indptr[v + 1]]:
                    if level[w] == lev + 1 and sigma[w] > 0:
                        delta[v] += sigma[v] / sigma[w] * (1 + delta[w])
        mask = level >= 0
        mask[src] = False
        bc[mask] += delta[mask]
    return bc
