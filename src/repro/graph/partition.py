"""Graph partitioning for the distributed backend.

Two schemes:

1. `block_partition_1d` — the paper's MPI scheme (§3.1/§4.2): contiguous
   equal-size vertex blocks per device ("index-based partitioning"), with the
   last block padded ("we pad temporary vertices for the last process").
   Every device owns the out-edges of its vertex block. Per-device edge
   counts differ, so each device's edge array is padded to the global max
   with harmless sentinel edges (src=dst=0, weight=INF, valid=0).

2. `partition_2d` — beyond-paper CombBLAS-style 2-D partitioning for the
   (data × model) mesh. The adjacency is blocked into R×C tiles; device
   (i, j) holds edges with dst ∈ block_i (contiguous, size N/R) and
   src ∈ colset_j (the interleaved pieces {b : b mod C == j}). Vertex state
   is sharded N/(R·C) per device (piece b = i*C + j). One relax step is then
     x_j  = all_gather(own piece, axis='data')          # N/C per device
     part = local semiring product over the tile        # N/R per device
     own' = reduce_scatter(part, axis='model', combiner)# N/(R·C)
   i.e. O(N/C + N/R) collective bytes/device/step instead of the 1-D O(N).

Both produce host-side numpy arrays stacked on leading device axes so they
can be dropped straight into `shard_map` via NamedSharding.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSRGraph, INF_I32


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class Partition1D:
    """Edges partitioned by source-vertex block; stacked [P, Emax]."""
    src: np.ndarray      # int32[P, Emax]  global src id
    dst: np.ndarray      # int32[P, Emax]  global dst id
    weight: np.ndarray   # int32[P, Emax]
    valid: np.ndarray    # bool [P, Emax]
    num_devices: int
    block: int           # vertices per device (padded)
    num_nodes_padded: int


def block_partition_1d(g: CSRGraph, num_devices: int) -> Partition1D:
    p = num_devices
    block = _ceil_div(g.num_nodes, p)
    n_pad = block * p
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.indices)
    w = np.asarray(g.weights)
    owner = src // block
    emax = max(int(np.bincount(owner, minlength=p).max()) if len(src) else 0, 1)
    out_src = np.zeros((p, emax), np.int32)
    out_dst = np.zeros((p, emax), np.int32)
    out_w = np.full((p, emax), int(INF_I32), np.int32)
    out_valid = np.zeros((p, emax), bool)
    for d in range(p):
        sel = owner == d
        k = int(sel.sum())
        out_src[d, :k] = src[sel]
        out_dst[d, :k] = dst[sel]
        out_w[d, :k] = w[sel]
        out_valid[d, :k] = True
    return Partition1D(out_src, out_dst, out_w, out_valid, p, block, n_pad)


@dataclasses.dataclass(frozen=True)
class Partition2D:
    """Adjacency tiles for an R×C (data × model) mesh.

    Index remapping (all host-side, baked into the edge arrays):
      - `src_local[i,j,e]` = position of the edge's source inside the
        all-gathered x_j (the i-ordered concat of pieces {b*C + j}).
      - `dst_local[i,j,e]` = position of the edge's dest inside dst block i
        (contiguous range [i*N/R, (i+1)*N/R)).
    """
    src_local: np.ndarray   # int32[R, C, Emax]
    dst_local: np.ndarray   # int32[R, C, Emax]
    weight: np.ndarray      # int32[R, C, Emax]
    valid: np.ndarray       # bool [R, C, Emax]
    rows: int               # R (data axis size)
    cols: int               # C (model axis size)
    piece: int              # vertices per device piece (padded)
    num_nodes_padded: int

    @property
    def block_rows(self) -> int:   # dst block size N/R
        return self.piece * self.cols

    @property
    def block_cols(self) -> int:   # src block size N/C
        return self.piece * self.rows


def partition_2d(g: CSRGraph, rows: int, cols: int) -> Partition2D:
    r, c = rows, cols
    piece = _ceil_div(g.num_nodes, r * c)
    n_pad = piece * r * c
    src = np.asarray(g.edge_src).astype(np.int64)
    dst = np.asarray(g.indices).astype(np.int64)
    w = np.asarray(g.weights)

    # piece id of a vertex v: b = v // piece ; owner (i, j): i = b // c, j = b % c
    b_src = src // piece
    b_dst = dst // piece
    j_of = (b_src % c).astype(np.int64)          # src column set
    i_of = (b_dst // c).astype(np.int64)         # dst row block
    # position of src inside gathered x_j: pieces ordered by i' = b // c
    src_local = (b_src // c) * piece + (src % piece)
    # position of dst inside contiguous dst block i
    dst_local = dst - i_of * (piece * c)

    tile = i_of * c + j_of
    counts = np.bincount(tile, minlength=r * c)
    emax = max(int(counts.max()) if len(src) else 0, 1)
    o_src = np.zeros((r, c, emax), np.int32)
    o_dst = np.zeros((r, c, emax), np.int32)
    o_w = np.full((r, c, emax), int(INF_I32), np.int32)
    o_valid = np.zeros((r, c, emax), bool)
    for i in range(r):
        for j in range(c):
            sel = tile == (i * c + j)
            k = int(sel.sum())
            o_src[i, j, :k] = src_local[sel]
            o_dst[i, j, :k] = dst_local[sel]
            o_w[i, j, :k] = w[sel]
            o_valid[i, j, :k] = True
    return Partition2D(o_src, o_dst, o_w, o_valid, r, c, piece, n_pad)


def piece_order_to_global(part: Partition2D) -> np.ndarray:
    """global_id[i, j, k] for piece-sharded state: device (i,j) owns
    vertices [(i*C + j)*piece, ...+piece)."""
    r, c, piece = part.rows, part.cols, part.piece
    base = (np.arange(r * c) * piece).reshape(r, c)
    return base[..., None] + np.arange(piece)[None, None, :]
