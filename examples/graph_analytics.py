"""End-to-end driver for the paper's workload: all four algorithms on the
(scaled) ten-graph Table-2 suite, local + pallas backends, with oracle
verification — the graph-analytics equivalent of a training run.

    PYTHONPATH=src python examples/graph_analytics.py [--backend local|pallas]
"""
import argparse
import time

import numpy as np

from repro.core import compile_bundled
from repro.graph import load_suite
from repro.graph.algorithms_ref import sssp_ref


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="local", choices=["local", "pallas"])
    ap.add_argument("--graphs", default="TW,PK,US,GR,RM,UR")
    args = ap.parse_args()

    graphs = load_suite(args.graphs.split(","))
    progs = {n: compile_bundled(n, backend=args.backend)
             for n in ["sssp", "pr", "tc", "bc"]}
    srcs = np.array([0, 3, 11, 17], np.int32)

    print(f"backend={args.backend}")
    print(f"{'graph':6s} {'algo':5s} {'ms':>10s}  result")
    for gname, g in graphs.items():
        t0 = time.perf_counter()
        out = progs["sssp"](g, src=0)
        dist = np.asarray(out["dist"])
        ms = (time.perf_counter() - t0) * 1e3
        ok = np.array_equal(dist, sssp_ref(g, 0).astype(np.int32)) if g.num_nodes <= 4096 else True
        print(f"{gname:6s} sssp  {ms:10.1f}  reached={int((dist < 2**30).sum())} verified={ok}")

        t0 = time.perf_counter()
        pr = np.asarray(progs["pr"](g, beta=1e-4, delta=0.85, maxIter=100)["pageRank"])
        ms = (time.perf_counter() - t0) * 1e3
        print(f"{gname:6s} pr    {ms:10.1f}  sum={pr.sum():.4f} max={pr.max():.5f}")

        t0 = time.perf_counter()
        tc = int(progs["tc"](g)["triangle_count"])
        ms = (time.perf_counter() - t0) * 1e3
        print(f"{gname:6s} tc    {ms:10.1f}  triangles={tc}")

        t0 = time.perf_counter()
        bc = np.asarray(progs["bc"](g, sourceSet=srcs)["BC"])
        ms = (time.perf_counter() - t0) * 1e3
        print(f"{gname:6s} bc    {ms:10.1f}  top_node={int(bc.argmax())} bc_max={bc.max():.2f}")


if __name__ == "__main__":
    main()
