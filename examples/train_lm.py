"""End-to-end LM training on the substrate the dry-run deploys: a reduced
minicpm-style model (WSD schedule, the arch's paper-of-record trick), with
checkpoint/restart fault tolerance demonstrated mid-run.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import tempfile
import time

import jax

from repro.configs import ARCHS
from repro.models import build
from repro.train import (OptimizerConfig, checkpoint as ckpt, init_state,
                         make_train_step)
from repro.train.data import DataConfig, batch_at


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    # reduced same-family config, slightly widened for a real loss curve
    cfg = dataclasses.replace(ARCHS[args.arch].smoke(), n_layers=4, vocab=1024)
    model = build(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(model.init(jax.random.PRNGKey(0))))
    print(f"arch={cfg.name} (reduced) params={n_params/1e6:.1f}M "
          f"schedule={'wsd' if cfg.wsd_schedule else 'cosine'}")

    oc = OptimizerConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                         schedule="wsd" if cfg.wsd_schedule else "cosine")
    step_fn = jax.jit(make_train_step(model, oc,
                                      microbatches=args.microbatches, impl="ref"))
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                    structure=8)

    state = init_state(model, jax.random.PRNGKey(0))
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    t0 = time.time()
    for i in range(args.steps):
        state, m = step_fn(state, batch_at(dc, i))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  gnorm {float(m['grad_norm']):.2f}")
        if i == args.steps // 2:
            # mid-run checkpoint + simulated failure + restore
            ckpt.save(ckpt_dir, i + 1, state)
            print(f"--- checkpoint at step {i+1}; simulating failure+restart ---")
            state = ckpt.restore(ckpt_dir, ckpt.latest_step(ckpt_dir),
                                 init_state(model, jax.random.PRNGKey(0)))
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps, {toks/dt:.0f} tok/s on CPU, "
          f"final loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
