"""Quickstart: compile a StarPlat program and run it on three backends.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import compile_program
from repro.graph import uniform_random

SSSP_SOURCE = """
// Single-source shortest paths (paper Fig. 3)
function Compute_SSSP(Graph g, node src) {
  propNode<int> dist;
  propNode<bool> modified;
  g.attachNodeProperty(dist = INF, modified = False);
  src.dist = 0;
  src.modified = True;
  bool finished = False;
  fixedPoint until (finished : !modified) {
    forall(v in g.nodes().filter(modified == True)) {
      forall(nbr in g.neighbors(v)) {
        edge e = g.getEdge(v, nbr);
        <nbr.dist, nbr.modified> = <Min(nbr.dist, v.dist + e.weight), True>;
      }
    }
  }
}
"""


def main():
    g = uniform_random(1000, 8, seed=42)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges\n")

    print("=== DSL source ===")
    print(SSSP_SOURCE)

    local = compile_program(SSSP_SOURCE, backend="local")
    print("=== generated JAX (local backend, first 25 lines) ===")
    print("\n".join(local.source.splitlines()[:25]))
    print("    ...\n")

    # bind(g) is the uniform per-graph entry point on every backend
    out = local.bind(g)(src=0)
    dist = np.asarray(out["dist"])
    reach = dist < 2**30
    print(f"local backend:   reached {reach.sum()} nodes, "
          f"max dist {dist[reach].max()}")

    pallas = compile_program(SSSP_SOURCE, backend="pallas")
    out_p = pallas.bind(g)(src=0)
    same = np.array_equal(np.asarray(out_p["dist"]), dist)
    print(f"pallas backend:  identical result: {same} "
          f"(block-ELL min-plus kernel)")

    distp = compile_program(SSSP_SOURCE, backend="distributed")
    out_d = distp.bind(g)(src=0)   # single-shard mesh in this process
    same_d = np.array_equal(np.asarray(out_d["dist"]), dist)
    print(f"distributed backend: identical result: {same_d} "
          f"({len(distp.source.splitlines())}-line per-device body under "
          "shard_map; multi-device via bind(g, mesh=...) — see "
          "examples/graph_analytics.py)")


if __name__ == "__main__":
    main()
