"""Multi-tenant graph query serving: `GraphService` end to end.

This example drives the async serving layer the engine API exists for
(`repro.serve.GraphService`): a server answering SSSP/BFS/BC queries for
many concurrent users, across several registered graphs, must never
re-parse DSL source, re-generate code, or rebuild per-graph views on the
query path — and should *coalesce* concurrent compatible queries into one
batched [N, B]-lane sweep. Everything expensive happens at registration:

  * `register_graph(name, g)` — fingerprints the graph, warm-reloads any
    persisted `TuningStore` record (tuned schedule without a measurement
    sweep), compiles the bundled programs through the compile cache,
    prepares the graph's derived views, and memoizes `prog.bind(g)`;
  * `await service.query(graph, kind, src=...)` — admission-checked,
    coalesced with concurrent lane-mates (up to `Schedule.batch_sources`
    per sweep, waiting at most `max_wait_ms`), answered from one batched
    sweep's per-source rows.

With `--autotune`, the server tunes the schedule per (program, graph)
before registering (`repro.autotune`); `--tune-store PATH` persists the
records so the next server start warm-reloads instead of re-measuring.
Every served answer is verified against the numpy reference oracles.

    PYTHONPATH=src python examples/query_server.py [--smoke] [--autotune]
"""
import argparse
import asyncio
import time

import numpy as np

from repro.autotune import TuningStore, autotune
from repro.core import compile_bundled
from repro.graph import preferential_attachment
from repro.graph.algorithms_ref import bc_ref, sssp_ref
from repro.serve import GraphService, ServiceConfig


async def serve(args, svc: GraphService, graphs: dict):
    rng = np.random.default_rng(0)

    # ---- fire concurrent SSSP queries across users AND graphs -----------
    queries = []   # (graph name, src)
    for name, g in graphs.items():
        for s in rng.integers(0, g.num_nodes, args.queries):
            queries.append((name, int(s)))
    rng.shuffle(queries)

    t0 = time.perf_counter()
    results = await asyncio.gather(
        *(svc.query(name, "sssp", src=s) for name, s in queries))
    total = time.perf_counter() - t0
    st = svc.stats()
    print(f"SSSP: {len(queries)} concurrent queries over {len(graphs)} "
          f"graphs in {total:.2f} s ({len(queries) / total:.1f} q/s; "
          f"first sweep pays the jit trace)")
    print(f"  coalescing: {st['sweeps']} sweeps, mean lane occupancy "
          f"{st['mean_batch']:.1f}, max {st['max_batch']}")

    # verify EVERY served answer against the reference oracle
    oracle = {}
    for (name, s), dist in zip(queries, results):
        key = (name, s)
        if key not in oracle:
            oracle[key] = sssp_ref(graphs[name], s).astype(np.int32)
        assert np.array_equal(np.asarray(dist), oracle[key]), key
    print(f"  verified: all {len(queries)} answers == numpy oracle")

    # ---- a BC request serves its own source set through the [N, B] lanes
    name, g = next(iter(graphs.items()))
    srcs = rng.integers(0, g.num_nodes, args.batch).astype(np.int32)
    t0 = time.perf_counter()
    bc = await svc.query(name, "bc", sourceSet=srcs)
    print(f"BC: {len(srcs)}-source aggregate on {name!r} in "
          f"{1e3 * (time.perf_counter() - t0):.1f} ms "
          f"(top node {int(np.asarray(bc).argmax())})")
    np.testing.assert_allclose(np.asarray(bc), bc_ref(g, srcs.tolist()),
                               atol=1e-3)
    print("  verified: BC == numpy oracle")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="pallas", choices=["local", "pallas"])
    ap.add_argument("--nodes", type=int, default=4000)
    ap.add_argument("--batch", type=int, default=16,
                    help="Schedule.batch_sources — lanes per coalesced sweep")
    ap.add_argument("--queries", type=int, default=64,
                    help="concurrent SSSP queries per graph")
    ap.add_argument("--max-wait-ms", type=float, default=10.0,
                    help="coalescing deadline for a partial lane")
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--autotune", action="store_true",
                    help="tune the schedule per (program, graph) at startup")
    ap.add_argument("--tune-budget", type=int, default=8,
                    help="candidate schedules measured per program")
    ap.add_argument("--tune-store", default=None, metavar="PATH",
                    help="persist tuning records; later starts warm-reload "
                         "instead of re-measuring")
    args = ap.parse_args()
    if args.smoke:
        args.nodes, args.batch, args.queries = 600, 8, 16
        args.tune_budget = min(args.tune_budget, 4)

    from repro.schedule import Schedule
    sched = Schedule(batch_sources=args.batch)
    graphs = {
        "social": preferential_attachment(args.nodes, m=6, seed=3),
        "web": preferential_attachment(max(args.nodes // 2, 200), m=4, seed=11),
    }
    for name, g in graphs.items():
        print(f"graph {name!r}: {g.num_nodes} nodes, {g.num_edges} edges")
    print(f"backend={args.backend} | batch_sources={sched.batch_sources} | "
          f"max_wait_ms={args.max_wait_ms}")

    store = TuningStore(args.tune_store) if args.tune_store else None
    if args.autotune:
        # tune once per (program, graph); the service then WARM-RELOADS the
        # records at registration (keyed source digest + graph fingerprint),
        # so a restarted server never re-measures. NB: `store or ...` would
        # discard an EMPTY path-backed store (TuningStore has __len__)
        if store is None:
            store = TuningStore()
        t0 = time.perf_counter()
        for pname in ("sssp", "bc"):
            prog = compile_bundled(pname, backend=args.backend, schedule=sched)
            for gname, g in graphs.items():
                res = autotune(prog, g, budget=args.tune_budget, seed=0,
                               store=store)
                how = ("warm-reloaded" if res.from_store
                       else f"{len(res.record.trials)} trials")
                print(f"autotune[{pname}/{gname}]: {how}, best "
                      f"{res.speedup:.2f}x -> {res.schedule}")
        print(f"autotune total: {time.perf_counter() - t0:.1f} s")

    svc = GraphService(
        ServiceConfig(backend=args.backend, schedule=sched,
                      max_wait_ms=args.max_wait_ms),
        tune_store=store)
    t0 = time.perf_counter()
    for name, g in graphs.items():
        h = svc.register_graph(name, g)
        tuned = f" (tuned: {', '.join(h.tuned)})" if h.tuned else ""
        print(f"register_graph({name!r}): "
              f"{1e3 * (time.perf_counter() - t0):.0f} ms — compiled, "
              f"prepared, bound{tuned}")
        t0 = time.perf_counter()

    async def run():
        async with svc:
            await serve(args, svc, graphs)

    asyncio.run(run())


if __name__ == "__main__":
    main()
