"""Query server: compile once per (program, schedule), prepare each graph
once, then stream batched analytics queries through the cached programs.

This is the loop the Schedule / GraphContext / compile-cache API exists
for: a server answering BC and SSSP queries for many users must never
re-parse DSL source, re-generate code, or rebuild per-graph views on the
query path. Here everything expensive happens before the first request:

  * `compile_bundled(..., schedule=sched)` — memoized on
    (source, backend, schedule); a repeated request for the same program
    returns the SAME CompiledProgram (asserted below);
  * `prepare(g, sched, backend=...)` — builds the graph's derived views
    (sliced-ELL buckets) in its shared GraphContext;
  * `prog.bind(g)` — the per-graph entry point every query goes through.

BC requests are served in source batches (`Schedule.batch_sources` lanes
per sweep); SSSP requests are served both through the compiled program
(one query per call) and through the batched engine (`rt.sssp_multi`, B
queries per sweep) for comparison.

With `--autotune`, the server tunes the schedule per (program, graph)
before serving (`repro.autotune`): the tuner sweeps candidate schedules
derived from the graph's statistics, and `--tune-store PATH` persists the
result so the next server start skips the sweep entirely (the stored
record is keyed by source digest + graph fingerprint, so it is re-tuned
automatically if either changes).

    PYTHONPATH=src python examples/query_server.py [--smoke] [--autotune]
"""
import argparse
import time

import numpy as np

from repro.autotune import autotune
from repro.core import Schedule, compile_bundled, prepare
from repro.core import runtime as rt
from repro.graph import preferential_attachment
from repro.graph.algorithms_ref import sssp_ref


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="pallas", choices=["local", "pallas"])
    ap.add_argument("--nodes", type=int, default=4000)
    ap.add_argument("--batch", type=int, default=16, help="sources per batch")
    ap.add_argument("--batches", type=int, default=4, help="batches to serve")
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--autotune", action="store_true",
                    help="tune the schedule per (program, graph) at startup")
    ap.add_argument("--tune-budget", type=int, default=8,
                    help="candidate schedules measured per program")
    ap.add_argument("--tune-store", default=None, metavar="PATH",
                    help="persist tuning records; later starts reload "
                         "instead of re-measuring")
    args = ap.parse_args()
    if args.smoke:
        args.nodes, args.batch, args.batches = 600, 8, 2
        args.tune_budget = min(args.tune_budget, 4)

    sched = Schedule(batch_sources=args.batch)
    g = preferential_attachment(args.nodes, m=6, seed=3)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges | "
          f"backend={args.backend} | schedule batch_sources={sched.batch_sources}")

    # ---- startup: compile once, prepare the graph once ------------------
    t0 = time.perf_counter()
    prepare(g, sched, backend=args.backend)
    print(f"prepare(g, sched): {1e3 * (time.perf_counter() - t0):.0f} ms "
          "(sliced-ELL views built, owned by the graph's GraphContext)")

    t0 = time.perf_counter()
    bc = compile_bundled("bc", backend=args.backend, schedule=sched)
    sssp = compile_bundled("sssp", backend=args.backend, schedule=sched)
    print(f"compile bc+sssp: {1e3 * (time.perf_counter() - t0):.0f} ms")
    # a second request for the same (program, schedule) is a cache hit:
    assert compile_bundled("bc", backend=args.backend, schedule=sched) is bc
    assert compile_bundled("sssp", backend=args.backend, schedule=sched) is sssp
    print("compile cache: repeated requests return the same CompiledProgram")

    if args.autotune:
        # tune once per (program, graph); with --tune-store the next server
        # start is a lookup (keyed source digest + graph fingerprint), not
        # a measurement sweep
        t0 = time.perf_counter()
        for name in ("bc", "sssp"):
            prog = {"bc": bc, "sssp": sssp}[name]
            res = autotune(prog, g, budget=args.tune_budget, seed=0,
                           store=args.tune_store)
            how = ("reloaded from store" if res.from_store
                   else f"{len(res.record.trials)} trials")
            print(f"autotune[{name}]: {how}, best {res.speedup:.2f}x vs "
                  f"compiled schedule -> {res.schedule}")
            if name == "bc":
                bc = res.program
            else:
                sssp = res.program
        print(f"autotune total: {time.perf_counter() - t0:.1f} s")

    bc_bound = bc.bind(g)
    sssp_bound = sssp.bind(g)

    rng = np.random.default_rng(0)

    # ---- serve BC query batches ----------------------------------------
    served = 0
    t0 = time.perf_counter()
    for i in range(args.batches):
        srcs = rng.integers(0, g.num_nodes, args.batch).astype(np.int32)
        t1 = time.perf_counter()
        out = np.asarray(bc_bound(sourceSet=srcs)["BC"])
        dt = time.perf_counter() - t1
        served += len(srcs)
        print(f"  BC batch {i}: {len(srcs)} sources in {1e3 * dt:7.1f} ms "
              f"(top node {int(out.argmax())})")
    total = time.perf_counter() - t0
    print(f"BC: {served} source-queries in {total:.2f} s "
          f"({served / total:.1f} q/s; first batch pays the jit trace)")

    # ---- serve SSSP query batches --------------------------------------
    srcs = rng.integers(0, g.num_nodes, args.batch).astype(np.int32)
    t0 = time.perf_counter()
    dist_multi = np.asarray(rt.sssp_multi(g, srcs))
    dt_multi = time.perf_counter() - t0
    print(f"SSSP batched engine: {len(srcs)} queries in one sweep "
          f"({1e3 * dt_multi:.1f} ms)")
    t0 = time.perf_counter()
    d0 = np.asarray(sssp_bound(src=int(srcs[0]))["dist"])
    print(f"SSSP compiled program: 1 query in "
          f"{1e3 * (time.perf_counter() - t0):.1f} ms")
    assert np.array_equal(dist_multi[0], d0), "batched vs compiled mismatch"
    ref = sssp_ref(g, int(srcs[0])).astype(np.int32)
    assert np.array_equal(d0, ref), "SSSP answer does not match oracle"
    print("verified: batched == compiled == numpy oracle")


if __name__ == "__main__":
    main()
