"""Batched serving demo: train a tiny model briefly so generation is
non-degenerate, then serve batched greedy continuations through the same
decode_step the dry-run lowers at decode_32k/long_500k shapes.

    PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import build
from repro.serve import ServeEngine
from repro.train import OptimizerConfig, init_state, make_train_step
from repro.train.data import DataConfig, batch_at


def main():
    cfg = dataclasses.replace(ARCHS["qwen2.5-3b"].smoke(), n_layers=2, vocab=256)
    model = build(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    oc = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(model, oc, impl="ref"))
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, structure=4)
    for i in range(60):
        state, m = step(state, batch_at(dc, i))
    print(f"pre-trained tiny model to loss {float(m['loss']):.3f} "
          "(periodic n-grams)")

    engine = ServeEngine(model, state.params, max_len=48, batch_size=4)
    # prompts drawn from the training distribution (period-4 n-grams)
    base = batch_at(dc, 999)["tokens"][:4, :8]
    res = engine.generate(np.asarray(base), new_tokens=12)
    for i, seq in enumerate(res.tokens):
        prompt, gen = seq[:8].tolist(), seq[8:].tolist()
        print(f"req{i}: prompt={prompt} → generated={gen}")
    # a learned period-4 model should repeat the prompt's cycle
    period_hits = sum(int(seq[8 + j] == seq[8 + j - 4])
                      for seq in res.tokens for j in range(4, 12))
    print(f"period-4 consistency: {period_hits}/{4*8} generated tokens")


if __name__ == "__main__":
    main()
